//! Transfer error — the paper's Algorithm 1 (§5.2): how much loss is lost
//! by tuning the *transfer* HP at a non-optimal value of the *fixed* HP
//! and carrying it over to the fixed HP's optimum.

use crate::util::stats;

use super::PairGrid;

#[derive(Debug, Clone)]
pub struct TransferError {
    pub fixed_name: String,
    pub transfer_name: String,
    pub error: f64,
}

/// Algorithm 1 over a completed [`PairGrid`].
///
/// err = mean over f != f* of [ L(f*, argmin_t L(f, t)) - L(f*, t*) ].
pub fn transfer_error(grid: &PairGrid) -> TransferError {
    let nf = grid.fixed_vals.len();
    let nt = grid.transfer_vals.len();
    // global argmin (f*, t*)
    let mut best = (0usize, 0usize);
    let mut best_loss = f64::INFINITY;
    for i in 0..nf {
        for j in 0..nt {
            if grid.loss[i][j] < best_loss {
                best_loss = grid.loss[i][j];
                best = (i, j);
            }
        }
    }
    let (fs, ts) = best;
    let mut err = 0.0;
    let mut n = 0usize;
    for f in 0..nf {
        if f == fs {
            continue;
        }
        // best transfer value at this (non-optimal) fixed value
        let t = stats::argmin(&grid.loss[f]);
        let delta = grid.loss[fs][t] - grid.loss[fs][ts];
        if delta.is_finite() {
            err += delta;
            n += 1;
        } else {
            // a diverged transfer pick is the worst possible outcome;
            // penalize with the grid's worst finite excess
            let worst = grid
                .loss
                .iter()
                .flatten()
                .filter(|l| l.is_finite())
                .fold(f64::NEG_INFINITY, |a, &b| a.max(b));
            err += worst - grid.loss[fs][ts];
            n += 1;
        }
    }
    TransferError {
        fixed_name: grid.fixed_name.clone(),
        transfer_name: grid.transfer_name.clone(),
        error: if n > 0 { err / n as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(loss: Vec<Vec<f64>>) -> PairGrid {
        PairGrid {
            fixed_name: "a".into(),
            transfer_name: "b".into(),
            fixed_vals: (0..loss.len()).map(|i| i as f64).collect(),
            transfer_vals: (0..loss[0].len()).map(|i| i as f64).collect(),
            loss,
        }
    }

    #[test]
    fn independent_hps_have_zero_error() {
        // separable bowl: argmin_t is the same column for every row
        let g = grid(vec![
            vec![3.0, 1.0, 2.0],
            vec![4.0, 2.0, 3.0],
            vec![5.0, 3.0, 4.0],
        ]);
        let e = transfer_error(&g);
        assert_eq!(e.error, 0.0);
    }

    #[test]
    fn coupled_hps_have_positive_error() {
        // diagonal valley: optimal t shifts with f (the Fig 14 pattern)
        let g = grid(vec![
            vec![0.0, 1.0, 4.0],
            vec![1.0, 0.5, 1.0],
            vec![4.0, 1.0, 0.4],
        ]);
        let e = transfer_error(&g);
        assert!(e.error > 0.5, "{e:?}");
    }

    #[test]
    fn handles_divergence() {
        let g = grid(vec![vec![1.0, 0.0], vec![f64::INFINITY, 5.0]]);
        let e = transfer_error(&g);
        assert!(e.error.is_finite());
    }
}
