//! Parallel run scheduler: executes batches of training runs across a
//! thread pool.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (!Send), so sessions
//! cannot cross threads: each worker compiles its *own* [`Session`] from
//! the (plain-data, `Send`) manifest and amortizes that compile over its
//! share of the job queue.  XLA's own intra-op thread pool already uses
//! the cores during each run, so `workers` trades batch-level against
//! op-level parallelism — tiny proxy models profit from more workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::data::Corpus;
use crate::runtime::{Manifest, Session};
use crate::train::{RunConfig, RunRecord, Runner};

/// One sweep job: a run config (the manifest/corpus come from the caller).
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub config: RunConfig,
    /// Arbitrary tag carried through to the result (e.g. HP values).
    pub tag: Vec<(String, f64)>,
}

#[derive(Debug, Clone)]
pub struct SweepResult {
    pub job: SweepJob,
    pub record: RunRecord,
}

/// Run all jobs with `workers` threads; results keep job order.
pub fn run_all_parallel(
    manifest: Arc<Manifest>,
    corpus: &Corpus,
    jobs: &[SweepJob],
    workers: usize,
) -> Result<Vec<SweepResult>> {
    let workers = workers.max(1).min(jobs.len().max(1));
    if workers == 1 {
        // fast path: reuse the caller's thread without a second compile
        let session = Arc::new(Session::open(manifest)?);
        let runner = Runner::new(session);
        return run_all(&runner, corpus, jobs, 1);
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<SweepResult>>> = Mutex::new(vec![None; jobs.len()]);
    let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let manifest = manifest.clone();
            let next = &next;
            let results = &results;
            let errors = &errors;
            scope.spawn(move || {
                let runner = match Session::open(manifest) {
                    Ok(s) => Runner::new(Arc::new(s)),
                    Err(e) => {
                        errors.lock().unwrap().push(e.context("worker session"));
                        return;
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    match runner.run(&jobs[i].config, corpus) {
                        Ok(record) => {
                            results.lock().unwrap()[i] =
                                Some(SweepResult { job: jobs[i].clone(), record });
                        }
                        Err(e) => {
                            errors.lock().unwrap().push(e.context(format!(
                                "sweep job {} ({})",
                                i, jobs[i].config.label
                            )));
                            break;
                        }
                    }
                }
            });
        }
    });

    let errs = errors.into_inner().unwrap();
    if let Some(e) = errs.into_iter().next() {
        return Err(e);
    }
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.with_context(|| format!("job {i} not completed")))
        .collect()
}

/// Sequential runner-local execution (used by single-session callers and
/// as the workers' inner loop).
pub fn run_all(
    runner: &Runner,
    corpus: &Corpus,
    jobs: &[SweepJob],
    _workers: usize,
) -> Result<Vec<SweepResult>> {
    jobs.iter()
        .map(|job| {
            let record = runner
                .run(&job.config, corpus)
                .with_context(|| format!("sweep job {}", job.config.label))?;
            Ok(SweepResult { job: job.clone(), record })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_construction() {
        let j = SweepJob {
            config: crate::train::RunConfig::quick(
                "x",
                crate::parametrization::Parametrization::new(
                    crate::parametrization::Scheme::Umup,
                ),
                crate::parametrization::HpSet::default(),
                1,
            ),
            tag: vec![("eta".into(), 0.5)],
        };
        assert_eq!(j.tag[0].1, 0.5);
    }
}
