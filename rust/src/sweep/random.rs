//! Random search (the standard μP sweep protocol, §2.1 / A.6): sample HP
//! combinations uniformly from the joint grid, train each, keep the best.
//! Runs are submitted non-blockingly and consumed as they finish, so
//! the incumbent best is visible while the sweep is still draining.
//! `simulate_run_counts` reproduces Fig 1(a)'s best-loss-vs-#runs curve
//! by resampling subsets of the completed runs (exactly as §A.6 does).

use std::sync::Arc;

use anyhow::Result;

use crate::data::Corpus;
use crate::engine::{Engine, EngineJob};
use crate::parametrization::HpSet;
use crate::runtime::Manifest;
use crate::train::RunConfig;
use crate::util::{stats, Rng};

use super::{HpSpace, SweepResult};

#[derive(Debug)]
pub struct RandomOutcome {
    pub results: Vec<SweepResult>,
    pub best: usize,
    pub best_hp: HpSet,
    pub best_loss: f64,
}

/// Run an `n_runs` random search over `space`, using `proto` for
/// everything except the swept HP values.
pub fn random_search(
    engine: &Engine,
    manifest: &Arc<Manifest>,
    corpus: &Arc<Corpus>,
    space: &HpSpace,
    proto: &RunConfig,
    n_runs: usize,
    seed: u64,
) -> Result<RandomOutcome> {
    let mut rng = Rng::new(seed).fork("random-search");
    let mut jobs = Vec::with_capacity(n_runs);
    for i in 0..n_runs {
        let mut hp = proto.hp;
        let mut tag = Vec::new();
        for (name, range) in &space.dims {
            let v = range.sample(&mut rng);
            hp.set(name, v);
            tag.push((name.to_string(), v));
        }
        let mut cfg = proto.clone();
        cfg.hp = hp;
        cfg.schedule.peak_lr = hp.eta;
        cfg.label = format!("{}-rs{:03}", proto.label, i);
        jobs.push(EngineJob::new(Arc::clone(manifest), Arc::clone(corpus), cfg, tag));
    }
    // stream: the incumbent best is reported the moment a run beats it,
    // not after the whole sweep lands
    let mut incumbent = f64::INFINITY;
    let results = engine.submit(jobs).drain_strict(|o, done, total| {
        if let Ok(rec) = &o.outcome {
            if !o.cached && rec.objective() < incumbent {
                incumbent = rec.objective();
                println!(
                    "    random search [{done}/{total}] new best {:.4} ({})",
                    incumbent, o.job.config.label
                );
            }
        }
    })?;
    let losses: Vec<f64> = results.iter().map(|r| r.record.objective()).collect();
    let best = stats::argmin(&losses);
    Ok(RandomOutcome {
        best,
        best_hp: results[best].job.config.hp,
        best_loss: losses[best],
        results,
    })
}

/// Fig 1(a) curve: expected best loss after k runs, estimated by
/// resampling `trials` random k-subsets of the finished results.
pub fn simulate_run_counts(
    results: &[SweepResult],
    ks: &[usize],
    trials: usize,
    seed: u64,
) -> Vec<(usize, f64)> {
    let losses: Vec<f64> = results.iter().map(|r| r.record.objective()).collect();
    let mut rng = Rng::new(seed).fork("subset-sim");
    ks.iter()
        .map(|&k| {
            let k = k.min(losses.len());
            let mut acc = 0.0;
            for _ in 0..trials {
                let idx = rng.sample_indices(losses.len(), k);
                let best = idx.iter().map(|&i| losses[i]).fold(f64::INFINITY, f64::min);
                acc += best;
            }
            (k, acc / trials as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::SweepJob;
    use crate::train::RunRecord;
    use std::collections::BTreeMap;

    fn fake_result(loss: f64) -> SweepResult {
        SweepResult {
            job: SweepJob {
                config: RunConfig::quick(
                    "f",
                    crate::parametrization::Parametrization::new(
                        crate::parametrization::Scheme::Umup,
                    ),
                    HpSet::default(),
                    1,
                ),
                tag: vec![],
            },
            record: RunRecord {
                label: "f".into(),
                train_curve: vec![],
                valid_curve: vec![],
                final_valid_loss: loss,
                rms_curves: BTreeMap::new(),
                final_rms: vec![],
                diverged: false,
                wall_seconds: 0.0,
            },
        }
    }

    #[test]
    fn run_count_curve_is_monotone() {
        let results: Vec<SweepResult> =
            (0..50).map(|i| fake_result(3.0 + (i as f64 * 0.731).sin())).collect();
        let curve = simulate_run_counts(&results, &[1, 4, 16, 50], 200, 7);
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "{curve:?}");
        }
        // with all runs the sim equals the true min
        let all = curve.last().unwrap().1;
        let true_min =
            results.iter().map(|r| r.record.objective()).fold(f64::INFINITY, f64::min);
        assert!((all - true_min).abs() < 1e-12);
    }
}
