//! S10 — the HP-search algorithms (paper §2.1, §4.5, §5.2-5.3, A.5/A.6).
//!
//! * [`space`] — per-HP log2 search grids (Table 5 ranges);
//! * [`random`] — the standard μP random search;
//! * [`independent`] — u-μP's independent search (LR line search, then
//!   parallel 1-D sweeps, then combine);
//! * [`grid`] — 2-D HP-pair grids (Figs 14/15);
//! * [`transfer_error`] — Algorithm 1.
//!
//! Execution lives in [`crate::engine`] (the unified run engine): the
//! search strategies here only *plan* job batches and interpret the
//! results.  The old per-manifest thread-pool scheduler was absorbed by
//! the engine's multi-manifest worker pool; [`SweepJob`]/[`SweepResult`]
//! are re-exported from there for the callers' convenience.

mod grid;
mod independent;
mod random;
mod space;
mod transfer_error;

pub use crate::engine::{SweepJob, SweepResult};
pub use grid::{pair_grid, PairGrid};
pub use independent::{independent_search, IndependentOutcome};
pub use random::{random_search, simulate_run_counts, RandomOutcome};
pub use space::{HpSpace, Range};
pub use transfer_error::{transfer_error, TransferError};
