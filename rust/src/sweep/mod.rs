//! S10 — the HP-search engine (paper §2.1, §4.5, §5.2-5.3, A.5/A.6).
//!
//! * [`space`] — per-HP log2 search grids (Table 5 ranges);
//! * [`random`] — the standard μP random search;
//! * [`independent`] — u-μP's independent search (LR line search, then
//!   parallel 1-D sweeps, then combine);
//! * [`grid`] — 2-D HP-pair grids (Figs 14/15);
//! * [`transfer_error`] — Algorithm 1;
//! * [`scheduler`] — thread-pool execution of run batches.

mod grid;
mod independent;
mod random;
mod scheduler;
mod space;
mod transfer_error;

pub use grid::{pair_grid, PairGrid};
pub use independent::{independent_search, IndependentOutcome};
pub use random::{random_search, simulate_run_counts, RandomOutcome};
pub use scheduler::{run_all, run_all_parallel, SweepJob, SweepResult};
pub use space::{HpSpace, Range};
pub use transfer_error::{transfer_error, TransferError};
