//! 2-D HP-pair grids (the raw data behind Figs 14/15 and the transfer-
//! error matrix of Fig 4).

use std::sync::Arc;

use anyhow::Result;

use crate::data::Corpus;
use crate::engine::{Engine, EngineJob};
use crate::runtime::Manifest;
use crate::train::RunConfig;

use super::Range;

/// Losses over a (fixed HP x transfer HP) grid.
#[derive(Debug, Clone)]
pub struct PairGrid {
    pub fixed_name: String,
    pub transfer_name: String,
    pub fixed_vals: Vec<f64>,
    pub transfer_vals: Vec<f64>,
    /// loss[i][j] for fixed_vals[i], transfer_vals[j].
    pub loss: Vec<Vec<f64>>,
}

/// Train the full 2-D grid for one HP pair; all other HPs stay at
/// `proto.hp` (the paper holds them at defaults, §A.5).
pub fn pair_grid(
    engine: &Engine,
    manifest: &Arc<Manifest>,
    corpus: &Arc<Corpus>,
    proto: &RunConfig,
    fixed: (&str, Range),
    transfer: (&str, Range),
) -> Result<PairGrid> {
    let fixed_vals = fixed.1.grid();
    let transfer_vals = transfer.1.grid();
    let mut jobs = Vec::new();
    for (i, &fv) in fixed_vals.iter().enumerate() {
        for (j, &tv) in transfer_vals.iter().enumerate() {
            let mut cfg = proto.clone();
            cfg.hp.set(fixed.0, fv);
            cfg.hp.set(transfer.0, tv);
            cfg.schedule.peak_lr = cfg.hp.eta;
            cfg.label = format!("{}-{}{}x{}{}", proto.label, fixed.0, i, transfer.0, j);
            jobs.push(EngineJob::new(Arc::clone(manifest), Arc::clone(corpus), cfg, vec![]));
        }
    }
    // the grid fills cell by cell as outcomes stream in (each job's
    // submission index encodes its (i, j) position row-major)
    let mut loss = vec![vec![f64::INFINITY; transfer_vals.len()]; fixed_vals.len()];
    let width = transfer_vals.len();
    engine.submit(jobs).drain_strict(|o, _, _| {
        if let Ok(rec) = &o.outcome {
            loss[o.idx / width][o.idx % width] = rec.objective();
        }
    })?;
    Ok(PairGrid {
        fixed_name: fixed.0.to_string(),
        transfer_name: transfer.0.to_string(),
        fixed_vals,
        transfer_vals,
        loss,
    })
}

impl PairGrid {
    /// Render as CSV rows (fixed, transfer, loss).
    pub fn csv_rows(&self) -> Vec<Vec<String>> {
        let mut rows = Vec::new();
        for (i, &f) in self.fixed_vals.iter().enumerate() {
            for (j, &t) in self.transfer_vals.iter().enumerate() {
                rows.push(vec![f.to_string(), t.to_string(), self.loss[i][j].to_string()]);
            }
        }
        rows
    }
}
