//! Independent search (paper §4.5 / Appendix A.6) — the cheap sweep
//! strategy that u-μP's decoupled HPs admit:
//!
//! 1. 1-D line search over the LR with every other HP at its default 1;
//! 2. in parallel, a 1-D line search per non-LR HP (at the phase-1 LR);
//! 3. combine the per-HP argmins and re-evaluate.
//!
//! For μP the combine phase *spikes* (Fig 1a) because its HPs are coupled
//! — the experiment reproduces exactly that contrast.

use std::sync::Arc;

use anyhow::Result;

use crate::data::Corpus;
use crate::engine::{Engine, EngineJob};
use crate::parametrization::HpSet;
use crate::runtime::Manifest;
use crate::train::RunConfig;
use crate::util::stats;

use super::{HpSpace, SweepJob, SweepResult};

/// Submit `jobs` for `manifest`/`corpus` and drain the stream strictly,
/// logging fresh-run completions under a phase label.
fn phase_sweep(
    engine: &Engine,
    manifest: &Arc<Manifest>,
    corpus: &Arc<Corpus>,
    phase: &str,
    jobs: Vec<SweepJob>,
) -> Result<Vec<SweepResult>> {
    let engine_jobs: Vec<EngineJob> = jobs
        .into_iter()
        .map(|j| EngineJob::new(Arc::clone(manifest), Arc::clone(corpus), j.config, j.tag))
        .collect();
    engine.submit(engine_jobs).drain_strict(|o, done, total| {
        if let (Ok(rec), false) = (&o.outcome, o.cached) {
            println!(
                "    {phase} [{done}/{total}] {}: loss {:.4}",
                o.job.config.label,
                rec.objective()
            );
        }
    })
}

#[derive(Debug)]
pub struct IndependentOutcome {
    /// Phase 1: (eta, loss) line.
    pub lr_line: Vec<(f64, f64)>,
    pub best_eta: f64,
    pub best_lr_loss: f64,
    /// Phase 2: per-HP lines: (name, Vec<(value, loss)>).
    pub hp_lines: Vec<(String, Vec<(f64, f64)>)>,
    /// Phase 3: combined HP set and its loss.
    pub combined_hp: HpSet,
    pub combined_loss: f64,
    /// Cumulative run count after each phase (Fig 1a x-axis).
    pub runs_after_phase: [usize; 3],
    pub all_results: Vec<SweepResult>,
}

pub fn independent_search(
    engine: &Engine,
    manifest: &Arc<Manifest>,
    corpus: &Arc<Corpus>,
    space: &HpSpace,
    proto: &RunConfig,
) -> Result<IndependentOutcome> {
    let mut all_results = Vec::new();

    // ---- phase 1: LR line search, everything else at default ----
    let lr_grid = space.lr_range().grid();
    let jobs: Vec<SweepJob> = lr_grid
        .iter()
        .enumerate()
        .map(|(i, &eta)| {
            let mut cfg = proto.clone();
            cfg.hp = HpSet { eta, ..proto.hp };
            cfg.schedule.peak_lr = eta;
            cfg.label = format!("{}-lr{:02}", proto.label, i);
            SweepJob { config: cfg, tag: vec![("eta".into(), eta)] }
        })
        .collect();
    let res = phase_sweep(engine, manifest, corpus, "phase 1 (LR line)", jobs)?;
    let lr_line: Vec<(f64, f64)> =
        res.iter().map(|r| (r.job.tag[0].1, r.record.objective())).collect();
    let best = stats::argmin(&lr_line.iter().map(|p| p.1).collect::<Vec<_>>());
    let best_eta = lr_line[best].0;
    let best_lr_loss = lr_line[best].1;
    let phase1_runs = res.len();
    all_results.extend(res);

    // ---- phase 2: per-HP 1-D lines at the phase-1 LR (parallelizable) ----
    let mut jobs = Vec::new();
    let mut line_specs: Vec<(String, Vec<f64>)> = Vec::new();
    for (name, range) in space.mult_dims() {
        let grid = range.grid();
        for (i, &v) in grid.iter().enumerate() {
            let mut cfg = proto.clone();
            cfg.hp = HpSet { eta: best_eta, ..proto.hp };
            cfg.hp.set(name, v);
            cfg.schedule.peak_lr = best_eta;
            cfg.label = format!("{}-{}{:02}", proto.label, name, i);
            jobs.push(SweepJob {
                config: cfg,
                tag: vec![(name.to_string(), v)],
            });
        }
        line_specs.push((name.to_string(), grid));
    }
    let res = phase_sweep(engine, manifest, corpus, "phase 2 (per-HP lines)", jobs)?;
    let mut hp_lines = Vec::new();
    let mut cursor = 0;
    let mut combined_hp = HpSet { eta: best_eta, ..proto.hp };
    for (name, grid) in &line_specs {
        let line: Vec<(f64, f64)> = grid
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, res[cursor + i].record.objective()))
            .collect();
        let bi = stats::argmin(&line.iter().map(|p| p.1).collect::<Vec<_>>());
        combined_hp.set(name, line[bi].0);
        hp_lines.push((name.clone(), line));
        cursor += grid.len();
    }
    let phase2_runs = phase1_runs + res.len();
    all_results.extend(res);

    // ---- phase 3: combine the argmins and re-evaluate ----
    let mut cfg = proto.clone();
    cfg.hp = combined_hp;
    cfg.schedule.peak_lr = combined_hp.eta;
    cfg.label = format!("{}-combined", proto.label);
    let res = phase_sweep(
        engine,
        manifest,
        corpus,
        "phase 3 (combine)",
        vec![SweepJob { config: cfg, tag: vec![] }],
    )?;
    let combined_loss = res[0].record.objective();
    let phase3_runs = phase2_runs + 1;
    all_results.extend(res);

    Ok(IndependentOutcome {
        lr_line,
        best_eta,
        best_lr_loss,
        hp_lines,
        combined_hp,
        combined_loss,
        runs_after_phase: [phase1_runs, phase2_runs, phase3_runs],
        all_results,
    })
}
