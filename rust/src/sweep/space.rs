//! HP search spaces: per-HP log2 grids (paper Table 5).

use crate::parametrization::Scheme;

/// A log2-uniform search range [2^lo, 2^hi] discretized at `step` in
/// log2 (the paper sweeps LR on a 2^(1/2) grid, §A.7).
#[derive(Debug, Clone, Copy)]
pub struct Range {
    pub log2_lo: f64,
    pub log2_hi: f64,
    pub log2_step: f64,
}

impl Range {
    pub fn new(log2_lo: f64, log2_hi: f64, log2_step: f64) -> Range {
        Range { log2_lo, log2_hi, log2_step }
    }

    pub fn grid(&self) -> Vec<f64> {
        let mut v = Vec::new();
        let mut x = self.log2_lo;
        while x <= self.log2_hi + 1e-9 {
            v.push(2f64.powf(x));
            x += self.log2_step;
        }
        v
    }

    pub fn sample(&self, rng: &mut crate::util::Rng) -> f64 {
        let g = self.grid();
        g[rng.below(g.len())]
    }
}

/// The HP space for one scheme (Table 5 ranges, rescaled to this
/// testbed's proxy by centering the LR range on the observed optimum).
#[derive(Debug, Clone)]
pub struct HpSpace {
    pub scheme: Scheme,
    /// (hp name, range) — "eta" first by convention.
    pub dims: Vec<(&'static str, Range)>,
}

impl HpSpace {
    /// Table 5 search ranges (log2): μP η ∈ [2^-10, 2^-6], multipliers
    /// [2^-2, 2^2]; u-μP η ∈ [2^-1, 2^3] shifted down for this testbed's
    /// smaller batch/seq, multipliers [2^-3, 2^3].
    pub fn table5(scheme: Scheme) -> HpSpace {
        let mults_mup = Range::new(-2.0, 2.0, 1.0);
        let mults_umup = Range::new(-3.0, 3.0, 1.0);
        let dims: Vec<(&'static str, Range)> = match scheme {
            Scheme::Sp => vec![
                ("eta", Range::new(-12.0, -5.0, 0.5)),
                ("sigma_init", Range::new(-2.0, 2.0, 1.0)),
            ],
            Scheme::Mup | Scheme::Intermediate => vec![
                ("eta", Range::new(-11.0, -5.0, 0.5)),
                ("eta_emb_hat", Range::new(0.0, 8.0, 1.0)),
                ("sigma_init", mults_mup),
                ("alpha_emb", mults_mup),
                ("alpha_attn", mults_mup),
                ("alpha_out", mults_mup),
            ],
            Scheme::Umup => vec![
                ("eta", Range::new(-4.0, 2.0, 0.5)),
                ("alpha_attn", Range::new(-2.0, 2.0, 1.0)),
                ("alpha_res", mults_umup),
                ("alpha_res_attn_ratio", mults_umup),
                ("alpha_ffn_act", mults_umup),
                ("alpha_out", mults_umup),
            ],
        };
        HpSpace { scheme, dims }
    }

    pub fn range_of(&self, name: &str) -> Option<Range> {
        self.dims.iter().find(|(n, _)| *n == name).map(|(_, r)| *r)
    }

    pub fn lr_range(&self) -> Range {
        self.range_of("eta").expect("every space has eta")
    }

    /// Non-LR dimensions.
    pub fn mult_dims(&self) -> impl Iterator<Item = &(&'static str, Range)> {
        self.dims.iter().filter(|(n, _)| *n != "eta")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_log_spaced() {
        let r = Range::new(-2.0, 2.0, 1.0);
        assert_eq!(r.grid(), vec![0.25, 0.5, 1.0, 2.0, 4.0]);
        let r = Range::new(-1.0, 0.0, 0.5);
        assert_eq!(r.grid().len(), 3);
    }

    #[test]
    fn spaces_have_eta_first() {
        for s in [Scheme::Sp, Scheme::Mup, Scheme::Umup] {
            let sp = HpSpace::table5(s);
            assert_eq!(sp.dims[0].0, "eta");
            assert!(sp.lr_range().grid().len() >= 8);
        }
    }

    #[test]
    fn sampling_stays_on_grid() {
        let mut rng = crate::util::Rng::new(3);
        let r = Range::new(-3.0, 3.0, 1.0);
        let grid = r.grid();
        for _ in 0..100 {
            let v = r.sample(&mut rng);
            assert!(grid.iter().any(|g| (g - v).abs() < 1e-12));
        }
    }
}
