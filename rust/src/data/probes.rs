//! Downstream probes — the Table 4 substitute (DESIGN.md §4).
//!
//! At this scale 0-shot MMLU/HellaSwag are meaningless, so the probe
//! suite measures the same *claim* (trained u-μP FP8 ≈ BF16 ≈ SP quality
//! parity) with held-out perplexity under distribution shift: each probe
//! is a fresh Zipf–Markov source at increasing distance from the training
//! distribution (same chain, new chain, higher entropy).

use super::{Corpus, CorpusConfig};

#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub name: String,
    pub loss: f64,
    pub perplexity: f64,
}

/// Build the probe corpora: (name, corpus).
pub fn probe_suite(train_cfg: &CorpusConfig, n_tokens: usize) -> Vec<(String, Corpus)> {
    let mk = |name: &str, cfg: CorpusConfig| (name.to_string(), Corpus::generate(cfg));
    vec![
        // in-domain: same chain, fresh walk (the paper's val-loss analogue)
        mk(
            "in-domain",
            CorpusConfig { n_tokens, seed: train_cfg.seed, ..train_cfg.clone() },
        ),
        // near shift: different chain, same statistics (≈ HellaSwag-ish
        // "same skill, new content")
        mk(
            "shifted-chain",
            CorpusConfig { n_tokens, seed: train_cfg.seed + 101, ..train_cfg.clone() },
        ),
        // far shift: flatter, higher-entropy source (tests calibration)
        mk(
            "high-entropy",
            CorpusConfig {
                n_tokens,
                seed: train_cfg.seed + 202,
                zipf_s: 1.05,
                smoothing: 0.35,
                ..train_cfg.clone()
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_ordering() {
        let cfg = CorpusConfig { n_tokens: 50_000, ..Default::default() };
        let suite = probe_suite(&cfg, 50_000);
        assert_eq!(suite.len(), 3);
        // the far-shift probe really is higher entropy
        let h_near = suite[0].1.bigram_entropy();
        let h_far = suite[2].1.bigram_entropy();
        assert!(h_far > h_near, "{h_far} <= {h_near}");
    }
}
