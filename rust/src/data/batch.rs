//! Batch sampling: random (seq+1)-token windows packed row-major for the
//! `tokens: i32[batch, seq+1]` step input.
//!
//! Both samplers have `_into` variants that fill a caller-owned buffer:
//! the training loop issues one sample per step, and at production step
//! counts a fresh `batch * (seq+1)` allocation per step is pure churn —
//! `train::Runner` reuses a single token buffer for the whole run.

use crate::util::Rng;

/// Samples token windows from a slice of a corpus stream.
pub struct BatchSampler<'a> {
    data: &'a [i32],
    batch: usize,
    seq: usize,
    rng: Rng,
    /// Next sequential window index, in `0..n_windows` (see
    /// [`BatchSampler::next_sequential_into`] for the wrap contract).
    next_window: usize,
}

impl<'a> BatchSampler<'a> {
    pub fn new(data: &'a [i32], batch: usize, seq: usize, seed: u64) -> Self {
        assert!(data.len() > seq + 1, "corpus shorter than one window");
        BatchSampler { data, batch, seq, rng: Rng::new(seed).fork("batch"), next_window: 0 }
    }

    /// Random training batch: `batch` windows of seq+1 tokens.
    pub fn sample(&mut self) -> Vec<i32> {
        let mut out = Vec::new();
        self.sample_into(&mut out);
        out
    }

    /// [`BatchSampler::sample`] into a reused buffer (cleared first);
    /// allocation-free once the buffer has reached batch size.
    pub fn sample_into(&mut self, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(self.batch * (self.seq + 1));
        let span = self.data.len() - (self.seq + 1);
        for _ in 0..self.batch {
            let start = self.rng.below(span);
            out.extend_from_slice(&self.data[start..start + self.seq + 1]);
        }
    }

    /// Deterministic sequential batch (validation); wraps around.
    pub fn next_sequential(&mut self) -> Vec<i32> {
        let mut out = Vec::new();
        self.next_sequential_into(&mut out);
        out
    }

    /// [`BatchSampler::next_sequential`] into a reused buffer (cleared
    /// first); allocation-free once the buffer has reached batch size.
    ///
    /// # Wrap contract (exact)
    ///
    /// The stream is tiled into [`BatchSampler::n_windows`] disjoint
    /// full windows `[i*(seq+1), (i+1)*(seq+1))`; rows are emitted in
    /// strict round-robin window order `0, 1, …, n_windows-1, 0, 1, …`
    /// regardless of batch boundaries, so no full window is ever
    /// skipped at the wrap — a batch may *straddle* it (its last rows
    /// continuing from window 0).  The trailing `len % (seq+1)` tokens
    /// do not form a full window and are never sequentially sampled.
    /// When `batch > n_windows`, a single batch revisits windows.
    pub fn next_sequential_into(&mut self, out: &mut Vec<i32>) {
        out.clear();
        let window = self.seq + 1;
        out.reserve(self.batch * window);
        let n_windows = self.n_windows();
        for _ in 0..self.batch {
            if self.next_window >= n_windows {
                self.next_window = 0;
            }
            let start = self.next_window * window;
            out.extend_from_slice(&self.data[start..start + window]);
            self.next_window += 1;
        }
    }

    /// Rewind the sequential cursor to window 0.
    pub fn reset(&mut self) {
        self.next_window = 0;
    }

    /// Disjoint full windows available to the sequential sampler.
    pub fn n_windows(&self) -> usize {
        self.data.len() / (self.seq + 1)
    }

    /// Number of *fully disjoint* sequential batches: the batches a
    /// caller can draw after [`BatchSampler::reset`] before any window
    /// repeats.  The `n_windows % batch` windows beyond them (the
    /// corpus tail) are not lost — the following batch emits them
    /// before wrapping (see [`BatchSampler::next_sequential_into`]).
    pub fn n_sequential_batches(&self) -> usize {
        self.n_windows() / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let data: Vec<i32> = (0..10_000).map(|i| i % 256).collect();
        let mut a = BatchSampler::new(&data, 4, 16, 9);
        let mut b = BatchSampler::new(&data, 4, 16, 9);
        let ba = a.sample();
        assert_eq!(ba.len(), 4 * 17);
        assert_eq!(ba, b.sample());
        // windows are contiguous runs of the underlying stream
        for w in 0..4 {
            let row = &ba[w * 17..(w + 1) * 17];
            for i in 1..17 {
                assert_eq!((row[i] - row[i - 1]).rem_euclid(256), 1);
            }
        }
    }

    #[test]
    fn sample_into_reuses_one_buffer_and_matches_sample() {
        let data: Vec<i32> = (0..10_000).collect();
        let mut a = BatchSampler::new(&data, 4, 16, 9);
        let mut b = BatchSampler::new(&data, 4, 16, 9);
        let mut buf = Vec::new();
        for _ in 0..5 {
            a.sample_into(&mut buf);
            assert_eq!(buf, b.sample());
        }
        let cap = buf.capacity();
        a.sample_into(&mut buf);
        assert_eq!(buf.capacity(), cap, "steady state must not reallocate");
        // sequential variant agrees with its allocating twin too
        let mut c = BatchSampler::new(&data, 4, 16, 0);
        let mut d = BatchSampler::new(&data, 4, 16, 0);
        for _ in 0..5 {
            c.next_sequential_into(&mut buf);
            assert_eq!(buf, d.next_sequential());
        }
    }

    #[test]
    fn sequential_covers_disjoint_windows() {
        let data: Vec<i32> = (0..1000).collect();
        let mut s = BatchSampler::new(&data, 2, 9, 0);
        let b1 = s.next_sequential();
        let b2 = s.next_sequential();
        assert_eq!(b1[0], 0);
        assert_eq!(b1[10], 10); // second row starts at 10
        assert_eq!(b2[0], 20);
        assert_eq!(s.n_sequential_batches(), 1000 / 20);
    }

    /// The wrap is exact: every full window (including the corpus tail
    /// beyond the last disjoint batch) is emitted before any repeats.
    #[test]
    fn sequential_wrap_is_exact_round_robin() {
        // 5 full windows of 10 tokens + a 3-token partial tail
        let data: Vec<i32> = (0..53).collect();
        let mut s = BatchSampler::new(&data, 2, 9, 0);
        assert_eq!(s.n_windows(), 5);
        assert_eq!(s.n_sequential_batches(), 2);
        let starts = |batch: &[i32]| [batch[0], batch[10]];
        // batches tile windows 0,1 | 2,3 | 4,WRAP->0 | 1,2 ...
        assert_eq!(starts(&s.next_sequential()), [0, 10]);
        assert_eq!(starts(&s.next_sequential()), [20, 30]);
        let straddle = s.next_sequential();
        assert_eq!(
            starts(&straddle),
            [40, 0],
            "the tail window must be emitted, then the wrap continues at 0"
        );
        // the tail window's content is the real corpus tail, not a copy
        // of an earlier window
        assert_eq!(&straddle[..10], &data[40..50]);
        assert_eq!(starts(&s.next_sequential()), [10, 20]);
        // the first n_sequential_batches after reset are pairwise
        // disjoint and cover the leading windows exactly once
        s.reset();
        let mut seen = Vec::new();
        for _ in 0..s.n_sequential_batches() {
            seen.extend(starts(&s.next_sequential()));
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 10, 20, 30]);
    }

    /// Boundary shapes: exact multiples, batch larger than the window
    /// count, and reset behavior.
    #[test]
    fn sequential_wrap_boundaries() {
        // exactly 4 windows, batch 2: clean tiling, wrap at batch edge
        let data: Vec<i32> = (0..40).collect();
        let mut s = BatchSampler::new(&data, 2, 9, 0);
        assert_eq!((s.n_windows(), s.n_sequential_batches()), (4, 2));
        assert_eq!(s.next_sequential()[0], 0);
        assert_eq!(s.next_sequential()[0], 20);
        assert_eq!(s.next_sequential()[0], 0, "wrap lands back on window 0");

        // batch exceeds the window count: one batch revisits windows
        let tiny: Vec<i32> = (0..21).collect(); // 2 full windows + tail
        let mut t = BatchSampler::new(&tiny, 3, 9, 0);
        assert_eq!((t.n_windows(), t.n_sequential_batches()), (2, 0));
        let b = t.next_sequential();
        assert_eq!([b[0], b[10], b[20]], [0, 10, 0]);

        // reset rewinds mid-cycle
        let mut r = BatchSampler::new(&data, 2, 9, 0);
        r.next_sequential();
        r.reset();
        assert_eq!(r.next_sequential()[0], 0);
    }
}
