//! Batch sampling: random (seq+1)-token windows packed row-major for the
//! `tokens: i32[batch, seq+1]` step input.

use crate::util::Rng;

/// Samples token windows from a slice of a corpus stream.
pub struct BatchSampler<'a> {
    data: &'a [i32],
    batch: usize,
    seq: usize,
    rng: Rng,
    /// Sequential cursor for deterministic eval batches.
    cursor: usize,
}

impl<'a> BatchSampler<'a> {
    pub fn new(data: &'a [i32], batch: usize, seq: usize, seed: u64) -> Self {
        assert!(data.len() > seq + 1, "corpus shorter than one window");
        BatchSampler { data, batch, seq, rng: Rng::new(seed).fork("batch"), cursor: 0 }
    }

    /// Random training batch: `batch` windows of seq+1 tokens.
    pub fn sample(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * (self.seq + 1));
        let span = self.data.len() - (self.seq + 1);
        for _ in 0..self.batch {
            let start = self.rng.below(span);
            out.extend_from_slice(&self.data[start..start + self.seq + 1]);
        }
        out
    }

    /// Deterministic sequential batch (validation); wraps around.
    pub fn next_sequential(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * (self.seq + 1));
        let window = self.seq + 1;
        for _ in 0..self.batch {
            if self.cursor + window > self.data.len() {
                self.cursor = 0;
            }
            out.extend_from_slice(&self.data[self.cursor..self.cursor + window]);
            self.cursor += window;
        }
        out
    }

    pub fn reset(&mut self) {
        self.cursor = 0;
    }

    /// Number of disjoint sequential batches available.
    pub fn n_sequential_batches(&self) -> usize {
        self.data.len() / ((self.seq + 1) * self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let data: Vec<i32> = (0..10_000).map(|i| i % 256).collect();
        let mut a = BatchSampler::new(&data, 4, 16, 9);
        let mut b = BatchSampler::new(&data, 4, 16, 9);
        let ba = a.sample();
        assert_eq!(ba.len(), 4 * 17);
        assert_eq!(ba, b.sample());
        // windows are contiguous runs of the underlying stream
        for w in 0..4 {
            let row = &ba[w * 17..(w + 1) * 17];
            for i in 1..17 {
                assert_eq!((row[i] - row[i - 1]).rem_euclid(256), 1);
            }
        }
    }

    #[test]
    fn sequential_covers_disjoint_windows() {
        let data: Vec<i32> = (0..1000).collect();
        let mut s = BatchSampler::new(&data, 2, 9, 0);
        let b1 = s.next_sequential();
        let b2 = s.next_sequential();
        assert_eq!(b1[0], 0);
        assert_eq!(b1[10], 10); // second row starts at 10
        assert_eq!(b2[0], 20);
        assert_eq!(s.n_sequential_batches(), 1000 / 20);
    }
}
