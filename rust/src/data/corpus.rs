//! The Zipf–Markov synthetic corpus.
//!
//! Construction: each token `t` gets `k_succ` preferred successors drawn
//! from a Zipfian proposal plus a smoothing floor, forming a sparse
//! Markov transition matrix; the stream is one long chain.  Entropy is
//! tunable via `zipf_s` and `smoothing`: defaults give a unigram entropy
//! of ~5.5 bits and a conditional (bigram) entropy of ~2.6 bits over
//! vocab 256, so cross-entropy curves fall from ~5.5 toward ~1.8 nats —
//! the same qualitative shape as WikiText LM training.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub n_tokens: usize,
    pub seed: u64,
    /// Zipf exponent of the successor-preference proposal.
    pub zipf_s: f64,
    /// Number of preferred successors per token.
    pub k_succ: usize,
    /// Uniform smoothing mass (0..1) mixed into each transition row.
    pub smoothing: f64,
    /// Fraction of the stream reserved for validation (from the end).
    pub valid_frac: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 256,
            n_tokens: 2_000_000,
            seed: 1234,
            zipf_s: 1.2,
            k_succ: 8,
            smoothing: 0.12,
            valid_frac: 0.05,
        }
    }
}

/// A generated token stream with train/valid split.
pub struct Corpus {
    pub config: CorpusConfig,
    pub tokens: Vec<i32>,
    pub n_train: usize,
}

impl Corpus {
    pub fn generate(config: CorpusConfig) -> Corpus {
        let v = config.vocab;
        let mut rng = Rng::new(config.seed).fork("corpus");

        // Zipfian global token ranks (shuffled so ids aren't ordered)
        let mut rank_of: Vec<usize> = (0..v).collect();
        rng.shuffle(&mut rank_of);

        // successor sets: k preferred successors per token, weights Zipf
        let mut succ: Vec<Vec<(usize, f64)>> = Vec::with_capacity(v);
        for _ in 0..v {
            let mut row = Vec::with_capacity(config.k_succ);
            for j in 0..config.k_succ {
                // proposal favours globally-frequent tokens
                let cand = zipf_sample(&mut rng, v, config.zipf_s);
                let tok = rank_of[cand];
                let w = 1.0 / ((j + 1) as f64).powf(config.zipf_s);
                row.push((tok, w));
            }
            let total: f64 = row.iter().map(|(_, w)| w).sum();
            for e in &mut row {
                e.1 /= total;
            }
            succ.push(row);
        }

        // walk the chain
        let mut tokens = Vec::with_capacity(config.n_tokens);
        let mut cur = rank_of[0];
        for _ in 0..config.n_tokens {
            tokens.push(cur as i32);
            let u = rng.f64();
            cur = if u < config.smoothing {
                // smoothing: Zipfian global draw
                rank_of[zipf_sample(&mut rng, v, config.zipf_s)]
            } else {
                let mut acc = 0.0;
                let r = rng.f64();
                let row = &succ[cur];
                let mut pick = row[row.len() - 1].0;
                for &(tok, w) in row {
                    acc += w;
                    if r < acc {
                        pick = tok;
                        break;
                    }
                }
                pick
            };
        }
        let n_train =
            ((config.n_tokens as f64) * (1.0 - config.valid_frac)) as usize;
        Corpus { config, tokens, n_train }
    }

    pub fn train_slice(&self) -> &[i32] {
        &self.tokens[..self.n_train]
    }

    pub fn valid_slice(&self) -> &[i32] {
        &self.tokens[self.n_train..]
    }

    /// Empirical unigram entropy (nats) — the no-context LM bound.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.config.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }

    /// Empirical conditional (bigram) entropy (nats) — the 1-Markov bound
    /// a context-using model can approach.
    pub fn bigram_entropy(&self) -> f64 {
        let v = self.config.vocab;
        let mut counts = vec![0u32; v * v];
        let mut row_tot = vec![0u64; v];
        for w in self.tokens.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1;
            row_tot[w[0] as usize] += 1;
        }
        let n = (self.tokens.len() - 1) as f64;
        let mut h = 0.0;
        for a in 0..v {
            if row_tot[a] == 0 {
                continue;
            }
            let pa = row_tot[a] as f64 / n;
            for b in 0..v {
                let c = counts[a * v + b];
                if c > 0 {
                    let p = c as f64 / row_tot[a] as f64;
                    h -= pa * p * p.ln();
                }
            }
        }
        h
    }
}

/// Zipf(s) rank sampler over [0, n) by inverse-CDF on the harmonic sum.
fn zipf_sample(rng: &mut Rng, n: usize, s: f64) -> usize {
    // precomputing the CDF per call would be wasteful; use rejection-free
    // approximate inverse via the continuous Zipf quantile
    let u = rng.f64().max(1e-12);
    if (s - 1.0).abs() < 1e-9 {
        let h = (n as f64).ln();
        return ((u * h).exp() - 1.0).min((n - 1) as f64) as usize;
    }
    let a = 1.0 - s;
    let h = ((n as f64).powf(a) - 1.0) / a;
    let x = (1.0 + u * h * a).powf(1.0 / a) - 1.0;
    (x as usize).min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Corpus {
        Corpus::generate(CorpusConfig { n_tokens: 200_000, ..Default::default() })
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.tokens[..1000], b.tokens[..1000]);
    }

    #[test]
    fn entropy_gap_is_learnable() {
        let c = small();
        let h1 = c.unigram_entropy();
        let h2 = c.bigram_entropy();
        // context must be worth something: a clear gap between the
        // no-context bound and the Markov bound
        assert!(h1 > h2 + 0.5, "h1={h1} h2={h2}");
        assert!(h2 > 0.5, "degenerate corpus h2={h2}");
        assert!(h1 < (c.config.vocab as f64).ln());
    }

    #[test]
    fn split_sizes() {
        let c = small();
        assert_eq!(c.train_slice().len() + c.valid_slice().len(), 200_000);
        assert!(c.valid_slice().len() >= 9_000);
    }

    #[test]
    fn tokens_in_range() {
        let c = small();
        assert!(c.tokens.iter().all(|&t| (t as usize) < c.config.vocab));
    }
}
