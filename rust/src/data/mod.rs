//! S8 — data substrate: synthetic corpus, batching, downstream probes.
//!
//! WikiText-103 / SlimPajama substitute (DESIGN.md §4): a deterministic
//! Zipf–Markov language source whose unigram distribution is Zipfian and
//! whose bigram structure is a sparse random Markov chain — low enough
//! entropy to be learnable, high enough that loss curves are non-trivial,
//! with exact train/valid splits and an under-fitting regime.

mod batch;
mod corpus;
mod probes;

pub use batch::BatchSampler;
pub use corpus::{Corpus, CorpusConfig};
pub use probes::{probe_suite, ProbeResult};
