//! S1 — Numeric-format substrate.
//!
//! Bit-exact software codecs for the low-precision floating-point formats
//! in the paper's Table 12 (FP8 E4M3FN, FP8 E5M2, FP16, BF16, TF32), plus
//! tensor-statistics tooling (RMS, underflow/overflow fractions) used by
//! the Fig 6/19/20 experiments and format-range overlays.
//!
//! The codec is validated three ways: against IEEE-754 closed forms
//! (unit tests), against itself under property tests (round-trip,
//! monotonicity, idempotence — `tests/` + `util::prop`), and bit-exactly
//! against the L1 Pallas quantizer through the standalone kernel
//! artifacts (`tests/artifact_roundtrip.rs`).

mod codec;
mod stats;
mod tables;

pub use codec::{FloatFormat, Rounding, BF16, E4M3, E5M2, FP16, FP32, TF32};
pub use stats::{ClipStats, TensorStats};
pub use tables::{format_table, format_table_markdown};
