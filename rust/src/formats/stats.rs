//! Tensor statistics: the paper's RMS metric and format-clip accounting.
//!
//! RMS = sqrt(sigma^2 + mu^2) = root-mean-square (Fig 6 caption): it
//! captures the larger of the mean and scale of a distribution and is the
//! paper's test of whether a tensor risks FP8 over/underflow.

use super::FloatFormat;

/// Counts of values that would clip when cast to a format.
#[derive(Debug, Default, Clone, Copy)]
pub struct ClipStats {
    pub overflow: usize,
    pub underflow: usize,
    pub total: usize,
}

impl ClipStats {
    pub fn overflow_frac(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.overflow as f64 / self.total as f64 }
    }
    pub fn underflow_frac(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.underflow as f64 / self.total as f64 }
    }
}

/// Summary statistics of a tensor, in the paper's terms.
#[derive(Debug, Clone, Copy, Default)]
pub struct TensorStats {
    pub rms: f64,
    pub mean: f64,
    pub std: f64,
    pub abs_max: f64,
    pub abs_min_nonzero: f64,
    pub n: usize,
}

impl TensorStats {
    pub fn of(xs: &[f32]) -> Self {
        if xs.is_empty() {
            return Self::default();
        }
        let n = xs.len() as f64;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        let mut abs_max = 0.0f64;
        let mut abs_min = f64::INFINITY;
        for &x in xs {
            let x = x as f64;
            sum += x;
            sumsq += x * x;
            let a = x.abs();
            if a > abs_max {
                abs_max = a;
            }
            if a > 0.0 && a < abs_min {
                abs_min = a;
            }
        }
        let mean = sum / n;
        let var = (sumsq / n - mean * mean).max(0.0);
        TensorStats {
            rms: (sumsq / n).sqrt(),
            mean,
            std: var.sqrt(),
            abs_max,
            abs_min_nonzero: if abs_min.is_finite() { abs_min } else { 0.0 },
            n: xs.len(),
        }
    }

    /// Would this tensor's RMS sit inside `fmt`'s comfortable range?
    /// (within [min_normal, max]; the Fig 6 dashed/solid red lines).
    pub fn rms_in_range(&self, fmt: &FloatFormat) -> bool {
        self.rms >= fmt.min_normal() && self.rms <= fmt.max_value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::E4M3;

    #[test]
    fn rms_is_sqrt_mu2_sigma2() {
        // constant tensor: std = 0, rms = |mu|
        let xs = vec![3.0f32; 100];
        let st = TensorStats::of(&xs);
        assert!((st.rms - 3.0).abs() < 1e-9);
        assert!(st.std < 1e-9);
        // zero-mean: rms = std
        let xs: Vec<f32> = (0..1000).map(|i| if i % 2 == 0 { 2.0 } else { -2.0 }).collect();
        let st = TensorStats::of(&xs);
        assert!((st.rms - 2.0).abs() < 1e-9);
        assert!((st.std - 2.0).abs() < 1e-9);
    }

    #[test]
    fn range_check() {
        let unit = TensorStats { rms: 1.0, ..Default::default() };
        assert!(unit.rms_in_range(&E4M3));
        let tiny = TensorStats { rms: 1e-4, ..Default::default() };
        assert!(!tiny.rms_in_range(&E4M3)); // below E4M3 min normal 2^-6
    }

    #[test]
    fn empty() {
        let st = TensorStats::of(&[]);
        assert_eq!(st.n, 0);
        assert_eq!(st.rms, 0.0);
    }
}
