//! Software float codecs: round f32 values onto the representable grid of
//! a narrower binary format (round-to-nearest-even, saturating).
//!
//! Semantics match `torch._scaled_mm` / the paper's `.to(float8)` cast:
//! * E4M3 is the *FN* (finite-only) variant: no infinities, the all-ones
//!   exponent carries normal values, max = 448, and overflow saturates.
//! * E5M2 keeps the IEEE layout (max 57344) but the cast saturates rather
//!   than producing inf (matching saturated-cast FP8 training).
//! * Subnormals are exact: the grid below `min_normal` is the fixed-point
//!   lattice with spacing `min_subnormal`.
//!
//! The implementation quantizes through the f32 bit pattern, so it is
//! exact for every input (no libm), mirroring the L1 Pallas kernel.

/// A binary floating-point format (1 sign bit + exponent + mantissa bits).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FloatFormat {
    pub name: &'static str,
    pub exp_bits: u32,
    pub mant_bits: u32,
    /// E4M3FN-style: all-ones exponent is used for normal numbers
    /// (no inf; one mantissa pattern reserved for NaN).
    pub finite_only: bool,
    /// Relative FLOPS vs TF32 on recent accelerators (paper Table 12).
    pub rel_flops: f64,
}

/// Rounding mode for [`FloatFormat::quantize`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// Round to nearest, ties to even (the hardware default).
    NearestEven,
    /// Truncate toward zero (used by ablation benches only).
    TowardZero,
}

pub const E4M3: FloatFormat =
    FloatFormat { name: "FP8 E4M3", exp_bits: 4, mant_bits: 3, finite_only: true, rel_flops: 4.0 };
pub const E5M2: FloatFormat =
    FloatFormat { name: "FP8 E5M2", exp_bits: 5, mant_bits: 2, finite_only: false, rel_flops: 4.0 };
pub const FP16: FloatFormat =
    FloatFormat { name: "FP16", exp_bits: 5, mant_bits: 10, finite_only: false, rel_flops: 2.0 };
pub const BF16: FloatFormat =
    FloatFormat { name: "BF16", exp_bits: 8, mant_bits: 7, finite_only: false, rel_flops: 2.0 };
pub const TF32: FloatFormat =
    FloatFormat { name: "TF32", exp_bits: 8, mant_bits: 10, finite_only: false, rel_flops: 1.0 };
/// f32 itself, as the identity codec (useful as a baseline in benches).
pub const FP32: FloatFormat =
    FloatFormat { name: "FP32", exp_bits: 8, mant_bits: 23, finite_only: false, rel_flops: 0.5 };

impl FloatFormat {
    pub const ALL: [FloatFormat; 6] = [FP32, TF32, BF16, FP16, E5M2, E4M3];

    pub fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Exponent of the smallest normal number.
    pub fn min_normal_exp(&self) -> i32 {
        1 - self.bias()
    }

    /// Exponent of the largest finite number.
    pub fn max_exp(&self) -> i32 {
        let all_ones = (1i32 << self.exp_bits) - 1;
        all_ones - self.bias() - if self.finite_only { 0 } else { 1 }
    }

    /// Largest finite value (448 for E4M3FN, 57344 for E5M2, ...).
    pub fn max_value(&self) -> f64 {
        let m = self.mant_bits as f64;
        let frac = if self.finite_only {
            2.0 - 2.0 * 0.5f64.powf(m) // top mantissa pattern is NaN
        } else {
            2.0 - 0.5f64.powf(m)
        };
        frac * 2.0f64.powi(self.max_exp())
    }

    /// Smallest positive normal value.
    pub fn min_normal(&self) -> f64 {
        2.0f64.powi(self.min_normal_exp())
    }

    /// Smallest positive subnormal value.
    pub fn min_subnormal(&self) -> f64 {
        2.0f64.powi(self.min_normal_exp() - self.mant_bits as i32)
    }

    /// log2 of the dynamic range max/min_subnormal (format "width" used
    /// in the Fig 6 range overlays).
    pub fn log2_dynamic_range(&self) -> f64 {
        (self.max_value() / self.min_subnormal()).log2()
    }

    /// Round one f32 onto this format's grid (saturating RTNE cast).
    ///
    /// NaN propagates; ±0 is preserved. Values below half the smallest
    /// subnormal round to (signed) zero.
    pub fn quantize(&self, x: f32) -> f32 {
        self.quantize_mode(x, Rounding::NearestEven)
    }

    pub fn quantize_mode(&self, x: f32, mode: Rounding) -> f32 {
        quantize_one(x, self.min_normal_exp(), self.mant_bits as i32, self.max_value(), self.identity(), mode)
    }

    #[inline]
    fn identity(&self) -> bool {
        self.mant_bits >= 23 && self.min_normal_exp() <= -126
    }

    /// Quantize a slice in place; returns clip statistics.
    ///
    /// §Perf: the format constants (max value, min-normal exponent, grid
    /// width) are hoisted out of the per-element loop — the naive
    /// per-element `quantize` recomputed `max_value()` (a powf) every
    /// call, which dominated the codec bench (~25 M elem/s before,
    /// see EXPERIMENTS.md §Perf for after).
    pub fn quantize_slice(&self, xs: &mut [f32]) -> super::ClipStats {
        let mut stats = super::ClipStats::default();
        let max_v = self.max_value();
        let max = max_v as f32;
        let min_sub = self.min_subnormal() as f32;
        let mne = self.min_normal_exp();
        let mant = self.mant_bits as i32;
        let ident = self.identity();
        for x in xs.iter_mut() {
            let v = *x;
            if v.is_finite() && v != 0.0 {
                if v.abs() > max {
                    stats.overflow += 1;
                } else if v.abs() < 0.5 * min_sub {
                    stats.underflow += 1;
                }
                stats.total += 1;
            }
            *x = quantize_one(v, mne, mant, max_v, ident, Rounding::NearestEven);
        }
        stats
    }

    /// Number of finite non-negative grid points (used by property tests).
    pub fn grid_points_per_octave(&self) -> u32 {
        1 << self.mant_bits
    }
}

/// Exact power of two from an integer exponent (valid for normal-f64
/// exponents, i.e. -1022..=1023 — every grid we use is inside).
#[inline]
fn pow2_f64(e: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// One quantization with pre-hoisted format constants.
#[inline]
fn quantize_one(
    x: f32,
    min_normal_exp: i32,
    mant_bits: i32,
    max_value: f64,
    identity: bool,
    mode: Rounding,
) -> f32 {
    if x.is_nan() || x == 0.0 || identity {
        return x;
    }
    let ax = x.abs();
    // Exact exponent from the bit pattern (subnormal f32 inputs report
    // -127 here and clamp up, which is correct: they are far below any
    // target format's grid spacing).
    let bits = ax.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let exp = exp.max(min_normal_exp);
    let ulp_exp = exp - mant_bits;
    // q = round(x / 2^ulp_exp) * 2^ulp_exp, both steps exact in f64.
    let scaled = x as f64 * pow2_f64(-ulp_exp);
    let r = match mode {
        Rounding::NearestEven => round_ties_even(scaled),
        Rounding::TowardZero => scaled.trunc(),
    };
    let q = r * pow2_f64(ulp_exp);
    q.clamp(-max_value, max_value) as f32
}

/// f64 round-half-to-even (stable Rust's `f64::round` rounds half away
/// from zero, which is NOT what cast hardware does).
fn round_ties_even(x: f64) -> f64 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let lo = x.trunc();
        let hi = lo + x.signum();
        if (lo as i64) % 2 == 0 {
            lo
        } else {
            hi
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table12_closed_forms() {
        // paper Table 12
        assert_eq!(E4M3.max_value(), 448.0);
        assert_eq!(E5M2.max_value(), 57344.0);
        assert_eq!(FP16.max_value(), 65504.0);
        assert!((E5M2.min_normal() - 6.1e-5).abs() / 6.1e-5 < 2e-3);
        assert!((E4M3.min_normal() - 1.5625e-2).abs() < 1e-12);
        assert_eq!(E4M3.min_subnormal(), 2.0f64.powi(-9));
        assert_eq!(E5M2.min_subnormal(), 2.0f64.powi(-16));
        assert_eq!(FP16.min_subnormal(), 2.0f64.powi(-24));
    }

    #[test]
    fn e4m3_exact_values() {
        assert_eq!(E4M3.quantize(448.0), 448.0);
        assert_eq!(E4M3.quantize(1e9), 448.0); // saturates
        assert_eq!(E4M3.quantize(-1e9), -448.0);
        assert_eq!(E4M3.quantize(1.0), 1.0);
        assert_eq!(E4M3.quantize(1.0625), 1.0); // RTNE tie -> even (8/8ths)
        assert_eq!(E4M3.quantize(1.1), 1.125);
        assert_eq!(E4M3.quantize(2f32.powi(-9)), 2f32.powi(-9)); // min subnormal
        assert_eq!(E4M3.quantize(2f32.powi(-11)), 0.0); // below half min-sub
        assert_eq!(E4M3.quantize(0.75 * 2f32.powi(-9)), 2f32.powi(-9));
    }

    #[test]
    fn ties_to_even() {
        // halfway between grid points 1.0 and 1.125 is 1.0625 -> 1.0 (even)
        assert_eq!(E4M3.quantize(1.0625), 1.0);
        // halfway between 1.125 and 1.25 is 1.1875 -> 1.25? mantissa of
        // 1.125 is 0b001 (odd), of 1.25 is 0b010 (even) -> 1.25
        assert_eq!(E4M3.quantize(1.1875), 1.25);
    }

    #[test]
    fn zero_and_nan() {
        assert_eq!(E5M2.quantize(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(E5M2.quantize(-0.0).to_bits(), (-0.0f32).to_bits());
        assert!(E5M2.quantize(f32::NAN).is_nan());
    }

    #[test]
    fn fp32_is_identity() {
        for v in [1.0e-40f32, 3.14159, -1e30, 123.456] {
            assert_eq!(FP32.quantize(v), v);
        }
    }

    #[test]
    fn clip_stats() {
        let mut xs = vec![1.0f32, 1000.0, 1e-6, -0.5];
        let st = E4M3.quantize_slice(&mut xs);
        assert_eq!(st.overflow, 1);
        assert_eq!(st.underflow, 1);
        assert_eq!(xs[1], 448.0);
        assert_eq!(xs[2], 0.0);
    }
}
