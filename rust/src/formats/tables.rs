//! Table 12 generator: the deep-learning format comparison table,
//! computed from the codecs (not hard-coded), so the unit tests that pin
//! the paper's numbers genuinely exercise the substrate.

use super::FloatFormat;

/// One row of the paper's Table 12.
#[derive(Debug, Clone)]
pub struct FormatRow {
    pub name: &'static str,
    pub e: u32,
    pub m: u32,
    pub max: f64,
    pub min_normal: f64,
    pub min_subnormal: f64,
    pub rel_flops: f64,
}

pub fn format_table() -> Vec<FormatRow> {
    FloatFormat::ALL
        .iter()
        .map(|f| FormatRow {
            name: f.name,
            e: f.exp_bits,
            m: f.mant_bits,
            max: f.max_value(),
            min_normal: f.min_normal(),
            min_subnormal: f.min_subnormal(),
            rel_flops: f.rel_flops,
        })
        .collect()
}

pub fn format_table_markdown() -> String {
    let mut s = String::from(
        "| Format | E | M | max | min normal | min subnormal | FLOPS (vs TF32) |\n|---|---|---|---|---|---|---|\n",
    );
    for r in format_table() {
        s.push_str(&format!(
            "| {} | {} | {} | {:.4e} | {:.4e} | {:.4e} | {}x |\n",
            r.name, r.e, r.m, r.max, r.min_normal, r.min_subnormal, r.rel_flops
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the paper's Table 12 numbers.
    #[test]
    fn matches_paper_table12() {
        let rows = format_table();
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap().clone();
        let fp16 = get("FP16");
        assert_eq!(fp16.max, 65504.0);
        assert!((fp16.min_normal - 6.1e-5).abs() / 6.1e-5 < 2e-3);
        assert!((fp16.min_subnormal - 6.0e-8).abs() / 6.0e-8 < 1e-2);
        let e5 = get("FP8 E5M2");
        assert_eq!(e5.max, 57344.0);
        assert!((e5.min_subnormal - 1.5e-5).abs() / 1.5e-5 < 2e-2);
        let e4 = get("FP8 E4M3");
        assert_eq!(e4.max, 448.0);
        assert!((e4.min_normal - 1.6e-2).abs() / 1.6e-2 < 3e-2);
        assert!((e4.min_subnormal - 2.0e-3).abs() / 2.0e-3 < 3e-2);
        let bf16 = get("BF16");
        assert!((bf16.max - 3.4e38).abs() / 3.4e38 < 2e-2);
    }

    #[test]
    fn markdown_renders() {
        let md = format_table_markdown();
        assert!(md.contains("FP8 E4M3"));
        assert_eq!(md.lines().count(), 2 + FloatFormat::ALL.len());
    }
}
