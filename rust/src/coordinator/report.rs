//! Report rendering: each experiment emits a markdown fragment with its
//! measured numbers, CSVs, and an ASCII rendering of the figure shape.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::util::plot::{ascii_plot, write_csv, Series};

pub struct Report {
    pub id: String,
    md: String,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        let mut md = String::new();
        let _ = writeln!(md, "## {id} — {title}\n");
        Report { id: id.to_string(), md }
    }

    pub fn para(&mut self, text: &str) {
        let _ = writeln!(self.md, "{text}\n");
    }

    pub fn kv(&mut self, key: &str, value: impl std::fmt::Display) {
        let _ = writeln!(self.md, "- **{key}**: {value}");
    }

    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        let _ = writeln!(self.md, "\n| {} |", header.join(" | "));
        let _ = writeln!(self.md, "|{}|", vec!["---"; header.len()].join("|"));
        for r in rows {
            let _ = writeln!(self.md, "| {} |", r.join(" | "));
        }
        let _ = writeln!(self.md);
    }

    /// Attach series: writes the CSV next to the report and inlines an
    /// ASCII plot of the figure shape.
    pub fn figure(&mut self, dir: &Path, name: &str, series: &[Series], log_x: bool) -> Result<()> {
        write_csv(&dir.join(format!("{name}.csv")), series)?;
        let _ = writeln!(self.md, "`{name}.csv`\n");
        let _ = writeln!(self.md, "```\n{}```\n", ascii_plot(series, 68, 14, log_x));
        Ok(())
    }

    pub fn finish(self, dir: &Path) -> Result<String> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("summary.md"), &self.md)?;
        Ok(self.md)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut r = Report::new("figX", "test");
        r.kv("metric", 1.25);
        r.table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        let dir = std::env::temp_dir().join("umup_report_test");
        let md = r.finish(&dir).unwrap();
        assert!(md.contains("## figX"));
        assert!(md.contains("| a | b |"));
        assert!(dir.join("summary.md").exists());
    }
}
