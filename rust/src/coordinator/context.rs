//! Shared experiment context: artifact registry, the unified run engine,
//! corpus cache, output directory, and the quick/full switch.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::data::{Corpus, CorpusConfig};
use crate::engine::{Backend, Engine, EngineConfig, EventBus, Shard};
use crate::runtime::Registry;

pub struct ExpContext {
    pub registry: Arc<Registry>,
    /// The unified run engine: multi-manifest job queue, per-worker
    /// session pools, content-addressed run cache.  All experiment
    /// execution routes through it.
    pub engine: Engine,
    pub out_dir: PathBuf,
    /// Reduced steps/grids — used by integration tests and smoke runs.
    pub quick: bool,
    pub seed: u64,
    corpora: Mutex<HashMap<usize, Arc<Corpus>>>,
}

impl ExpContext {
    pub fn new(artifacts: &str, out_dir: &str, quick: bool, workers: usize) -> Result<Self> {
        Self::with_cache(artifacts, out_dir, quick, workers, None, false, None)
    }

    /// Like [`ExpContext::new`] with run-cache persistence: `cache_dir`
    /// records completed runs as lock-safe JSONL segments; `resume`
    /// additionally merges in what previous (possibly interrupted or
    /// sharded) sweeps completed, so re-running an experiment skips
    /// those jobs.  With `shard` set (`--shard i/n`), this process
    /// executes only its deterministic slice of each sweep and records
    /// it to its own `runs.<i>.jsonl` segment — N such processes over
    /// one shared `cache_dir` drain one experiment concurrently.
    pub fn with_cache(
        artifacts: &str,
        out_dir: &str,
        quick: bool,
        workers: usize,
        cache_dir: Option<PathBuf>,
        resume: bool,
        shard: Option<Shard>,
    ) -> Result<Self> {
        Self::with_backend(artifacts, out_dir, quick, workers, cache_dir, resume, shard, None, None)
    }

    /// Like [`ExpContext::with_cache`] over an explicit execution
    /// backend (`--backend process|mock`); `None` uses the default
    /// in-process XLA backend.  `events` is the engine's telemetry
    /// publisher (`--progress` / the TUI); `None` keeps the engine's
    /// bus inert.
    #[allow(clippy::too_many_arguments)] // mirrors the CLI surface 1:1
    pub fn with_backend(
        artifacts: &str,
        out_dir: &str,
        quick: bool,
        workers: usize,
        cache_dir: Option<PathBuf>,
        resume: bool,
        shard: Option<Shard>,
        backend: Option<Arc<dyn Backend>>,
        events: Option<EventBus>,
    ) -> Result<Self> {
        let registry = Arc::new(Registry::open(Path::new(artifacts))?);
        let engine_cfg = EngineConfig {
            workers,
            cache_dir,
            resume,
            shard,
            events,
            ..EngineConfig::default()
        };
        let engine = match backend {
            Some(b) => Engine::with_backend(engine_cfg, b)?,
            None => Engine::new(engine_cfg)?,
        };
        Ok(ExpContext {
            registry,
            engine,
            out_dir: PathBuf::from(out_dir),
            quick,
            seed: 1234,
            corpora: Mutex::new(HashMap::new()),
        })
    }

    /// Corpus for a vocab size, generated once per process and shared
    /// with the engine's worker threads (a handful of corpora per
    /// process; bounded).
    pub fn corpus(&self, vocab: usize) -> Arc<Corpus> {
        let mut map = self.corpora.lock().unwrap();
        if let Some(c) = map.get(&vocab) {
            return Arc::clone(c);
        }
        let n_tokens = if self.quick { 200_000 } else { 2_000_000 };
        let c = Arc::new(Corpus::generate(CorpusConfig {
            vocab,
            n_tokens,
            seed: self.seed,
            ..Default::default()
        }));
        map.insert(vocab, Arc::clone(&c));
        c
    }

    /// A *shrunken* corpus emulating the TP5 overfitting regime (Fig 2a).
    pub fn tiny_corpus(&self, vocab: usize, fraction: f64) -> Arc<Corpus> {
        let n_tokens = ((if self.quick { 200_000.0 } else { 2_000_000.0 }) * fraction) as usize;
        Arc::new(Corpus::generate(CorpusConfig {
            vocab,
            n_tokens: n_tokens.max(20_000),
            seed: self.seed,
            ..Default::default()
        }))
    }

    /// Steps for a standard run, honoring quick mode and the
    /// UMUP_STEP_SCALE env knob (single-core testbeds set e.g. 0.5).
    pub fn steps(&self, full: u64) -> u64 {
        if self.quick {
            return (full / 10).max(8);
        }
        let scale: f64 = std::env::var("UMUP_STEP_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        ((full as f64 * scale) as u64).max(16)
    }

    pub fn exp_dir(&self, id: &str) -> PathBuf {
        let d = self.out_dir.join(id);
        let _ = std::fs::create_dir_all(&d);
        d
    }
}
