//! Shared experiment context: artifact registry, corpus cache, output
//! directory, and the quick/full switch.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::Result;

use crate::data::{Corpus, CorpusConfig};
use crate::runtime::Registry;

pub struct ExpContext {
    pub registry: Registry,
    pub out_dir: PathBuf,
    /// Reduced steps/grids — used by integration tests and smoke runs.
    pub quick: bool,
    pub workers: usize,
    pub seed: u64,
    corpora: Mutex<HashMap<usize, &'static Corpus>>,
}

impl ExpContext {
    pub fn new(artifacts: &str, out_dir: &str, quick: bool, workers: usize) -> Result<Self> {
        Ok(ExpContext {
            registry: Registry::open(std::path::Path::new(artifacts))?,
            out_dir: PathBuf::from(out_dir),
            quick,
            workers,
            seed: 1234,
            corpora: Mutex::new(HashMap::new()),
        })
    }

    /// Corpus for a vocab size, generated once and leaked for 'static
    /// borrows across scoped worker threads (a handful of corpora per
    /// process; bounded).
    pub fn corpus(&self, vocab: usize) -> &'static Corpus {
        let mut map = self.corpora.lock().unwrap();
        if let Some(c) = map.get(&vocab) {
            return c;
        }
        let n_tokens = if self.quick { 200_000 } else { 2_000_000 };
        let c = Box::leak(Box::new(Corpus::generate(CorpusConfig {
            vocab,
            n_tokens,
            seed: self.seed,
            ..Default::default()
        })));
        map.insert(vocab, c);
        c
    }

    /// A *shrunken* corpus emulating the TP5 overfitting regime (Fig 2a).
    pub fn tiny_corpus(&self, vocab: usize, fraction: f64) -> Corpus {
        let n_tokens = ((if self.quick { 200_000.0 } else { 2_000_000.0 }) * fraction) as usize;
        Corpus::generate(CorpusConfig {
            vocab,
            n_tokens: n_tokens.max(20_000),
            seed: self.seed,
            ..Default::default()
        })
    }

    /// Steps for a standard run, honoring quick mode and the
    /// UMUP_STEP_SCALE env knob (single-core testbeds set e.g. 0.5).
    pub fn steps(&self, full: u64) -> u64 {
        if self.quick {
            return (full / 10).max(8);
        }
        let scale: f64 = std::env::var("UMUP_STEP_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        ((full as f64 * scale) as u64).max(16)
    }

    pub fn exp_dir(&self, id: &str) -> PathBuf {
        let d = self.out_dir.join(id);
        let _ = std::fs::create_dir_all(&d);
        d
    }
}
