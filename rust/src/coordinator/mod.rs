//! S11 — experiment coordinator: one registered experiment per paper
//! figure/table, a context carrying the registry/corpus/output dir, and
//! report rendering into `results/` + EXPERIMENTS.md fragments.

mod context;
mod experiments;
mod report;

pub use context::ExpContext;
pub use experiments::{list_experiments, run_experiment};
pub use report::Report;
