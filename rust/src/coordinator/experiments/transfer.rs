//! Transfer experiments: Fig 1(b), Fig 3, Fig 5, Fig 17.

use anyhow::Result;

use crate::coordinator::{ExpContext, Report};
use crate::parametrization::{EmbLrRule, Scheme};
use crate::sweep::SweepJob;
use crate::util::plot::Series;

use super::helpers::*;

/// Fig 1(b): LR transfer across width. μP's optimum drifts and its loss
/// plateaus with width; u-μP's optimum is flat and keeps improving.
pub fn fig1b(ctx: &ExpContext) -> Result<String> {
    // width 256 is exercised by examples/e2e_train + fig7; the sweep here
    // caps at 128 to fit the single-core testbed budget (DESIGN.md §4)
    let widths: &[usize] = if ctx.quick { &[32, 64] } else { &[32, 64, 128] };
    let mut report = Report::new("fig1b", "learning-rate transfer across width");
    let dir = ctx.exp_dir("fig1b");
    let mut rows = Vec::new();
    for scheme in [Scheme::Mup, Scheme::Umup] {
        let mut series: Vec<Series> = Vec::new();
        let mut opt_by_width = Vec::new();
        for &w in widths {
            let man = ctx.registry.find(w, 4, 16)?;
            let corpus = ctx.corpus(man.spec.vocab);
            let p = proto(ctx, scheme, 256);
            let line = lr_line(ctx, &man, &corpus, &p, &lr_grid(scheme, false))?;
            series.push(to_series(format!("{} w{}", scheme.name(), w), &line));
            match best_point(&line) {
                Some((opt_lr, opt_loss)) => {
                    opt_by_width.push((w, opt_lr, opt_loss));
                    rows.push(vec![
                        scheme.name().into(),
                        w.to_string(),
                        format!("{:.4}", opt_lr.log2()),
                        format!("{opt_loss:.4}"),
                    ]);
                }
                // every point diverged/cancelled: report it, don't panic
                None => rows.push(vec![
                    scheme.name().into(),
                    w.to_string(),
                    "(all diverged)".into(),
                    "-".into(),
                ]),
            }
        }
        report.figure(&dir, &format!("lr_vs_loss_{}", scheme.name()), &series, true)?;
        // transfer quality: log2 drift of the optimum from proxy to target
        let drift_label = format!(
            "{} optimum drift (|log2|, w{}→w{})",
            scheme.name(),
            widths[0],
            widths[widths.len() - 1]
        );
        match (opt_by_width.first(), opt_by_width.last()) {
            (Some(&(_, first_lr, _)), Some(&(_, last_lr, _))) => {
                let drift = (last_lr / first_lr).log2().abs();
                report.kv(&drift_label, format!("{drift:.2}"));
            }
            _ => report.kv(&drift_label, "n/a (no width produced a finite optimum)".to_string()),
        }
    }
    report.table(&["scheme", "width", "log2 opt LR", "best loss"], &rows);
    report.para(
        "Paper claim: u-μP's optimal LR is constant across width while μP drifts, \
         and u-μP reaches equal-or-lower loss at the largest width.",
    );
    report.finish(&dir)
}

/// Fig 3: the embedding LR rule. Constant c_emb vs 1/sqrt(fan-out):
/// sweeping the global LR under both rules across widths, the sqrt rule
/// keeps improving with width while constant saturates.
pub fn fig3(ctx: &ExpContext) -> Result<String> {
    let widths: &[usize] = if ctx.quick { &[32, 64] } else { &[32, 64, 128] };
    let mut report = Report::new("fig3", "embedding LR rule (constant vs 1/sqrt(fan-out))");
    let dir = ctx.exp_dir("fig3");
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (rule, label) in [
        (EmbLrRule::Constant, "c_emb = 1"),
        (EmbLrRule::InvSqrtFanOut, "c_emb = 1/sqrt(fan-out)"),
    ] {
        let mut s = Series::new(label);
        for &w in widths {
            let man = ctx.registry.find(w, 4, 16)?;
            let corpus = ctx.corpus(man.spec.vocab);
            let mut p = proto(ctx, Scheme::Umup, 256);
            p.parametrization.emb_lr_rule = rule;
            let line = lr_line(ctx, &man, &corpus, &p, &lr_grid(Scheme::Umup, false))?;
            match best_point(&line) {
                Some((opt_lr, opt_loss)) => {
                    s.push(w as f64, opt_loss);
                    rows.push(vec![
                        label.into(),
                        w.to_string(),
                        format!("{:.2}", opt_lr.log2()),
                        format!("{opt_loss:.4}"),
                    ]);
                }
                None => rows.push(vec![
                    label.into(),
                    w.to_string(),
                    "(all diverged)".into(),
                    "-".into(),
                ]),
            }
        }
        series.push(s);
    }
    report.figure(&dir, "best_loss_vs_width", &series, true)?;
    report.table(&["rule", "width", "log2 opt LR", "best loss"], &rows);
    report.para("Paper claim (Fig 3 right): the sqrt rule attains lower loss at large width.");
    report.finish(&dir)
}

/// Fig 5: LR transfer over training steps, batch size, depth.
pub fn fig5(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig5", "LR transfer over steps / batch size / depth");
    let dir = ctx.exp_dir("fig5");
    let steps_axis: &[u64] = if ctx.quick { &[64, 128] } else { &[128, 384] };
    let batch_axis: &[usize] = &[8, 32];
    let depth_axis: &[usize] = &[2, 8];

    for scheme in [Scheme::Mup, Scheme::Umup] {
        // --- steps ---
        let mut series = Vec::new();
        for &steps in steps_axis {
            let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
            let corpus = ctx.corpus(man.spec.vocab);
            let mut p = proto(ctx, scheme, steps);
            p.schedule.warmup_steps = (ctx.steps(steps) / 4).max(1); // fixed fraction
            let line = lr_line(ctx, &man, &corpus, &p, &lr_grid(scheme, false))?;
            series.push(to_series(format!("steps {steps}"), &line));
        }
        report.figure(&dir, &format!("steps_{}", scheme.name()), &series, true)?;

        // --- batch size ---
        let mut series = Vec::new();
        for &b in batch_axis {
            let man = ctx.registry.find(PROXY_WIDTH, 4, b)?;
            let corpus = ctx.corpus(man.spec.vocab);
            let p = proto(ctx, scheme, 256);
            let line = lr_line(ctx, &man, &corpus, &p, &lr_grid(scheme, false))?;
            series.push(to_series(format!("batch {b}"), &line));
        }
        report.figure(&dir, &format!("batch_{}", scheme.name()), &series, true)?;

        // --- depth ---
        let mut series = Vec::new();
        for &d in depth_axis {
            let man = ctx.registry.find(PROXY_WIDTH, d, 16)?;
            let corpus = ctx.corpus(man.spec.vocab);
            let p = proto(ctx, scheme, 256);
            let line = lr_line(ctx, &man, &corpus, &p, &lr_grid(scheme, false))?;
            series.push(to_series(format!("depth {d}"), &line));
        }
        report.figure(&dir, &format!("depth_{}", scheme.name()), &series, true)?;
    }
    report.para(
        "Paper claim: optimal LR approximately constant over steps and batch for \
         u-μP, least stable over depth; μP basins shallower/drifting.",
    );
    report.finish(&dir)
}

/// Fig 17: transfer of non-LR HPs across width (μP's η̂_emb and σ_init
/// transfer poorly; u-μP's α HPs have ~constant optima).
pub fn fig17(ctx: &ExpContext) -> Result<String> {
    let widths: &[usize] = if ctx.quick { &[32, 64] } else { &[32, 64, 128] };
    let mut report = Report::new("fig17", "non-LR HP transfer across width");
    let dir = ctx.exp_dir("fig17");
    let grid: Vec<f64> = (-2..=2).map(|e| 2f64.powi(e)).collect();
    // fixed near-optimal eta per scheme (from fig1b proxy sweeps)
    let cases = [
        (Scheme::Mup, 2f64.powf(-8.0), vec!["sigma_init", "eta_emb_hat", "alpha_attn"]),
        (Scheme::Umup, 2f64.powf(-1.0), vec!["alpha_attn", "alpha_res", "alpha_ffn_act"]),
    ];
    for (scheme, eta, hps) in cases {
        for hp_name in hps {
            let mut series = Vec::new();
            for &w in widths {
                let man = ctx.registry.find(w, 4, 16)?;
                let corpus = ctx.corpus(man.spec.vocab);
                let p0 = proto(ctx, scheme, 192);
                let jobs: Vec<SweepJob> = grid
                    .iter()
                    .map(|&v| {
                        let mut cfg = p0.clone();
                        cfg.hp.eta = eta;
                        cfg.schedule.peak_lr = eta;
                        cfg.hp.set(hp_name, v);
                        cfg.label = format!("{}-{hp_name}-{v}", scheme.name());
                        SweepJob { config: cfg, tag: vec![(hp_name.into(), v)] }
                    })
                    .collect();
                // stream: points land as workers finish, and a diverged
                // multiplier tail is cancelled instead of trained
                let line = hp_line(ctx, &man, &corpus, jobs)?;
                series.push(to_series(format!("w{w}"), &line));
            }
            report.figure(&dir, &format!("{}_{hp_name}", scheme.name()), &series, true)?;
        }
    }
    report.para("Paper claim: u-μP optima stay ≈1 across width; μP's η̂_emb/σ_init drift.");
    report.finish(&dir)
}
