//! Experiment registry: one entry per reproduced figure/table
//! (DESIGN.md §5 maps each to the paper).

use anyhow::{bail, Result};

use super::ExpContext;

mod precision;
mod search;
mod stability;
mod transfer;

pub(crate) mod helpers;

/// (id, paper artifact, description)
pub const EXPERIMENTS: &[(&str, &str, &str)] = &[
    ("fig1a", "Figure 1(a)", "random vs independent HP search efficiency"),
    ("fig1b", "Figure 1(b)", "LR transfer across width, muP vs u-muP"),
    ("fig1c", "Figure 1(c)", "out-of-the-box FP8 cast training"),
    ("fig2", "Figure 2", "muTransfer across training setups + stability fixes"),
    ("fig3", "Figure 3", "embedding LR rule: constant vs 1/sqrt(fan-out)"),
    ("fig4", "Figure 4 (+14/15)", "HP interdependence: pair grids + transfer error"),
    ("fig5", "Figure 5", "LR transfer over steps / batch size / depth"),
    ("fig6", "Figure 6", "per-tensor RMS at init and end vs FP8 ranges"),
    ("fig7", "Figure 7 + Table 4", "larger-scale: u-muP FP8 vs BF16 vs SP + probes"),
    ("fig13", "Figure 13", "per-tensor LR multipliers around the global optimum"),
    ("fig17", "Figure 17", "non-LR HP transfer across width"),
    ("fig19", "Figure 19", "RMS during training for matmul inputs"),
    ("fig20", "Figure 20", "end-RMS of critical tensors vs LR/width/depth/steps/batch"),
    ("fig25", "Figure 25 / App. L", "attention-output RMS growth with depth at init"),
    ("tab12", "Table 12", "number-format table from the Rust codecs"),
];

pub fn list_experiments() -> String {
    let mut s = String::from("id       paper artifact        description\n");
    for (id, art, desc) in EXPERIMENTS {
        s.push_str(&format!("{id:8} {art:22} {desc}\n"));
    }
    s
}

pub fn run_experiment(ctx: &ExpContext, id: &str) -> Result<String> {
    // comma-separated list: run in one process to share corpus caches
    if id.contains(',') {
        let mut out = String::new();
        for part in id.split(',') {
            println!("=== running {part} ===");
            out.push_str(&run_experiment(ctx, part.trim())?);
            out.push('\n');
        }
        return Ok(out);
    }
    let md = match id {
        "fig1a" => search::fig1a(ctx)?,
        "fig1b" => transfer::fig1b(ctx)?,
        "fig1c" => precision::fig1c(ctx)?,
        "fig2" => stability::fig2(ctx)?,
        "fig3" => transfer::fig3(ctx)?,
        "fig4" => search::fig4(ctx)?,
        "fig5" => transfer::fig5(ctx)?,
        "fig6" => precision::fig6(ctx)?,
        "fig7" => precision::fig7(ctx)?,
        "fig13" => search::fig13(ctx)?,
        "fig17" => transfer::fig17(ctx)?,
        "fig19" => precision::fig19(ctx)?,
        "fig20" => precision::fig20(ctx)?,
        "fig25" => stability::fig25(ctx)?,
        "tab12" => precision::tab12(ctx)?,
        "all" => {
            let mut out = String::new();
            for (id, _, _) in EXPERIMENTS {
                println!("=== running {id} ===");
                out.push_str(&run_experiment(ctx, id)?);
                out.push('\n');
            }
            out
        }
        _ => bail!("unknown experiment {id:?}; `repro exp list` to enumerate"),
    };
    Ok(md)
}
