//! Numerics experiments: Fig 1(c), Fig 6, Fig 7 + Table 4, Fig 19,
//! Fig 20, Table 12.

use anyhow::Result;

use crate::coordinator::{ExpContext, Report};
use crate::data::probe_suite;
use crate::formats::{format_table_markdown, E4M3, E5M2};
use crate::parametrization::{Precision, Scheme};
use crate::util::plot::Series;

use super::helpers::*;

/// Fig 1(c): naive `.to(float8)` cast training. u-μP trains with minimal
/// degradation; SP/μP under the same cast degrade or diverge.
pub fn fig1c(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig1c", "out-of-the-box FP8 cast training");
    let dir = ctx.exp_dir("fig1c");
    let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (scheme, eta) in [
        (Scheme::Umup, 2f64.powf(-1.0)),
        (Scheme::Mup, 2f64.powf(-8.0)),
        (Scheme::Sp, 2f64.powf(-8.0)),
    ] {
        for precision in [Precision::Fp32, Precision::Fp8Naive] {
            let mut cfg = proto(ctx, scheme, 384);
            cfg.hp.eta = eta;
            cfg.schedule.peak_lr = eta;
            cfg.precision = precision;
            cfg.label = format!("{}-{}", scheme.name(), precision.name());
            let res = single(ctx, &man, &corpus, cfg)?;
            let mut s = Series::new(format!("{} {}", scheme.name(), precision.name()));
            for &(t, l) in &res.record.train_curve {
                s.push(t as f64, l.min(12.0));
            }
            rows.push(vec![
                scheme.name().into(),
                precision.name().into(),
                format!("{:.4}", res.record.final_valid_loss),
                res.record.diverged.to_string(),
            ]);
            series.push(s);
        }
    }
    report.figure(&dir, "train_curves", &series, false)?;
    // degradation = fp8 loss - fp32 loss per scheme
    report.table(&["scheme", "precision", "final valid loss", "diverged"], &rows);
    report.para(
        "Paper claim: u-μP FP8-vs-FP32 degradation is minimal; the same cast \
         hurts (or destabilizes) SP and μP because their tensors sit far from \
         unit scale.",
    );
    report.finish(&dir)
}

/// Fig 6: per-tensor RMS at init and after training vs the E4M3/E5M2
/// ranges.
pub fn fig6(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig6", "per-tensor RMS vs FP8 ranges");
    let dir = ctx.exp_dir("fig6");
    let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let mut rows = Vec::new();
    let mut summary = Vec::new();
    for (scheme, eta) in [(Scheme::Umup, 2f64.powf(-1.0)), (Scheme::Mup, 2f64.powf(-8.0))] {
        // init telemetry is stateful work -> caller-thread pooled session
        let runner = ctx.engine.runner(&man)?;
        let mut cfg = proto(ctx, scheme, 384);
        cfg.hp.eta = eta;
        cfg.schedule.peak_lr = eta;
        let (_, init_rms) = runner.eval_at_init(&cfg, &corpus)?;
        let rec = single(ctx, &man, &corpus, cfg)?.record;
        let end: std::collections::BTreeMap<_, _> = rec.final_rms.iter().cloned().collect();
        let mut n_in_range_init = 0usize;
        let mut n_in_range_end = 0usize;
        let mut n = 0usize;
        for (name, rms0) in &init_rms {
            if name.starts_with("g.") {
                continue; // grads are zero in the init eval pass
            }
            let rms1 = end.get(name).copied().unwrap_or(f64::NAN);
            let inr = |r: f64| r >= E4M3.min_normal() && r <= E4M3.max_value();
            n += 1;
            n_in_range_init += inr(*rms0) as usize;
            n_in_range_end += inr(rms1) as usize;
            rows.push(vec![
                scheme.name().into(),
                name.clone(),
                format!("{rms0:.4e}"),
                format!("{rms1:.4e}"),
            ]);
        }
        summary.push(vec![
            scheme.name().into(),
            format!("{n_in_range_init}/{n}"),
            format!("{n_in_range_end}/{n}"),
        ]);
    }
    report.kv(
        "E4M3 comfortable range",
        format!("[{:.3e}, {:.0}]", E4M3.min_normal(), E4M3.max_value()),
    );
    report.kv("E5M2 min normal", format!("{:.3e}", E5M2.min_normal()));
    report.table(&["scheme", "tensors with RMS in E4M3 normal range (init)", "(end)"], &summary);
    crate::util::plot::write_table(
        &dir.join("rms_per_tensor.csv"),
        &["scheme", "site", "rms_init", "rms_end"],
        &rows,
    )?;
    report.para(
        "Paper claim: u-μP tensors start at RMS ≈ 1 and stay within E4M3 range; \
         μP weights/grads sit orders of magnitude lower (underflow risk).",
    );
    report.finish(&dir)
}

/// Fig 7 + Table 4: the scaled-down "large" run: u-μP FP8(paper scheme)
/// vs u-μP high-precision vs SP, plus downstream probes.
pub fn fig7(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig7", "target-scale runs + downstream probes (Table 4)");
    let dir = ctx.exp_dir("fig7");
    let width = if ctx.quick { 64 } else { 128 };
    let man = ctx.registry.find(width, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let steps = 384;
    let cases = [
        ("u-muP bf16", Scheme::Umup, Precision::Fp32, 2f64.powf(-1.0)),
        ("u-muP fp8", Scheme::Umup, Precision::Fp8Paper, 2f64.powf(-1.0)),
        ("SP bf16", Scheme::Sp, Precision::Fp32, 2f64.powf(-8.0) * 64.0 / width as f64),
    ];
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (label, scheme, precision, eta) in cases {
        // run_full returns the on-device state for the probe evals, so
        // this goes through the engine's caller-thread session pool
        let runner = ctx.engine.runner(&man)?;
        let mut cfg = proto(ctx, scheme, steps);
        cfg.hp.eta = eta;
        cfg.schedule.peak_lr = eta;
        cfg.precision = precision;
        cfg.label = label.into();
        let (rec, ts) = runner.run_full(&cfg, &corpus)?;
        let mut s = Series::new(label);
        for &(t, l) in &rec.train_curve {
            s.push(t as f64, l);
        }
        series.push(s);
        // Table 4 substitute: held-out perplexity probes on the trained
        // model — in-domain, shifted-chain, high-entropy (DESIGN.md §4)
        let probes = probe_suite(&corpus.config, 60_000);
        let mut probe_cells = vec![label.to_string(), format!("{:.4}", rec.final_valid_loss)];
        for (_, pc) in &probes {
            let loss = runner.eval_on(&ts, pc, 4)?;
            probe_cells.push(format!("{:.3}", loss.exp())); // perplexity
        }
        rows.push(probe_cells);
    }
    report.figure(&dir, "loss_curves", &series, false)?;
    report.table(&["run", "valid loss", "in-domain", "shifted-chain", "high-entropy"], &rows);
    report.para(
        "Paper claim (Fig 7/Table 4): FP8 curves track BF16 with no significant \
         degradation; u-μP is competitive with SP downstream.",
    );
    report.finish(&dir)
}

/// Fig 19: RMS during training for matmul inputs/weights/grads.
pub fn fig19(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig19", "RMS during training (matmul inputs)");
    let dir = ctx.exp_dir("fig19");
    let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let last = man.spec.depth - 1;
    let sites = vec![
        format!("act.l{last}.o_in"),
        format!("act.l{last}.down_in"),
        format!("act.l{last}.qkv_in"),
        "act.head_in".to_string(),
        "w.head".to_string(),
        format!("g.l{last}.ffn.down"),
        format!("w.l{last}.ffn.down"),
    ];
    let mut all_series = Vec::new();
    let mut rows = Vec::new();
    for (scheme, eta) in [(Scheme::Umup, 2f64.powf(-1.0)), (Scheme::Mup, 2f64.powf(-8.0))] {
        let mut cfg = proto(ctx, scheme, 384);
        cfg.hp.eta = eta;
        cfg.schedule.peak_lr = eta;
        cfg.rms_sites = sites.clone();
        let res = single(ctx, &man, &corpus, cfg)?;
        for (site, curve) in &res.record.rms_curves {
            // a curve can be empty when the run diverges before its
            // first RMS sample: emit a labelled skip row, don't panic
            let (Some(first), Some(last)) = (curve.first(), curve.last()) else {
                rows.push(vec![
                    scheme.name().into(),
                    site.clone(),
                    "(no samples)".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            let mut s = Series::new(format!("{} {}", scheme.name(), site));
            for &(t, r) in curve {
                s.push(t as f64, r.max(1e-12).log2());
            }
            let growth = last.1 / first.1.max(1e-12);
            rows.push(vec![
                scheme.name().into(),
                site.clone(),
                format!("{:.3e}", first.1),
                format!("{:.3e}", last.1),
                format!("{growth:.2}x"),
            ]);
            all_series.push(s);
        }
    }
    report.figure(&dir, "rms_curves_log2", &all_series, false)?;
    report.table(&["scheme", "site", "rms start", "rms end", "growth"], &rows);
    report.para(
        "Paper claim: u-μP starts at RMS ≈ 1 everywhere; the critical tensors \
         (attn out-proj input, FFN down input, decoder weight) grow during \
         training while norm-guarded inputs stay flat.",
    );
    report.finish(&dir)
}

/// Fig 20: end-of-training RMS of critical tensors vs LR, width, depth,
/// steps, batch size.
pub fn fig20(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig20", "end-RMS of critical tensors vs HPs");
    let dir = ctx.exp_dir("fig20");
    let base_steps = 256;
    let crit = |man: &crate::runtime::Manifest| {
        let last = man.spec.depth - 1;
        vec!["w.head".to_string(), format!("act.l{last}.down_in"), format!("g.l{last}.ffn.down")]
    };
    let mut series: Vec<Series> = Vec::new();
    let mut rows = Vec::new();
    let record = |axis: &str,
                      x: f64,
                      rec: &crate::train::RunRecord,
                      names: &[String],
                      series: &mut Vec<Series>,
                      rows: &mut Vec<Vec<String>>| {
        let final_rms: std::collections::BTreeMap<_, _> = rec.final_rms.iter().cloned().collect();
        for name in names {
            let v = final_rms.get(name).copied().unwrap_or(f64::NAN);
            let label = format!("{axis}:{name}");
            if let Some(s) = series.iter_mut().find(|s| s.label == label) {
                s.push(x, v);
            } else {
                let mut s = Series::new(label);
                s.push(x, v);
                series.push(s);
            }
            rows.push(vec![axis.into(), x.to_string(), name.clone(), format!("{v:.4e}")]);
        }
    };

    // LR axis
    let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    for &lg in &[-3.0, -2.0, -1.0, 0.0, 1.0] {
        let mut cfg = proto(ctx, Scheme::Umup, base_steps);
        cfg.hp.eta = 2f64.powf(lg);
        cfg.schedule.peak_lr = cfg.hp.eta;
        let rec = single(ctx, &man, &corpus, cfg)?;
        record("lr", 2f64.powf(lg), &rec.record, &crit(&man), &mut series, &mut rows);
    }
    // width axis
    for &w in &[32usize, 64, 128] {
        let man = ctx.registry.find(w, 4, 16)?;
        let mut cfg = proto(ctx, Scheme::Umup, base_steps);
        cfg.hp.eta = 0.5;
        cfg.schedule.peak_lr = 0.5;
        let rec = single(ctx, &man, &ctx.corpus(man.spec.vocab), cfg)?;
        record("width", w as f64, &rec.record, &crit(&man), &mut series, &mut rows);
    }
    // depth axis
    for &d in &[2usize, 4, 8] {
        let man = ctx.registry.find(PROXY_WIDTH, d, 16)?;
        let mut cfg = proto(ctx, Scheme::Umup, base_steps);
        cfg.hp.eta = 0.5;
        cfg.schedule.peak_lr = 0.5;
        let rec = single(ctx, &man, &ctx.corpus(man.spec.vocab), cfg)?;
        record("depth", d as f64, &rec.record, &crit(&man), &mut series, &mut rows);
    }
    // steps axis
    for &st in &[128u64, 256, 512] {
        let mut cfg = proto(ctx, Scheme::Umup, st);
        cfg.hp.eta = 0.5;
        cfg.schedule.peak_lr = 0.5;
        let rec = single(ctx, &man, &corpus, cfg)?;
        record("steps", st as f64, &rec.record, &crit(&man), &mut series, &mut rows);
    }
    // batch axis
    for &b in &[8usize, 16, 32] {
        let man = ctx.registry.find(PROXY_WIDTH, 4, b)?;
        let mut cfg = proto(ctx, Scheme::Umup, base_steps);
        cfg.hp.eta = 0.5;
        cfg.schedule.peak_lr = 0.5;
        let rec = single(ctx, &man, &ctx.corpus(man.spec.vocab), cfg)?;
        record("batch", b as f64, &rec.record, &crit(&man), &mut series, &mut rows);
    }
    crate::util::plot::write_table(&dir.join("end_rms.csv"), &["axis", "x", "site", "rms"], &rows)?;
    report.figure(&dir, "end_rms", &series, true)?;
    report.para(
        "Paper claim: only the learning rate substantially moves end-training \
         RMS of the critical tensors; width/depth/steps/batch leave it stable.",
    );
    report.finish(&dir)
}

/// Table 12: generated from the Rust codecs.
pub fn tab12(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("tab12", "deep-learning number formats (from the codecs)");
    let dir = ctx.exp_dir("tab12");
    report.para(&format_table_markdown());
    report.para("Matches paper Table 12 (unit tests pin every cell).");
    report.finish(&dir)
}
