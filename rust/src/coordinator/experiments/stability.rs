//! Stability experiments: Fig 2 (setups + fixes) and Fig 25 (App. L).

use anyhow::Result;

use crate::coordinator::{ExpContext, Report};
use crate::parametrization::{plain_prenorm_skip_rms, Scheme, SetupFlavor};
use crate::train::RunConfig;
use crate::util::plot::Series;

use super::helpers::*;

/// Fig 2: μTransfer holds in the TP5 setup, breaks in the standard Llama
/// setup, and is restored by non-parametric norms + independent WD.
pub fn fig2(ctx: &ExpContext) -> Result<String> {
    let widths: &[usize] = if ctx.quick { &[32, 64] } else { &[32, 64, 128] };
    let mut report = Report::new("fig2", "muTransfer across training setups");
    let dir = ctx.exp_dir("fig2");
    let mut rows = Vec::new();
    for flavor in [
        SetupFlavor::TensorPrograms5,
        SetupFlavor::LlamaStandard,
        SetupFlavor::LlamaFixed,
    ] {
        let mut series = Vec::new();
        let mut opts = Vec::new();
        for &w in widths {
            let man =
                ctx.registry.find_opt(w, 4, 16, flavor.trainable_norms())?;
            let steps = ctx.steps(256);
            let mut p: RunConfig = proto(ctx, Scheme::Mup, 256);
            p.adam = flavor.adam();
            p.schedule = flavor.schedule(1.0, steps, (steps / 4).max(1));
            p.label = format!("fig2-{}-w{w}", flavor.name());
            // TP5's overfitting regime: tiny repeated corpus
            let vocab = man.spec.vocab;
            let line = if flavor.corpus_fraction() < 1.0 {
                let tiny = ctx.tiny_corpus(vocab, flavor.corpus_fraction());
                lr_line(ctx, &man, &tiny, &p, &lr_grid(Scheme::Mup, false))?
            } else {
                lr_line(ctx, &man, &ctx.corpus(vocab), &p, &lr_grid(Scheme::Mup, false))?
            };
            series.push(to_series(format!("w{w}"), &line));
            match best_point(&line) {
                Some((opt_lr, opt_loss)) => {
                    opts.push((w, opt_lr));
                    rows.push(vec![
                        flavor.name().into(),
                        w.to_string(),
                        format!("{:.2}", opt_lr.log2()),
                        format!("{opt_loss:.4}"),
                    ]);
                }
                // every point diverged/cancelled: report it, don't panic
                None => rows.push(vec![
                    flavor.name().into(),
                    w.to_string(),
                    "(all diverged)".into(),
                    "-".into(),
                ]),
            }
        }
        report.figure(&dir, &format!("lr_sweep_{}", flavor.name()), &series, true)?;
        match (opts.first(), opts.last()) {
            (Some(&(_, first_lr)), Some(&(_, last_lr))) => {
                let drift = (last_lr / first_lr).log2().abs();
                report
                    .kv(&format!("{} optimum drift |log2|", flavor.name()), format!("{drift:.2}"));
            }
            _ => report.kv(
                &format!("{} optimum drift |log2|", flavor.name()),
                "n/a (no width produced a finite optimum)".to_string(),
            ),
        }
    }
    report.table(&["setup", "width", "log2 opt LR", "best loss"], &rows);
    report.para(
        "Paper claim: transfer looks good in the (a) TP5 setup, degrades in \
         (b) the standard Llama setup, and is restored in (c) with \
         non-parametric norms + independent weight decay.",
    );
    report.finish(&dir)
}

/// Fig 25 / Appendix L: attention-output RMS grows with depth at
/// initialization (causal uniform attention ≈ running mean induces
/// correlation), while norm-guarded inputs stay unit.
pub fn fig25(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig25", "attention-output RMS growth with depth at init");
    let dir = ctx.exp_dir("fig25");
    let man = ctx.registry.find(PROXY_WIDTH, 8, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let runner = ctx.engine.runner(&man)?;
    let cfg = proto(ctx, Scheme::Umup, 8);
    let (_, rms) = runner.eval_at_init(&cfg, &corpus)?;
    let get = |name: &str| {
        rms.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(f64::NAN)
    };
    let mut s_attn = Series::new("attn raw output RMS");
    let mut s_skip = Series::new("skip stream RMS");
    let mut s_qkv = Series::new("qkv input RMS (post-norm)");
    let mut rows = Vec::new();
    for l in 0..man.spec.depth {
        let a = get(&format!("attn_out.l{l}.raw"));
        let k = get(&format!("skip.l{l}.post"));
        let q = get(&format!("act.l{l}.qkv_in"));
        s_attn.push(l as f64, a);
        s_skip.push(l as f64, k);
        s_qkv.push(l as f64, q);
        rows.push(vec![l.to_string(), format!("{a:.3}"), format!("{k:.3}"), format!("{q:.3}")]);
    }
    report.figure(&dir, "rms_by_layer", &[s_attn, s_skip, s_qkv], false)?;
    report.table(&["layer", "attn out RMS", "skip RMS", "qkv in RMS"], &rows);
    // analytic reference from Appendix F (plain pre-norm growth)
    let analytic =
        plain_prenorm_skip_rms(man.spec.depth, 1.0, 1.0 / (man.spec.depth as f64).sqrt());
    report.kv("plain pre-norm skip RMS (Eq. 9 analytic, for contrast)", format!("{analytic:.3}"));
    report.para(
        "Paper claim (App. L): attention outputs after layer 0 exceed unit RMS \
         (correlation from near-uniform causal attention) while the norm-guarded \
         qkv inputs remain at 1; the u-μP residual keeps the skip stream near 1.",
    );
    report.finish(&dir)
}
