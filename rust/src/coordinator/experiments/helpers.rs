//! Shared experiment plumbing: LR sweeps, grid helpers, proto configs.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::ExpContext;
use crate::data::Corpus;
use crate::parametrization::{HpSet, Parametrization, Precision, Scheme};
use crate::runtime::Manifest;
use crate::sweep::{run_all_parallel, SweepJob, SweepResult};
use crate::train::{AdamConfig, RunConfig, Schedule};
use crate::util::stats;

/// Default proxy width used throughout (the paper's 256 scaled down).
pub const PROXY_WIDTH: usize = 64;

/// LR grids per scheme (log2, coarse 2^1 steps for transfer plots).
pub fn lr_grid(scheme: Scheme, fine: bool) -> Vec<f64> {
    let (lo, hi) = match scheme {
        Scheme::Umup => (-4.0, 0.0),
        _ => (-10.0, -6.0),
    };
    let step = if fine { 0.5 } else { 1.0 };
    let mut v = Vec::new();
    let mut x = lo;
    while x <= hi + 1e-9 {
        v.push(2f64.powf(x));
        x += step;
    }
    v
}

/// A standard run prototype for a scheme at some artifact + step count.
pub fn proto(ctx: &ExpContext, scheme: Scheme, steps: u64) -> RunConfig {
    let steps = ctx.steps(steps);
    let mut p = Parametrization::new(scheme);
    p.base_width = PROXY_WIDTH;
    RunConfig {
        label: scheme.name().to_string(),
        parametrization: p,
        hp: HpSet::default(),
        precision: Precision::Fp32,
        schedule: Schedule::standard(1.0, steps, (steps / 4).max(1)),
        adam: AdamConfig::default(),
        seed: 7,
        log_every: (steps / 16).max(1),
        valid_batches: 4,
        rms_sites: Vec::new(),
        lr_tweaks: Vec::new(),
    }
}

/// Run an LR line for `proto` on a manifest; returns (eta, loss) points.
pub fn lr_line(
    ctx: &ExpContext,
    man: Arc<Manifest>,
    corpus: &Corpus,
    proto: &RunConfig,
    grid: &[f64],
) -> Result<Vec<(f64, f64)>> {
    let jobs: Vec<SweepJob> = grid
        .iter()
        .enumerate()
        .map(|(i, &eta)| {
            let mut cfg = proto.clone();
            cfg.hp.eta = eta;
            cfg.schedule.peak_lr = eta;
            cfg.label = format!("{}-lr{i:02}", proto.label);
            SweepJob { config: cfg, tag: vec![("eta".into(), eta)] }
        })
        .collect();
    let res = run_all_parallel(man, corpus, &jobs, ctx.workers)?;
    Ok(res.iter().map(|r| (r.job.tag[0].1, r.record.objective())).collect())
}

/// Best (x, loss) of a line.
pub fn best_point(line: &[(f64, f64)]) -> (f64, f64) {
    let i = stats::argmin(&line.iter().map(|p| p.1).collect::<Vec<_>>());
    line[i]
}

/// Render a line as a plot series.
pub fn to_series(label: impl Into<String>, line: &[(f64, f64)]) -> crate::util::plot::Series {
    let mut s = crate::util::plot::Series::new(label);
    for &(x, y) in line {
        if y.is_finite() {
            s.push(x, y);
        }
    }
    s
}

/// Run a single config and return the record.
pub fn single(
    ctx: &ExpContext,
    man: Arc<Manifest>,
    corpus: &Corpus,
    cfg: RunConfig,
) -> Result<SweepResult> {
    let mut res = run_all_parallel(man, corpus, &[SweepJob { config: cfg, tag: vec![] }], 1)?;
    let _ = ctx;
    Ok(res.pop().unwrap())
}
