//! HP-search experiments: Fig 1(a), Fig 4 (+14/15), Fig 13.

use anyhow::Result;

use crate::coordinator::{ExpContext, Report};
use crate::parametrization::Scheme;
use crate::sweep::{
    independent_search, pair_grid, random_search, simulate_run_counts, transfer_error, HpSpace,
    Range,
};
use crate::util::plot::Series;

use super::helpers::*;

/// Fig 1(a): random vs independent search. For u-μP the 1-D LR phase
/// alone reaches near-optimal loss; for μP the combined-mults phase
/// spikes (coupled HPs) and random search needs many runs.
pub fn fig1a(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig1a", "random vs independent HP search");
    let dir = ctx.exp_dir("fig1a");
    let man = ctx.registry.find(32, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let n_random = if ctx.quick { 6 } else { 32 };
    let mut rows = Vec::new();
    for scheme in [Scheme::Mup, Scheme::Umup] {
        let space = HpSpace::table5(scheme);
        let p = proto(ctx, scheme, 192);
        let rand = random_search(&ctx.engine, &man, &corpus, &space, &p, n_random, ctx.seed)?;
        let curve = simulate_run_counts(
            &rand.results,
            &[1, 2, 4, 8, 16, n_random],
            200,
            ctx.seed,
        );
        let ind = independent_search(&ctx.engine, &man, &corpus, &space, &p)?;
        let mut s_rand = Series::new(format!("{} random", scheme.name()));
        for (k, l) in &curve {
            s_rand.push(*k as f64, *l);
        }
        let mut s_ind = Series::new(format!("{} independent", scheme.name()));
        s_ind.push(ind.runs_after_phase[0] as f64, ind.best_lr_loss);
        s_ind.push(ind.runs_after_phase[2] as f64, ind.combined_loss);
        report.figure(&dir, &format!("search_{}", scheme.name()), &[s_rand, s_ind], true)?;
        rows.push(vec![
            scheme.name().into(),
            format!("{:.4}", rand.best_loss),
            format!("{:.4}", ind.best_lr_loss),
            format!("{:.4}", ind.combined_loss),
            format!("{:.2}", ind.best_eta.log2()),
        ]);
    }
    report.table(
        &["scheme", "random best", "LR-only loss", "combined loss", "log2 opt eta"],
        &rows,
    );
    report.para(
        "Paper claim: u-μP's LR-only phase ≈ its combined/random best \
         (unit scale is near-optimal); μP needs the full search and its \
         combined phase can spike above the LR-only loss.",
    );
    report.finish(&dir)
}

/// Fig 4 (with the Fig 14/15 grids as CSV): transfer error per HP pair.
pub fn fig4(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig4", "HP interdependence (transfer error, Algorithm 1)");
    let dir = ctx.exp_dir("fig4");
    let man = ctx.registry.find(32, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let r = if ctx.quick {
        Range::new(-1.0, 1.0, 1.0)
    } else {
        Range::new(-2.0, 2.0, 1.0)
    };
    let cases = [
        (Scheme::Mup, vec!["sigma_init", "eta_emb_hat", "alpha_attn"], 2f64.powf(-8.0)),
        (Scheme::Umup, vec!["alpha_attn", "alpha_res", "alpha_res_attn_ratio"], 2f64.powf(-1.0)),
    ];
    let mut rows = Vec::new();
    let mut mean_by_scheme = Vec::new();
    for (scheme, hps, eta) in cases {
        let mut p = proto(ctx, scheme, 128);
        p.hp.eta = eta;
        p.schedule.peak_lr = eta;
        let eta_range = if scheme == Scheme::Umup {
            Range::new(eta.log2() - 2.0, eta.log2() + 2.0, 1.0)
        } else {
            Range::new(eta.log2() - 2.0, eta.log2() + 2.0, 1.0)
        };
        // pairs: (eta, each HP) + (hp_i, hp_j)
        let mut pairs: Vec<(&str, Range, &str, Range)> = Vec::new();
        for h in &hps {
            pairs.push(("eta", eta_range, h, r));
        }
        for i in 0..hps.len() {
            for j in (i + 1)..hps.len() {
                pairs.push((hps[i], r, hps[j], r));
            }
        }
        pairs.truncate(if ctx.quick { 2 } else { 4 });
        let mut errs = Vec::new();
        for (fa, ra, fb, rb) in pairs {
            let grid = pair_grid(&ctx.engine, &man, &corpus, &p, (fa, ra), (fb, rb))?;
            crate::util::plot::write_table(
                &dir.join(format!("grid_{}_{}_{}.csv", scheme.name(), fa, fb)),
                &[fa, fb, "loss"],
                &grid.csv_rows(),
            )?;
            let te = transfer_error(&grid);
            rows.push(vec![
                scheme.name().into(),
                format!("{fa} x {fb}"),
                format!("{:.4}", te.error),
            ]);
            errs.push(te.error);
        }
        let mean = crate::util::stats::mean(&errs);
        mean_by_scheme.push((scheme.name(), mean));
        report.kv(&format!("{} mean transfer error", scheme.name()), format!("{mean:.4}"));
    }
    report.table(&["scheme", "pair", "transfer error"], &rows);
    report.para(
        "Paper claim (Fig 4): mean transfer error ~0.03 for μP vs ~0.005 for \
         u-μP — u-μP's HPs are markedly more independent.",
    );
    report.finish(&dir)
}

/// Fig 13: independently varying per-tensor LR multipliers around the
/// optimized global LR — the optimum should sit near 1 for every tensor,
/// justifying the single global η.
pub fn fig13(ctx: &ExpContext) -> Result<String> {
    let mut report = Report::new("fig13", "per-tensor LR multipliers around the global optimum");
    let dir = ctx.exp_dir("fig13");
    let man = ctx.registry.find(PROXY_WIDTH, 4, 16)?;
    let corpus = ctx.corpus(man.spec.vocab);
    let eta = 2f64.powf(-1.0);
    let groups: &[(&str, &[&str])] = &[
        ("emb", &["emb"]),
        ("attn.qkv", &["attn.q", "attn.k", "attn.v"]),
        ("attn.o", &["attn.o"]),
        ("ffn", &["ffn.gate", "ffn.up", "ffn.down"]),
        ("head", &["head"]),
    ];
    let mults: Vec<f64> = (-2..=2).map(|e| 2f64.powi(e)).collect();
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for (gname, members) in groups {
        let mut jobs = Vec::new();
        for &m in &mults {
            let mut cfg = proto(ctx, Scheme::Umup, 192);
            cfg.hp.eta = eta;
            cfg.schedule.peak_lr = eta;
            cfg.lr_tweaks = members.iter().map(|t| (t.to_string(), m)).collect();
            cfg.label = format!("lrmult-{gname}-{m}");
            jobs.push(crate::sweep::SweepJob { config: cfg, tag: vec![((*gname).into(), m)] });
        }
        // stream the multiplier line; outcomes fill in as they finish
        let line = hp_line(ctx, &man, &corpus, jobs)?;
        match best_point(&line) {
            Some((opt, loss)) => {
                rows.push(vec![gname.to_string(), format!("{opt}"), format!("{loss:.4}")]);
            }
            None => rows.push(vec![gname.to_string(), "(all diverged)".into(), "-".into()]),
        }
        series.push(to_series(gname.to_string(), &line));
    }
    report.figure(&dir, "per_tensor_lr", &series, true)?;
    report.table(&["tensor group", "optimal multiplier", "loss"], &rows);
    report.para("Paper claim: per-tensor optima sit at/near 1 ⇒ a single global η suffices.");
    report.finish(&dir)
}
