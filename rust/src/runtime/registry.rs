//! Artifact registry: discovers artifact directories and picks the right
//! shape for an experiment request.
//!
//! Pure discovery: session compilation and caching live behind
//! `crate::engine::Engine` (per-worker pools plus a caller-thread pool),
//! so the registry never touches XLA.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Manifest;

/// Discovers [`Manifest`]s keyed by spec name.
pub struct Registry {
    root: PathBuf,
    manifests: Vec<Arc<Manifest>>,
}

impl Registry {
    /// Scan `root` (usually `artifacts/`) for manifest directories.
    pub fn open(root: &Path) -> Result<Registry> {
        let mut manifests = Vec::new();
        for entry in std::fs::read_dir(root)
            .with_context(|| format!("reading artifact root {}", root.display()))?
        {
            let dir = entry?.path();
            if dir.is_dir() && dir.join("manifest.json").exists() {
                manifests.push(Arc::new(Manifest::load(&dir)?));
            }
        }
        if manifests.is_empty() {
            bail!(
                "no artifacts under {} — run `make artifacts` first",
                root.display()
            );
        }
        manifests.sort_by_key(|m| m.name.clone());
        Ok(Registry { root: root.to_path_buf(), manifests })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn manifests(&self) -> &[Arc<Manifest>] {
        &self.manifests
    }

    pub fn manifest(&self, name: &str) -> Result<Arc<Manifest>> {
        self.manifests
            .iter()
            .find(|m| m.name == name)
            .cloned()
            .with_context(|| format!("no artifact named {name:?} under {}", self.root.display()))
    }

    /// Find the artifact for a given shape (non-trainable norms).
    pub fn find(&self, width: usize, depth: usize, batch: usize) -> Result<Arc<Manifest>> {
        self.find_opt(width, depth, batch, false)
    }

    pub fn find_opt(
        &self,
        width: usize,
        depth: usize,
        batch: usize,
        trainable_norms: bool,
    ) -> Result<Arc<Manifest>> {
        self.manifests
            .iter()
            .find(|m| {
                m.spec.width == width
                    && m.spec.depth == depth
                    && m.spec.batch == batch
                    && m.spec.trainable_norms == trainable_norms
            })
            .cloned()
            .with_context(|| {
                format!("no artifact for w{width} d{depth} b{batch} tn={trainable_norms}")
            })
    }
}
