//! Manifest parsing: the L2↔L3 contract (see python/compile/specs.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Weight-type classification, Table 1 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightKind {
    /// fan-out ∝ width only (embedding table).
    Input,
    /// fan-in and fan-out ∝ width (all in-block matmuls).
    Hidden,
    /// fan-in ∝ width only (decoder head).
    Output,
    /// norm gains (only present under trainable_norms).
    Norm,
}

impl WeightKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "emb" => WeightKind::Input,
            "hidden" => WeightKind::Hidden,
            "out" => WeightKind::Output,
            "norm" => WeightKind::Norm,
            _ => bail!("unknown weight kind {s:?}"),
        })
    }
}

/// One parameter tensor in packing order.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: WeightKind,
    pub fan_in: usize,
    pub fan_out: usize,
    pub offset: usize,
    pub size: usize,
}

/// The compiled model shape.
#[derive(Debug, Clone)]
pub struct Spec {
    pub width: usize,
    pub depth: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub head_dim: usize,
    pub trainable_norms: bool,
}

/// Parsed manifest.json for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub dir: PathBuf,
    pub spec: Spec,
    pub tensors: Vec<TensorMeta>,
    pub n_params: usize,
    pub state_ext_len: usize,
    pub loss_offset: usize,
    pub rms_offset: usize,
    pub scale_sites: BTreeMap<String, usize>,
    pub n_scale_sites: usize,
    pub quant_sites: BTreeMap<String, usize>,
    pub n_quant_sites: usize,
    pub rms_sites: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}", dir.join("manifest.json").display()))?;
        let j = Json::parse(&text)?;
        let spec = j.get("spec")?;
        let spec = Spec {
            width: spec.get("width")?.as_usize()?,
            depth: spec.get("depth")?.as_usize()?,
            batch: spec.get("batch")?.as_usize()?,
            seq: spec.get("seq")?.as_usize()?,
            vocab: spec.get("vocab")?.as_usize()?,
            head_dim: spec.get("head_dim")?.as_usize()?,
            trainable_norms: spec.get("trainable_norms")?.as_bool()?,
        };
        let mut tensors = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            tensors.push(TensorMeta {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                kind: WeightKind::parse(t.get("kind")?.as_str()?)?,
                fan_in: t.get("fan_in")?.as_usize()?,
                fan_out: t.get("fan_out")?.as_usize()?,
                offset: t.get("offset")?.as_usize()?,
                size: t.get("size")?.as_usize()?,
            });
        }
        let site_map = |key: &str| -> Result<BTreeMap<String, usize>> {
            let mut m = BTreeMap::new();
            for (k, v) in j.get(key)?.as_obj()? {
                m.insert(k.clone(), v.as_usize()?);
            }
            Ok(m)
        };
        let man = Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            dir: dir.to_path_buf(),
            spec,
            tensors,
            n_params: j.get("n_params")?.as_usize()?,
            state_ext_len: j.get("state_ext_len")?.as_usize()?,
            loss_offset: j.get("loss_offset")?.as_usize()?,
            rms_offset: j.get("rms_offset")?.as_usize()?,
            scale_sites: site_map("scale_sites")?,
            n_scale_sites: j.get("n_scale_sites")?.as_usize()?,
            quant_sites: site_map("quant_sites")?,
            n_quant_sites: j.get("n_quant_sites")?.as_usize()?,
            rms_sites: j
                .get("rms_sites")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<_>>()?,
        };
        man.validate()?;
        Ok(man)
    }

    /// Internal-consistency checks (run on load and in integration tests).
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for t in &self.tensors {
            if t.offset != off {
                bail!("tensor {} offset {} != expected {}", t.name, t.offset, off);
            }
            let prod: usize = t.shape.iter().product();
            if prod != t.size {
                bail!("tensor {} size mismatch", t.name);
            }
            off += t.size;
        }
        if off != self.n_params {
            bail!("n_params {} != packed {}", self.n_params, off);
        }
        if self.state_ext_len != 3 * self.n_params + 1 + self.rms_sites.len() {
            bail!("state_ext_len inconsistent");
        }
        if self.loss_offset != 3 * self.n_params || self.rms_offset != self.loss_offset + 1 {
            bail!("tail offsets inconsistent");
        }
        if self.scale_sites.len() != self.n_scale_sites
            || self.quant_sites.len() != self.n_quant_sites
        {
            bail!("site counts inconsistent");
        }
        Ok(())
    }

    pub fn tensor(&self, name: &str) -> Result<&TensorMeta> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("no tensor {name:?} in {}", self.name))
    }

    pub fn scale_site(&self, name: &str) -> Result<usize> {
        self.scale_sites
            .get(name)
            .copied()
            .with_context(|| format!("no scale site {name:?} in {}", self.name))
    }

    pub fn rms_index(&self, name: &str) -> Result<usize> {
        self.rms_sites
            .iter()
            .position(|s| s == name)
            .with_context(|| format!("no rms site {name:?}"))
    }

    pub fn init_path(&self) -> PathBuf {
        self.dir.join("init.hlo.txt")
    }
    pub fn step_path(&self) -> PathBuf {
        self.dir.join("step.hlo.txt")
    }
    pub fn eval_path(&self) -> PathBuf {
        self.dir.join("eval.hlo.txt")
    }
    pub fn tail_path(&self) -> PathBuf {
        self.dir.join("tail.hlo.txt")
    }
}
