//! PJRT execution: compile HLO-text artifacts once, then drive training
//! with on-device state chaining.
//!
//! The train step is a single-array-root computation
//! ``step(state_ext, tokens, scales, lr_scale, hyp, qmask) -> state_ext'``
//! so the output `PjRtBuffer` feeds straight back in via `execute_b` with
//! no host round-trip; per step only the telemetry tail ``[loss | rms]``
//! is copied back (via the tiny `tail.hlo.txt` slice executable — the
//! 0.5.1 CPU PJRT plugin does not implement partial raw reads).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::Manifest;

/// A PJRT client + compiled executables for one artifact directory.
pub struct Session {
    pub client: PjRtClient,
    pub manifest: Arc<Manifest>,
    init: Executable,
    step: Executable,
    evalf: Executable,
    /// Slices [loss | rms] out of the device state (the 0.5.1 CPU PJRT
    /// plugin lacks CopyRawToHost, so partial reads go through XLA).
    tail: Executable,
}

/// A compiled HLO module.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    pub fn compile(client: &PjRtClient, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Execute with literal inputs; expect a single (array-root) output.
    pub fn run_literals(&self, args: &[Literal]) -> Result<PjRtBuffer> {
        let mut out = self.exe.execute::<Literal>(args)?;
        take_single(&mut out, &self.name)
    }

    /// Execute with device buffers.
    pub fn run_buffers(&self, args: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
        let mut out = self.exe.execute_b::<&PjRtBuffer>(args)?;
        take_single(&mut out, &self.name)
    }
}

fn take_single(out: &mut Vec<Vec<PjRtBuffer>>, name: &str) -> Result<PjRtBuffer> {
    if out.len() != 1 {
        bail!("{name}: expected 1 replica, got {}", out.len());
    }
    let mut inner = out.pop().unwrap();
    if inner.len() != 1 {
        bail!("{name}: expected single-array root, got {} outputs", inner.len());
    }
    Ok(inner.pop().unwrap())
}

/// The on-device training state plus its cached host-side inputs.
pub struct TrainState {
    pub state: PjRtBuffer,
    /// Device-resident constant-per-run inputs (scales, lr_scale, qmask).
    pub scales: PjRtBuffer,
    pub lr_scale: PjRtBuffer,
    pub qmask: PjRtBuffer,
    pub step_count: u64,
    /// Telemetry tail scratch: [loss | rms...].
    tail: Vec<f32>,
}

impl Session {
    pub fn open(manifest: Arc<Manifest>) -> Result<Session> {
        let client = PjRtClient::cpu()?;
        Self::open_with_client(client, manifest)
    }

    pub fn open_with_client(client: PjRtClient, manifest: Arc<Manifest>) -> Result<Session> {
        let init = Executable::compile(&client, &manifest.init_path())?;
        let step = Executable::compile(&client, &manifest.step_path())?;
        let evalf = Executable::compile(&client, &manifest.eval_path())?;
        let tail = Executable::compile(&client, &manifest.tail_path())?;
        Ok(Session { client, manifest, init, step, evalf, tail })
    }

    fn upload(&self, xs: &[f32]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(xs, &[xs.len()], None)?)
    }

    fn upload_tokens(&self, tokens: &[i32]) -> Result<PjRtBuffer> {
        let m = &self.manifest;
        Ok(self.client.buffer_from_host_buffer::<i32>(
            tokens,
            &[m.spec.batch, m.spec.seq + 1],
            None,
        )?)
    }

    /// Initialize a fresh training state on device.
    ///
    /// `init_std` and the runtime vectors come from the parametrization
    /// engine ([`crate::parametrization::RuntimeVectors`]).
    pub fn init(
        &self,
        seed: i32,
        init_std: &[f32],
        scales: &[f32],
        lr_scale: &[f32],
        qmask: &[f32],
    ) -> Result<TrainState> {
        let m = &self.manifest;
        if init_std.len() != m.tensors.len() {
            bail!("init_std len {} != {}", init_std.len(), m.tensors.len());
        }
        if scales.len() != m.n_scale_sites {
            bail!("scales len {} != {}", scales.len(), m.n_scale_sites);
        }
        if lr_scale.len() != m.tensors.len() {
            bail!("lr_scale len {} != {}", lr_scale.len(), m.tensors.len());
        }
        if qmask.len() != m.n_quant_sites {
            bail!("qmask len {} != {}", qmask.len(), m.n_quant_sites);
        }
        let state = self
            .init
            .run_literals(&[Literal::scalar(seed), Literal::vec1(init_std)])?;
        Ok(TrainState {
            state,
            scales: self.upload(scales)?,
            lr_scale: self.upload(lr_scale)?,
            qmask: self.upload(qmask)?,
            step_count: 0,
            tail: vec![0.0; 1 + m.rms_sites.len()],
        })
    }

    /// Run one train step in place; returns the training loss.
    ///
    /// `hyp` is the 8-float hyper vector (see python/compile/optim.py);
    /// tokens are `i32[batch, seq+1]` row-major.
    pub fn step(&self, ts: &mut TrainState, tokens: &[i32], hyp: &[f32; 8]) -> Result<f32> {
        self.step_chain(ts, tokens, hyp)?;
        self.fetch_tail(ts)?;
        Ok(ts.tail[0])
    }

    /// §Perf: the chain-only step — advances the on-device state without
    /// fetching telemetry (no tail executable launch, no device→host
    /// copy). The training driver uses this between logging points and
    /// calls [`Session::fetch_tail`] at the cadence.
    pub fn step_chain(&self, ts: &mut TrainState, tokens: &[i32], hyp: &[f32; 8]) -> Result<()> {
        let m = &self.manifest;
        debug_assert_eq!(tokens.len(), m.spec.batch * (m.spec.seq + 1));
        let tok_buf = self.upload_tokens(tokens)?;
        let hyp_buf = self.upload(&hyp[..])?;
        let next = self.step.run_buffers(&[
            &ts.state, &tok_buf, &ts.scales, &ts.lr_scale, &hyp_buf, &ts.qmask,
        ])?;
        ts.state = next;
        ts.step_count += 1;
        Ok(())
    }

    /// Fetch [loss | rms] from the device state into the host-side tail.
    pub fn fetch_tail(&self, ts: &mut TrainState) -> Result<f32> {
        let tail_buf = self.tail.run_buffers(&[&ts.state])?;
        ts.tail = tail_buf.to_literal_sync()?.to_vec()?;
        Ok(ts.tail[0])
    }

    /// Evaluate validation loss (+ telemetry) without touching the state.
    pub fn eval(&self, ts: &TrainState, tokens: &[i32]) -> Result<EvalOut> {
        let tok_buf = self.upload_tokens(tokens)?;
        let out = self
            .evalf
            .run_buffers(&[&ts.state, &tok_buf, &ts.scales, &ts.qmask])?;
        let lit = out.to_literal_sync()?;
        let v: Vec<f32> = lit.to_vec()?;
        Ok(EvalOut { loss: v[0], rms: v[1..].to_vec() })
    }

    /// Last-step telemetry (valid after `step`): (loss, rms tail).
    pub fn telemetry<'a>(&self, ts: &'a TrainState) -> (f32, &'a [f32]) {
        (ts.tail[0], &ts.tail[1..])
    }

    /// Download the full extended state (params + moments + tail).
    pub fn download_state(&self, ts: &TrainState) -> Result<Vec<f32>> {
        Ok(ts.state.to_literal_sync()?.to_vec()?)
    }

    /// Download just one named parameter tensor (via a full-state copy;
    /// the CPU plugin has no partial reads).
    pub fn download_tensor(&self, ts: &TrainState, name: &str) -> Result<Vec<f32>> {
        let t = self.manifest.tensor(name)?;
        let full = self.download_state(ts)?;
        Ok(full[t.offset..t.offset + t.size].to_vec())
    }

    /// Replace the run-constant vectors (used by sweep re-use of state).
    pub fn set_vectors(
        &self,
        ts: &mut TrainState,
        scales: &[f32],
        lr_scale: &[f32],
        qmask: &[f32],
    ) -> Result<()> {
        ts.scales = self.upload(scales)?;
        ts.lr_scale = self.upload(lr_scale)?;
        ts.qmask = self.upload(qmask)?;
        Ok(())
    }
}

/// Output of an eval pass.
#[derive(Debug, Clone)]
pub struct EvalOut {
    pub loss: f32,
    pub rms: Vec<f32>,
}
