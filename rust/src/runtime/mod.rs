//! S7 — PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `artifact.rs` mirrors the manifest contract written by
//! `python/compile/aot.py`; `exec.rs` wraps the `xla` crate
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` → compile →
//! `execute_b`) with on-device state chaining: the train step is
//! state-in/state-out over a single flat buffer, and only the telemetry
//! tail ([loss | rms]) is copied back per step.

mod artifact;
#[cfg(feature = "xla")]
mod exec;
mod registry;

pub use artifact::{Manifest, Spec, TensorMeta, WeightKind};
#[cfg(feature = "xla")]
pub use exec::{Executable, Session, TrainState};
pub use registry::Registry;
