//! `repro` — the u-μP coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   rules                       print the Table 1/2/11 rule evaluation
//!   check                       validate every artifact + manifest
//!   train [opts]                one training run
//!   exp <id|all|list> [--quick] reproduce a paper figure/table
//!   drive --shards n [exp opts] spawn/monitor/restart n shard processes
//!   worker [--mock]             serve engine jobs over stdin/stdout
//!                               (the child side of --backend process)
//!   worker --listen <ep>        serve engine jobs on a TCP/Unix socket
//!                               (the dialed side of --backend network)
//!   serve  [--addr ep]          long-lived coordinator daemon: owns an
//!                               engine, exposes submit/status/cancel/
//!                               cache-stats/events/shutdown over a
//!                               JSONL RPC socket
//!   ctl    <verb> --addr ep     one RPC against a live `repro serve`
//!                               (`ctl watch` tails the daemon's event
//!                               stream)
//!   cache <stats|gc|compact>    run-cache lifecycle (segments, GC,
//!                               background-style tiered merges)
//!   report                      collate results/ into EXPERIMENTS-style md
//!
//! Execution backends: `train`/`exp`/`drive` take
//! `--backend in-process|process|network|mock`.  `in-process` (default)
//! runs jobs on this process's pooled XLA sessions; `process` spawns
//! one `repro worker` child per engine worker slot and ships jobs over
//! a length-prefixed JSONL pipe protocol (crash-supervised, bounded
//! restarts); `network` dials the same frames to long-lived
//! `repro worker --listen` endpoints (`--workers host:port,...`,
//! round-robin failover, bounded reconnects); `mock` is the
//! deterministic no-op executor used by tests and benches.
//!
//! Dependency-light by design (offline env): argument parsing is the
//! in-tree `Args` helper below.
//!
//! Built with `--no-default-features`, the XLA runtime is absent and the
//! execution subcommands (`check`/`train`/`exp`/`drive`) explain that;
//! the pure subcommands (`rules`, `cache`, `report`, `corpus`) and the
//! mock worker (`worker --mock`) still work.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use umup::data::{Corpus, CorpusConfig};
use umup::engine::{gc, parse_bytes, parse_duration, stats, Compactor, GcOptions, Shard};
use umup::parametrization::{Abc, HpSet, Parametrization, Scheme};
use umup::runtime::Registry;

/// Minimal flag parser: positional args + `--key value` + `--flag`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// The engine's run-cache flags, shared by `train`, `exp` and
    /// `serve`.
    fn cache_opts(&self) -> (Option<PathBuf>, bool) {
        (self.flags.get("cache-dir").map(PathBuf::from), self.has("resume"))
    }

    /// The sweep-sharding flag (`--shard i/n`).
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    fn shard(&self) -> Result<Option<Shard>> {
        match self.flags.get("shard") {
            Some(s) => Ok(Some(Shard::parse(s).context("bad --shard")?)),
            None => Ok(None),
        }
    }
}

/// The `--job-timeout SECS` deadline shared by `train`/`exp`/`drive`/
/// `serve`.  `None` (flag absent, or 0) keeps every blocking wire
/// read/write unbounded — the byte-deterministic default.
fn job_timeout_flag(args: &Args) -> Result<Option<std::time::Duration>> {
    match args.flags.get("job-timeout") {
        Some(s) => {
            let secs: u64 = s.parse().context("bad --job-timeout (whole seconds)")?;
            Ok((secs > 0).then_some(std::time::Duration::from_secs(secs)))
        }
        None => Ok(None),
    }
}

/// The shared-secret token from `--token` or `UMUP_TOKEN` (flag wins).
/// One secret covers a whole fleet: listeners require it, dialers
/// present it.
fn token_flag(args: &Args) -> Option<String> {
    args.flags.get("token").cloned().or_else(|| std::env::var("UMUP_TOKEN").ok())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "rules" => rules(&args),
        "check" => check(&args),
        "train" => train(&args),
        "exp" => exp(&args),
        "drive" => drive_cmd(&args),
        "worker" => worker_cmd(&args),
        "serve" => serve_cmd(&args),
        "ctl" => ctl_cmd(&args),
        "chaos" => chaos_cmd(&args),
        "cache" => cache_cmd(&args),
        "report" => report(&args),
        "corpus" => corpus_info(&args),
        _ => {
            println!(
                "repro — u-muP reproduction coordinator\n\n\
                 usage: repro <command> [--flags]\n\n\
                 commands:\n\
                 \x20 rules   [--scheme umup] [--width 256] [--depth 4]   print A/B/C per tensor\n\
                 \x20 check   [--artifacts artifacts]                     validate artifacts\n\
                 \x20 train   [--scheme umup] [--width 64] [--depth 4] [--batch 16]\n\
                 \x20         [--lr 0.5] [--steps 256] [--precision fp32|fp8|fp8-paper] [--seed 7]\n\
                 \x20 exp     <id|all|list> [--quick] [--workers N] [--shard i/n] [--quiet]\n\
                 \x20                                                     reproduce figures/tables\n\
                 \x20 drive   <id|all> --shards N [--quick] [--workers N] [--out DIR]\n\
                 \x20                 [--bg-compact] spawn, monitor and restart the N shard\n\
                 \x20                             processes of `exp --shard` (one shared cache;\n\
                 \x20                             --bg-compact tier-merges idle segments)\n\
                 \x20                 [--progress jsonl[:PATH]] stream typed telemetry events\n\
                 \x20                             as JSON lines (stderr, or append to PATH) —\n\
                 \x20                             also accepted by train/exp; drive merges its\n\
                 \x20                             children's shard-tagged streams into its own\n\
                 \x20                 [--tui]     live sweep dashboard (shard bars, cache/pool\n\
                 \x20                             panels, recent failures; needs a build with\n\
                 \x20                             --features tui)\n\
                 \x20 worker  [--mock] [--artifacts DIR] [--sessions N]   serve engine jobs on\n\
                 \x20                             stdin/stdout (spawned by --backend process);\n\
                 \x20                             reads ahead up to 8 frames so parsing overlaps\n\
                 \x20                             execution whatever the engine's\n\
                 \x20                             --pipeline-depth\n\
                 \x20 worker  --listen HOST:PORT|unix:/path [--mock] [--token SECRET]\n\
                 \x20                             serve engine jobs on a socket, one thread\n\
                 \x20                             per connected engine (the dialed side of\n\
                 \x20                             --backend network); same read-ahead as\n\
                 \x20                             stdio mode; SIGTERM drains (see below)\n\
                 \x20 serve   [--addr HOST:PORT|unix:/path] [--workers N|EP,EP,...]\n\
                 \x20         [--backend network|process|mock|in-process] [--cache-dir DIR]\n\
                 \x20         [--resume] [--token SECRET] [--job-timeout SECS]\n\
                 \x20                     long-lived coordinator daemon: owns one engine and\n\
                 \x20                             answers submit/status/cancel/cache-stats/\n\
                 \x20                             events/shutdown RPCs (prints `serving ADDR`\n\
                 \x20                             when up); SIGTERM drains (see below)\n\
                 \x20 ctl     <submit|status|cancel|cache-stats|watch|shutdown> --addr ADDR\n\
                 \x20         [--jobs FILE] [--sweep N] [--timeout SECS] [--token SECRET]\n\
                 \x20                             one RPC against a live serve daemon;\n\
                 \x20                             prints the JSON result on stdout (`watch`\n\
                 \x20                             tails the daemon's event stream as JSONL\n\
                 \x20                             until the daemon exits).  --timeout\n\
                 \x20                             (default 30) bounds the dial and every\n\
                 \x20                             reply; expiry is a nonzero exit naming the\n\
                 \x20                             fix (0 disables; watch is unbounded unless\n\
                 \x20                             --timeout is passed explicitly)\n\
                 \x20 chaos   --listen EP --upstream EP [--faults SPEC]   deterministic fault-\n\
                 \x20                             injecting proxy for the worker wire\n\
                 \x20                             protocol: forwards verbatim except the\n\
                 \x20                             faults SPEC names by global reply ordinal\n\
                 \x20                             (stall-after:N, delay-ms:N, tear-frame:N,\n\
                 \x20                             drop-conn:N, garbage-reply:N — also read\n\
                 \x20                             from UMUP_FAULTS; see tests/chaos.rs)\n\
                 \x20 cache   stats [--cache-dir DIR]                     segment/key statistics\n\
                 \x20 cache   gc    [--cache-dir DIR] [--older-than 30d] [--manifest NAME]\n\
                 \x20               [--max-bytes 512m] [--chunk-entries N] [--dry-run]\n\
                 \x20                                                     prune + compact segments\n\
                 \x20 cache   compact [--cache-dir DIR] [--max-steps N]   fold similar-sized\n\
                 \x20                             segments (size-tiered, non-blocking locks)\n\
                 \x20 report  [--out results]                             collate summaries\n\
                 \x20 corpus  [--vocab 256]                               corpus statistics\n\n\
                 execution backends:\n\
                 \x20 train/exp/drive take [--backend in-process|process|mock].  in-process\n\
                 \x20 (default) runs jobs on this process's pooled XLA sessions.  process\n\
                 \x20 spawns one `repro worker` child per engine worker slot and ships each\n\
                 \x20 job over a length-prefixed JSONL stdin/stdout protocol (the reply is\n\
                 \x20 the run-cache line itself); crashed children are restarted with a\n\
                 \x20 bounded per-worker budget (--max-restarts, default 2), the in-flight\n\
                 \x20 job is re-dispatched once, and child stderr is teed here with a\n\
                 \x20 [worker k] prefix.  mock is the deterministic test executor.\n\
                 \x20 train/exp/drive/serve also take [--pipeline-depth N]: each worker slot\n\
                 \x20 keeps up to N encoded jobs in flight on its wire connection (replies\n\
                 \x20 stream back in any order, matched by content key).  Default: 1 for\n\
                 \x20 --backend process (lockstep), 4 for --backend network, where the\n\
                 \x20 round-trip dominates.  On a connection death every unacknowledged job\n\
                 \x20 in the window is re-dispatched once under the same --max-restarts\n\
                 \x20 budget.  Depth 1 keeps per-connection dispatch order byte-identical\n\
                 \x20 to the classic lockstep path; any depth leaves cache *contents*\n\
                 \x20 identical, only segment line order may differ.\n\n\
                 deadlines, drain & auth:\n\
                 \x20 train/exp/drive/serve take [--job-timeout SECS]: every wire read/write\n\
                 \x20 gets a deadline and each process child a kill-after watchdog, so a\n\
                 \x20 hung-but-alive peer is treated exactly like a crashed one — connection\n\
                 \x20 torn down, the unacked window re-dispatched once under the same\n\
                 \x20 --max-restarts budget, a worker_stalled event published.  Default: off\n\
                 \x20 (the unarmed path stays byte-identical to previous builds); drive\n\
                 \x20 forwards the flag to its shard children.  --backend network, serve and\n\
                 \x20 ctl take [--token SECRET] (or UMUP_TOKEN): a listener started with a\n\
                 \x20 token advertises auth in its hello and requires the dialer's token\n\
                 \x20 frame before any traffic (mismatch fails the handshake with a hint;\n\
                 \x20 no token leaves the socket open as before).  SIGTERM/SIGINT drain\n\
                 \x20 serve, worker --listen and drive gracefully: stop accepting work,\n\
                 \x20 finish or cancel what is in flight (persist-before-report intact),\n\
                 \x20 unlink unix sockets, exit 75 (EX_TEMPFAIL) so a supervisor can tell a\n\
                 \x20 drain from a crash.\n\n\
                 network topology:\n\
                 \x20 --backend network ships the same wire frames over sockets: start\n\
                 \x20 long-lived workers with `repro worker --listen HOST:PORT` (or\n\
                 \x20 unix:/path), then point an engine at them with\n\
                 \x20 --workers HOST:PORT,HOST:PORT,... — worker slot k starts at endpoint\n\
                 \x20 k mod n and every reconnect advances round-robin, so a dead endpoint\n\
                 \x20 fails over instead of pinning its slot.  Reconnects share the process\n\
                 \x20 backend's bounded --max-restarts budget.  For a persistent\n\
                 \x20 coordinator, `repro serve` owns the engine and exposes an RPC socket\n\
                 \x20 (hello `umup-serve`, deliberately distinct from the worker hello, so\n\
                 \x20 cross-wired sockets fail their handshake); `repro ctl <verb> --addr A`\n\
                 \x20 is the client: submit --jobs FILE (wire-job JSONL), status [--sweep N],\n\
                 \x20 cancel --sweep N (queued jobs unqueue; in-flight jobs finish and are\n\
                 \x20 cached), cache-stats, shutdown (drains sweeps, then exits).\n\n\
                 cache layout & lifecycle:\n\
                 \x20 train/exp take [--cache-dir DIR] [--resume].  --cache-dir records each\n\
                 \x20 completed run as one JSONL line, content-addressed by (manifest, corpus,\n\
                 \x20 config) — identical configs dedupe; --resume merges every segment already\n\
                 \x20 in DIR so a restarted sweep skips finished jobs (without --resume this\n\
                 \x20 process's own segment is truncated).  `repro exp all` defaults to\n\
                 \x20 --cache-dir <out>/run-cache --resume so figures share baselines.\n\
                 \x20 Segments: an unsharded run appends to runs.jsonl; `--shard i/n` makes\n\
                 \x20 this process execute only the runs whose content hash lands in slice i\n\
                 \x20 of n, appending to its own runs.<i>.jsonl — so n processes given the\n\
                 \x20 same command drain one sweep into one shared DIR concurrently, then any\n\
                 \x20 later --resume (or `cache gc`) merges the segments.  Each segment is\n\
                 \x20 guarded by a <segment>.lock file (holder pid; stale locks from dead\n\
                 \x20 processes are reclaimed automatically).\n\
                 \x20 Lifecycle: `cache stats` summarizes segments/keys/manifests;\n\
                 \x20 `cache gc` prunes by age (--older-than, via each line's ts field) and/or\n\
                 \x20 --manifest, drops corrupt lines and cross-segment duplicates, and\n\
                 \x20 compacts everything into a single key-sorted runs.jsonl.  gc streams:\n\
                 \x20 memory is bounded by --chunk-entries (sorted spill runs + k-way merge),\n\
                 \x20 not by cache size.  `cache compact` instead folds groups of\n\
                 \x20 similar-sized segments in place (size-tiered merges under non-blocking\n\
                 \x20 locks — safe while a sweep is running; `drive --bg-compact` does the\n\
                 \x20 same from its idle loop).  Both rebuild each output segment's\n\
                 \x20 <segment>.idx key-presence sidecar, which later opens and watchers use\n\
                 \x20 to skip scanning segments for keys they cannot contain.\n"
            );
            Ok(())
        }
    }
}

/// Print the evaluated parametrization table (Tables 1/2/11 made concrete).
fn rules(args: &Args) -> Result<()> {
    let scheme = Scheme::parse(&args.get("scheme", "umup")).context("bad --scheme")?;
    let width: usize = args.get("width", "256").parse()?;
    let depth: usize = args.get("depth", "4").parse()?;
    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    // use the manifest at the requested shape if present, else any other
    // as the tensor-name template
    let man = reg
        .find(width, depth, 16)
        .or_else(|_| reg.manifest("w64_d4_b16_t64_v256"))?;
    let p = Parametrization::new(scheme);
    let hp = HpSet::default();
    println!("{} rules at width {width}, depth {depth} (eta=1):", scheme.name());
    println!(
        "{:24} {:>12} {:>12} {:>12} {:>12}",
        "tensor", "A (param)", "A bwd", "B (init)", "C (lr)"
    );
    for t in &man.tensors {
        let abc = Abc::of(&p, &hp, t, width, depth);
        println!(
            "{:24} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            t.name, abc.a, abc.a_bwd, abc.b, abc.c
        );
    }
    Ok(())
}

/// Validate all artifacts: manifests parse, HLO compiles, one step runs.
#[cfg(feature = "xla")]
fn check(args: &Args) -> Result<()> {
    use umup::engine::{Engine, EngineConfig};

    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    for man in reg.manifests() {
        print!("{:28}", man.name);
        let session = engine.session(man)?;
        let vecs = umup::parametrization::RuntimeVectors::build(
            man,
            &Parametrization::new(Scheme::Umup),
            &HpSet::with_eta(0.5),
            umup::parametrization::Precision::Fp32,
        )?;
        let mut ts =
            session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
        let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
            .map(|i| (i % man.spec.vocab) as i32)
            .collect();
        let hyp = umup::train::AdamConfig::default().hyp(0.1, 1);
        let loss = session.step(&mut ts, &tokens, &hyp)?;
        if !loss.is_finite() {
            bail!("{}: non-finite loss", man.name);
        }
        println!(" ok   n_params={:9}  step loss={loss:.4}", man.n_params);
    }
    println!("all artifacts OK");
    Ok(())
}

#[cfg(feature = "xla")]
fn train(args: &Args) -> Result<()> {
    use std::sync::Arc;

    use umup::engine::{Engine, EngineConfig, EngineJob};
    use umup::parametrization::Precision;
    use umup::train::{RunConfig, Schedule};

    let scheme = Scheme::parse(&args.get("scheme", "umup")).context("bad --scheme")?;
    let width: usize = args.get("width", "64").parse()?;
    let depth: usize = args.get("depth", "4").parse()?;
    let batch: usize = args.get("batch", "16").parse()?;
    let steps: u64 = args.get("steps", "256").parse()?;
    let lr: f64 =
        args.get("lr", if scheme == Scheme::Umup { "0.5" } else { "0.005" }).parse()?;
    let precision =
        Precision::parse(&args.get("precision", "fp32")).context("bad --precision")?;
    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    let man = reg.find(width, depth, batch)?;
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: man.spec.vocab,
        n_tokens: 2_000_000,
        ..Default::default()
    }));
    let (cache_dir, resume) = args.cache_opts();
    let tap = progress_tap(args, None)?;
    let engine_cfg = EngineConfig {
        workers: 1,
        cache_dir,
        resume,
        events: tap.as_ref().map(|(bus, _)| bus.clone()),
        ..EngineConfig::default()
    };
    let engine = match make_backend(args, &args.get("artifacts", "artifacts"))? {
        Some(backend) => Engine::with_backend(engine_cfg, backend)?,
        None => Engine::new(engine_cfg)?,
    };
    let mut cfg = RunConfig::quick(
        &format!("{}-{}", scheme.name(), precision.name()),
        Parametrization::new(scheme),
        HpSet::with_eta(lr),
        steps,
    );
    cfg.precision = precision;
    cfg.seed = args.get("seed", "7").parse()?;
    cfg.schedule = Schedule::standard(lr, steps, (steps / 4).max(1));
    println!("training {} on {} for {steps} steps (lr {lr})", cfg.label, man.name);
    // non-blocking submission: the handle resolves a cache hit
    // instantly and otherwise streams the outcome when the run ends
    let handle =
        engine.submit_one(EngineJob::new(Arc::clone(&man), Arc::clone(&corpus), cfg, vec![]));
    let rec = handle.result()?.record;
    for &(t, l) in &rec.train_curve {
        println!("step {t:6}  train loss {l:.4}");
    }
    let cached = if engine.stats().cache_hits > 0 { "  (from run cache)" } else { "" };
    println!(
        "final valid loss {:.4}  (diverged: {})  [{:.1}s]{cached}",
        rec.final_valid_loss, rec.diverged, rec.wall_seconds
    );
    if !args.has("quiet") {
        print_engine_stats(&engine);
    }
    // the engine's bus clone must go before the writer can see
    // end-of-stream
    drop(engine);
    if let Some((bus, writer)) = tap {
        drop(bus);
        let _ = writer.join();
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn exp(args: &Args) -> Result<()> {
    use umup::coordinator::{list_experiments, run_experiment, ExpContext};

    let id = args.positional.get(1).map(String::as_str).unwrap_or("list");
    if id == "list" {
        println!("{}", list_experiments());
        return Ok(());
    }
    let workers_flag = args.get("workers", "4");
    // --workers may also be a network endpoint list (host:port,...)
    // under --backend network; then one engine slot per endpoint
    let workers: usize = if workers_flag.contains(':') {
        workers_flag.split(',').filter(|s| !s.trim().is_empty()).count()
    } else {
        workers_flag.parse().context("bad --workers")?
    };
    let out = args.get("out", "results");
    let shard = args.shard()?;
    let (mut cache_dir, mut resume) = args.cache_opts();
    // figures share baselines (fig1a's u-muP curve is fig5's w=64 point,
    // ...), so the full reproduction defaults to a persistent cache;
    // sharded drains need one shared dir + resume to be useful at all
    if cache_dir.is_none() && (id == "all" || shard.is_some()) {
        cache_dir = Some(Path::new(&out).join("run-cache"));
        resume = true;
        println!(
            "(defaulting to --cache-dir {} --resume; override with --cache-dir)",
            Path::new(&out).join("run-cache").display()
        );
    }
    if let Some(s) = shard {
        println!(
            "shard {s}: executing only this slice of each sweep; runs owned by other \
             shards are awaited from the shared cache dir (start the sibling shards \
             with the same command — progress merges automatically)"
        );
    }
    let artifacts = args.get("artifacts", "artifacts");
    let backend = make_backend(args, &artifacts)?;
    if let Some(b) = &backend {
        if !args.has("quiet") {
            println!("backend: {} ({} engine workers)", b.name(), workers);
        }
    }
    // drive children arrive here as `exp --shard i/n --progress
    // jsonl:FILE`; tagging the bus with the shard index keeps the
    // driver's merged stream attributable per shard
    let tap = progress_tap(args, shard.map(|s| s.index))?;
    let ctx = ExpContext::with_backend(
        &artifacts,
        &out,
        args.has("quick"),
        workers,
        cache_dir,
        resume,
        shard,
        backend,
        tap.as_ref().map(|(bus, _)| bus.clone()),
    )?;
    // A sharded drain executes only this process's slice; when the
    // experiment next needs a foreign run, retry after merging in what
    // sibling shards have published.  Every shard follows the same
    // deterministic plan over the same merged results, so the batch
    // frontier advances each round and the final retry is a pure
    // cache-hit replay that yields the full report.
    //
    // Waiting is exponential backoff with full jitter (reset whenever
    // the refresh makes progress): N sibling shards started by one
    // driver would otherwise poll the segment reader in lockstep, all
    // re-scanning every segment at the same instant.
    let md = if shard.is_some() {
        use std::time::Duration;
        let mut rng = umup::util::Rng::new(
            (std::process::id() as u64) ^ shard.map_or(0, |s| (s.index as u64) << 32),
        )
        .fork("shard-idle-backoff");
        const IDLE_TIMEOUT: Duration = Duration::from_secs(120);
        const MAX_BACKOFF: Duration = Duration::from_secs(8);
        let mut backoff = Duration::from_millis(250);
        let mut idled = Duration::ZERO;
        loop {
            match run_experiment(&ctx, id) {
                Ok(md) => break md,
                Err(e) if format!("{e:#}").contains(umup::engine::SHARD_SKIP_MARKER) => {
                    if ctx.engine.refresh_cache() > 0 {
                        backoff = Duration::from_millis(250);
                        idled = Duration::ZERO;
                        continue;
                    }
                    if idled >= IDLE_TIMEOUT {
                        eprintln!(
                            "shard {}: no sibling progress in ~{}s; this slice is \
                             drained as far as it can go.  Run the remaining shards into \
                             the same --cache-dir (or use `repro drive`), then finish \
                             with an unsharded --resume pass.",
                            shard.expect("sharded branch"),
                            IDLE_TIMEOUT.as_secs()
                        );
                        // the engine line stays observable even when a
                        // sharded drain gives up waiting for siblings
                        if !args.has("quiet") {
                            print_engine_stats(&ctx.engine);
                        }
                        return Err(e);
                    }
                    // full jitter in [backoff/2, backoff)
                    let wait = backoff.mul_f64(0.5 + 0.5 * rng.f64());
                    std::thread::sleep(wait);
                    idled += wait;
                    backoff = (backoff * 2).min(MAX_BACKOFF);
                }
                Err(e) => return Err(e),
            }
        }
    } else {
        run_experiment(&ctx, id)?
    };
    println!("{md}");
    if !args.has("quiet") {
        print_engine_stats(&ctx.engine);
    }
    // the engine's bus clone (inside ctx) must go before the writer
    // can see end-of-stream
    drop(ctx);
    if let Some((bus, writer)) = tap {
        drop(bus);
        let _ = writer.join();
    }
    Ok(())
}

/// `repro drive <id> --shards n`: run a sharded `repro exp` end to end
/// from one terminal — the driver spawns the n shard processes against
/// one shared cache dir, restarts any that crash, and streams merged
/// progress while they drain disjoint slices of the sweep.
#[cfg(feature = "xla")]
fn drive_cmd(args: &Args) -> Result<()> {
    use umup::engine::driver::{drive, DriveConfig};

    let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let shards: usize = args.get("shards", "2").parse().context("bad --shards")?;
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    let out = args.get("out", "results");
    let cache_dir = args
        .flags
        .get("cache-dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(&out).join("run-cache"));
    let exe = std::env::current_exe().context("resolving repro binary path")?;
    let workers = args.get("workers", "2");
    let artifacts = args.get("artifacts", "artifacts");
    let quick = args.has("quick");

    // one bus feeds every consumer: the --progress JSONL writer, the
    // --tui dashboard, and the driver's own lifecycle events
    let tui_wanted = args.has("tui");
    #[cfg(not(feature = "tui"))]
    if tui_wanted {
        bail!(
            "`repro drive --tui` needs the dashboard compiled in; rebuild with \
             --features tui"
        );
    }
    let tap = progress_tap(args, None)?;
    let (bus, writer) = match tap {
        Some((bus, writer)) => (Some(bus), Some(writer)),
        None if tui_wanted => (Some(umup::engine::EventBus::new()), None),
        None => (None, None),
    };
    #[cfg(feature = "tui")]
    let tui_thread = match (tui_wanted, &bus) {
        (true, Some(bus)) => {
            let stream = bus.subscribe(4096);
            Some(std::thread::spawn(move || {
                let mut out = std::io::stdout();
                if let Err(e) = umup::engine::events::tui::run(stream, &mut out) {
                    eprintln!("drive: tui exited with error: {e:#}");
                }
            }))
        }
        _ => None,
    };
    // children stream their own shard-tagged events into per-shard
    // JSONL files under the cache dir; the driver tails and merges them
    let child_event_files: Vec<PathBuf> = if bus.is_some() {
        std::fs::create_dir_all(&cache_dir)
            .with_context(|| format!("creating {}", cache_dir.display()))?;
        (0..shards).map(|i| cache_dir.join(format!("events.{i}.jsonl"))).collect()
    } else {
        Vec::new()
    };
    for f in &child_event_files {
        // children open in append mode (restarts continue the stream);
        // stale streams from an earlier drive must not leak in
        let _ = std::fs::remove_file(f);
    }

    // graceful drain: SIGTERM/SIGINT latch the process-wide flag; a
    // bridge thread mirrors it into the driver's stop flag, which the
    // supervision loop polls between rounds (tearing the shard children
    // down; their persisted runs stay resumable)
    umup::util::signal::install_drain_handler();
    let stop_flag = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    {
        let stop_flag = std::sync::Arc::clone(&stop_flag);
        std::thread::spawn(move || {
            while !umup::util::signal::drain_requested() {
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            stop_flag.store(true, std::sync::atomic::Ordering::SeqCst);
        });
    }

    let cfg = DriveConfig {
        shards,
        cache_dir: cache_dir.clone(),
        max_restarts_per_shard: args.get("max-restarts", "2").parse()?,
        background_compaction: args.has("bg-compact"),
        events: bus.clone(),
        child_event_files: child_event_files.clone(),
        stop: Some(std::sync::Arc::clone(&stop_flag)),
        ..DriveConfig::default()
    };
    println!(
        "drive: {id} across {shards} shard processes (cache {}, {} restarts/shard max)",
        cache_dir.display(),
        cfg.max_restarts_per_shard
    );
    let report = drive(&cfg, |shard| {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("exp")
            .arg(id)
            .arg("--shard")
            .arg(shard.to_string())
            .arg("--cache-dir")
            .arg(&cache_dir)
            .arg("--resume")
            .arg("--workers")
            .arg(&workers)
            .arg("--out")
            .arg(&out)
            .arg("--artifacts")
            .arg(&artifacts);
        if quick {
            cmd.arg("--quick");
        }
        // shard children inherit the execution backend: with
        // `--backend process` each shard process runs its own worker
        // fleet (shards x workers children in total)
        if let Some(b) = args.flags.get("backend") {
            cmd.arg("--backend").arg(b);
        }
        if let Some(d) = args.flags.get("pipeline-depth") {
            cmd.arg("--pipeline-depth").arg(d);
        }
        // the deadline and fleet secret apply per child engine
        if let Some(t) = args.flags.get("job-timeout") {
            cmd.arg("--job-timeout").arg(t);
        }
        if let Some(t) = args.flags.get("token") {
            cmd.arg("--token").arg(t);
        }
        if !child_event_files.is_empty() {
            cmd.arg("--progress")
                .arg(format!("jsonl:{}", child_event_files[shard.index].display()));
        }
        cmd
    });
    let report = match report {
        Ok(r) => r,
        Err(e) if umup::util::signal::drain_requested() => {
            eprintln!(
                "drive: drained on signal ({e:#}); partial results are resumable in {}",
                cache_dir.display()
            );
            std::process::exit(umup::util::signal::EXIT_DRAINED);
        }
        Err(e) => return Err(e),
    };
    println!(
        "drive: all {shards} shards done in {:.1}s ({} restarts, {} runs cached); \
         reports are in {out}/",
        report.elapsed.as_secs_f64(),
        report.restarts,
        report.cache_entries
    );
    // the driver config's bus clone must go before the consumers can
    // see end-of-stream
    drop(cfg);
    drop(bus);
    if let Some(w) = writer {
        let _ = w.join();
    }
    #[cfg(feature = "tui")]
    if let Some(t) = tui_thread {
        let _ = t.join();
    }
    Ok(())
}

/// Build the execution backend selected by `--backend` (`None` = the
/// default in-process XLA path), shared by `train` and `exp`.
#[cfg(feature = "xla")]
fn make_backend(
    args: &Args,
    artifacts: &str,
) -> Result<Option<std::sync::Arc<dyn umup::engine::Backend>>> {
    use std::sync::Arc;

    use umup::engine::{MockBackend, NetworkBackend, ProcessBackend};

    // `--pipeline-depth N`: how many encoded jobs each worker slot
    // keeps in flight on its wire connection.  Unset keeps each
    // backend's own default (process: 1 = lockstep; network: 4).
    let pipeline_depth: Option<usize> = args
        .flags
        .get("pipeline-depth")
        .map(|d| d.parse().context("bad --pipeline-depth"))
        .transpose()?;
    let job_timeout = job_timeout_flag(args)?;
    Ok(match args.get("backend", "in-process").as_str() {
        "in-process" => None,
        "process" => {
            let max_restarts: usize =
                args.get("max-restarts", "2").parse().context("bad --max-restarts")?;
            // forward the engine's session cap so each child's LruPool
            // matches the scheduler's warm-manifest mirror
            let sessions = umup::engine::EngineConfig::default().max_sessions_per_worker;
            let mut backend = ProcessBackend::repro_worker(artifacts, false, sessions)?
                .with_max_restarts(max_restarts)
                .with_job_timeout(job_timeout);
            if let Some(d) = pipeline_depth {
                backend = backend.with_pipeline_depth(d);
            }
            Some(Arc::new(backend))
        }
        "network" => {
            let max_restarts: usize =
                args.get("max-restarts", "2").parse().context("bad --max-restarts")?;
            let endpoints = args.get("workers", "");
            if !endpoints.contains(':') {
                bail!(
                    "--backend network needs --workers host:port[,host:port,...] (or \
                     unix:/path) — the endpoint list doubles as the engine worker count"
                );
            }
            let mut backend = NetworkBackend::new(&endpoints)?
                .with_max_restarts(max_restarts)
                .with_job_timeout(job_timeout)
                .with_token(token_flag(args));
            if let Some(d) = pipeline_depth {
                backend = backend.with_pipeline_depth(d);
            }
            Some(Arc::new(backend))
        }
        "mock" => Some(Arc::new(MockBackend::deterministic())),
        other => {
            bail!("unknown --backend {other:?} (expected in-process, process, network or mock)")
        }
    })
}

/// One-line engine counters (runs/cache/dedup/affinity), printed after
/// every non-quiet `train`/`exp` so backend comparisons are observable
/// without `drive`.
#[cfg(feature = "xla")]
fn print_engine_stats(engine: &umup::engine::Engine) {
    let s = engine.stats();
    println!(
        "engine: {} runs executed, {} cache hits, {} deduped, {} skipped, {} cancelled, \
         {} failed ({} records cached; session affinity {} hits / {} steals)",
        s.executed,
        s.cache_hits,
        s.deduped,
        s.skipped,
        s.cancelled,
        s.failed,
        engine.cache_len(),
        s.pool_hits,
        s.pool_steals
    );
}

/// The `--progress jsonl[:PATH]` tap shared by `train`/`exp`/`drive`:
/// build an event bus (envelopes tagged with `shard` when this process
/// is one drive child) and spawn a writer thread draining every event
/// to the JSONL sink — stderr for bare `jsonl`, an append-mode file
/// for `jsonl:PATH`.  Returns `None` when the flag is absent.  The
/// writer exits when the last bus clone (engine, driver config, the
/// returned one) is dropped; join it after dropping them.
#[cfg(feature = "xla")]
fn progress_tap(
    args: &Args,
    shard: Option<usize>,
) -> Result<Option<(umup::engine::EventBus, std::thread::JoinHandle<()>)>> {
    use std::io::Write as _;

    let Some(spec) = args.flags.get("progress") else {
        return Ok(None);
    };
    let mut sink: Box<dyn std::io::Write + Send> = match spec.as_str() {
        "jsonl" => Box::new(std::io::stderr()),
        s => match s.strip_prefix("jsonl:") {
            Some(path) => Box::new(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)
                    .with_context(|| format!("opening --progress file {path}"))?,
            ),
            None => bail!("bad --progress {s:?} (expected jsonl or jsonl:PATH)"),
        },
    };
    let bus = match shard {
        Some(i) => umup::engine::EventBus::new().with_source(i),
        None => umup::engine::EventBus::new(),
    };
    let stream = bus.subscribe(4096);
    let writer = std::thread::spawn(move || {
        for env in stream {
            if writeln!(sink, "{}", env.line()).is_err() {
                break;
            }
        }
        let _ = sink.flush();
    });
    Ok(Some((bus, writer)))
}

/// `repro worker`: serve the engine's wire protocol on stdin/stdout —
/// the child side of `--backend process`.  The parent speaks
/// length-prefixed JSON frames (see `umup::engine::backend::wire`); a
/// success reply is the run-cache line codec itself.  `--mock` swaps
/// the XLA executor for the canonical deterministic mock (works in
/// no-XLA builds; used by the backend test suite and benches).
fn worker_cmd(args: &Args) -> Result<()> {
    if let Some(listen) = args.flags.get("listen") {
        return worker_listen(args, &listen.clone());
    }
    if args.has("mock") {
        return worker_mock_serve();
    }
    worker_xla_serve(args)
}

/// `repro worker --listen <endpoint>`: accept any number of engines on
/// a TCP/Unix socket, serving each connection's wire-protocol stream on
/// its own thread — the dialed side of `--backend network`.  The bound
/// endpoint (real port when listening on `:0`) is announced as one
/// `listening <addr>` line on stdout, so spawners can read it back.
///
/// With `--token`/`UMUP_TOKEN` the hello advertises shared-secret auth
/// and every connection must answer with a matching token frame before
/// any job is served.  SIGTERM/SIGINT drain: stop accepting, give
/// in-flight connections a bounded grace, unlink a unix socket, and
/// exit with [`umup::util::signal::EXIT_DRAINED`].
fn worker_listen(args: &Args, listen: &str) -> Result<()> {
    use std::io::{BufReader, Write as _};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    use umup::engine::{Endpoint, Listener};
    use umup::util::signal;

    let mock = args.has("mock");
    if !mock && !cfg!(feature = "xla") {
        bail!(
            "`repro worker --listen` without --mock needs the XLA runtime; rebuild \
             without --no-default-features (or pass --mock)"
        );
    }
    let token = token_flag(args);
    let ep = Endpoint::parse(listen).context("bad --listen endpoint")?;
    let listener = Listener::bind(&ep)?;
    // graceful drain: SIGTERM/SIGINT latch the flag, but the handler is
    // installed with SA_RESTART semantics, so a blocking accept() never
    // sees EINTR — a monitor thread self-dials the listener to pop it
    // out of accept once the flag is up (the loop re-checks the flag
    // before serving anything it accepted).  Installed before the
    // announcement so a spawner may signal as soon as it reads it.
    signal::install_drain_handler();
    {
        let desc = listener.local_desc();
        std::thread::spawn(move || {
            while !signal::drain_requested() {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            if let Ok(ep) = Endpoint::parse(&desc) {
                let _ = ep.connect();
            }
        });
    }
    println!("listening {}", listener.local_desc());
    std::io::stdout().flush()?;
    // serving threads are counted so a drain can wait (bounded — an
    // idle engine may hold its connection open forever) for in-flight
    // work to finish
    let active = Arc::new(AtomicUsize::new(0));
    loop {
        let (r, w, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                if signal::drain_requested() {
                    break;
                }
                eprintln!("worker: accept failed: {e:#}");
                continue;
            }
        };
        if signal::drain_requested() {
            break;
        }
        eprintln!("worker: engine connected ({peer})");
        // a serve-loop error means the stream is unusable for further
        // jobs, but the write half usually still works: name the reason
        // on the wire (best-effort, key "?") so the engine's transport
        // error carries the worker's own diagnosis instead of a bare
        // "connection lost"
        fn report(w: &mut impl std::io::Write, e: &anyhow::Error) {
            use umup::engine::backend::wire;
            eprintln!("worker: connection ended with error: {e:#}");
            let _ = wire::write_frame(w, &wire::err_reply_line("?", &format!("{e:#}")));
        }
        if mock {
            let token = token.clone();
            let active = Arc::clone(&active);
            active.fetch_add(1, Ordering::SeqCst);
            std::thread::spawn(move || {
                let mut w = w;
                if let Err(e) = mock_serve_loop(BufReader::new(r), &mut w, token.as_deref()) {
                    report(&mut w, &e);
                }
                active.fetch_sub(1, Ordering::SeqCst);
            });
        } else {
            #[cfg(feature = "xla")]
            {
                let artifacts = args.get("artifacts", "artifacts");
                let cap: usize = args.get("sessions", "8").parse().context("bad --sessions")?;
                let token = token.clone();
                let active = Arc::clone(&active);
                active.fetch_add(1, Ordering::SeqCst);
                std::thread::spawn(move || {
                    let mut w = w;
                    if let Err(e) = worker_xla_serve_on(
                        &artifacts,
                        cap,
                        token.as_deref(),
                        BufReader::new(r),
                        &mut w,
                    ) {
                        report(&mut w, &e);
                    }
                    active.fetch_sub(1, Ordering::SeqCst);
                });
            }
            #[cfg(not(feature = "xla"))]
            {
                let _ = (r, w);
                unreachable!("non-mock --listen was rejected above without the xla feature");
            }
        }
    }
    // drain: already-accepted connections get a bounded grace to finish
    // their in-flight windows (persist-before-report happens engine
    // side), then the listener drop unlinks a unix socket and the
    // distinct exit code tells supervisors this was a drain, not a
    // crash
    eprintln!("worker: drain signal received; no longer accepting connections");
    let grace = std::time::Instant::now();
    while active.load(Ordering::SeqCst) > 0
        && grace.elapsed() < std::time::Duration::from_secs(5)
    {
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    drop(listener);
    std::process::exit(signal::EXIT_DRAINED);
}

/// The deterministic mock worker loop, with env-armed failure injection
/// for the robustness tests: `UMUP_MOCK_FAIL` picks a failure mode
/// (`crash-before-reply`, `crash-after-reply`, `garbage`, `truncate`,
/// `hang` — alive but silent, recoverable only via `--job-timeout`)
/// and `UMUP_MOCK_FAIL_ONCE=<path>` arms it exactly once across a whole
/// worker fleet (first child to atomically create the marker file
/// fails; everyone else — including this child's own restart — serves
/// normally).  Without `UMUP_MOCK_FAIL_ONCE` the mode fires on every
/// job, which is how restart-budget exhaustion is exercised.
///
/// Two more knobs serve the robustness suites:
/// `UMUP_MOCK_STDERR_SPAM=<bytes>` floods stderr *before* the hello
/// frame (stdio mode only — regression fuel for the health probe's
/// concurrent stderr drain), and `UMUP_MOCK_SLEEP_MS=<ms>` sleeps per
/// job so cancellation races have something to catch.
fn worker_mock_serve() -> Result<()> {
    use std::io::Write as _;

    if let Ok(n) = std::env::var("UMUP_MOCK_STDERR_SPAM") {
        // write the requested byte count as 64-byte newline-terminated
        // lines; past the OS pipe buffer (~64KiB) this blocks unless
        // the parent drains stderr while waiting for the hello
        let mut left: usize = n.parse().context("bad UMUP_MOCK_STDERR_SPAM")?;
        let stderr = std::io::stderr();
        let mut err = stderr.lock();
        let line = [b'x'; 63];
        while left > 0 {
            err.write_all(&line)?;
            err.write_all(b"\n")?;
            left = left.saturating_sub(64);
        }
        err.flush()?;
    }
    // a plain BufReader, not StdinLock: the serve loop's read-ahead
    // thread needs to own a Send reader
    let stdout = std::io::stdout();
    mock_serve_loop(std::io::BufReader::new(std::io::stdin()), stdout.lock(), None)
}

/// One mock wire-protocol stream: hello, then deterministic replies
/// (with the env-armed failure injection above) until EOF.  Generic
/// over the transport so stdio workers and `--listen` socket
/// connections share it.
///
/// Mirrors `wire::serve`'s read-ahead structure (a scoped reader
/// thread feeding a bounded queue) so a pipelining parent gets the
/// same overlap from mock workers as from real ones — but the failure
/// injection stays at execution/reply time, exactly where the real
/// executor would fail, never in the reader.
fn mock_serve_loop(
    mut input: impl std::io::BufRead + Send,
    mut output: impl std::io::Write,
    token: Option<&str>,
) -> Result<()> {
    use umup::engine::backend::wire;
    use umup::engine::det_record;

    let fail_mode = std::env::var("UMUP_MOCK_FAIL").ok();
    let claim_failure = || -> bool {
        match std::env::var("UMUP_MOCK_FAIL_ONCE") {
            Ok(path) => std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(path)
                .is_ok(),
            Err(_) => true,
        }
    };
    let sleep_ms: u64 = std::env::var("UMUP_MOCK_SLEEP_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);

    wire::write_frame(&mut output, &wire::hello_line_auth(token.is_some()))?;
    if let Some(expect) = token {
        // the dialer's token frame precedes any job; a peer that hangs
        // up instead (a port probe, a drain self-dial) is not an error
        match wire::read_frame(&mut input)? {
            Some(line) => wire::check_token_frame(&line, expect)?,
            None => return Ok(()),
        }
    }
    let (tx, rx) = std::sync::mpsc::sync_channel::<Result<wire::WireJob>>(wire::WORKER_READAHEAD);
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut input = input;
            let mut scratch = Vec::new();
            loop {
                let job = match wire::read_frame_into(&mut input, &mut scratch) {
                    Ok(Some(line)) => wire::decode_job(line),
                    Ok(None) => break,
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                };
                let stop = job.is_err();
                if tx.send(job).is_err() || stop {
                    break;
                }
            }
        });
        // `rx` dies with this closure, so an early error return
        // unblocks a reader parked on a full queue before the scope
        // joins it
        let rx = rx;
        let mut reply = String::new();
        for job in rx.iter() {
            let job = job?;
            // claim_failure's marker-file side effect only runs while a
            // mode is armed (the && short-circuits on None)
            if let Some(mode) = fail_mode.as_deref() {
                if claim_failure() {
                    match mode {
                        "crash-before-reply" => {
                            eprintln!(
                                "worker-mock: injected crash before replying to {}",
                                job.config.label
                            );
                            std::process::exit(17);
                        }
                        "crash-after-reply" => {
                            let rec = det_record(&job.config);
                            let reply = wire::ok_reply_line(&job.key, &job.manifest, &rec);
                            wire::write_frame(&mut output, &reply)?;
                            eprintln!("worker-mock: injected exit between jobs");
                            std::process::exit(0);
                        }
                        "garbage" => {
                            eprintln!("worker-mock: injected garbage on stdout");
                            output.write_all(b"** this is not a frame **\n")?;
                            output.flush()?;
                            // never reply; the parent declares us dead
                            continue;
                        }
                        "truncate" => {
                            eprintln!("worker-mock: injected truncated frame");
                            output.write_all(b"4096\n{\"to")?;
                            output.flush()?;
                            std::process::exit(0);
                        }
                        "hang" => {
                            eprintln!(
                                "worker-mock: injected hang before replying to {}",
                                job.config.label
                            );
                            // alive but silent — the hung-worker shape
                            // only a --job-timeout deadline recovers
                            loop {
                                std::thread::sleep(std::time::Duration::from_secs(3600));
                            }
                        }
                        other => bail!("unknown UMUP_MOCK_FAIL mode {other:?}"),
                    }
                }
            }
            if sleep_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
            }
            let rec = det_record(&job.config);
            reply.clear();
            wire::ok_reply_line_into(&job.key, &job.manifest, &rec, &mut reply);
            wire::write_frame(&mut output, &reply)?;
        }
        Ok(())
    })
}

/// The real worker loop: resolve each wire job against this process's
/// own artifact registry / corpus cache / LRU session pool and train.
#[cfg(feature = "xla")]
fn worker_xla_serve(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts", "artifacts");
    let cap: usize = args.get("sessions", "8").parse().context("bad --sessions")?;
    // a plain BufReader, not StdinLock: the serve loop's read-ahead
    // thread needs to own a Send reader
    let stdout = std::io::stdout();
    worker_xla_serve_on(
        &artifacts,
        cap,
        None,
        std::io::BufReader::new(std::io::stdin()),
        stdout.lock(),
    )
}

/// One real-worker wire-protocol stream over any transport (stdio for
/// `--backend process` children, a socket connection for `--listen`):
/// each stream keeps its own LRU session pool and corpus cache.
#[cfg(feature = "xla")]
fn worker_xla_serve_on(
    artifacts: &str,
    cap: usize,
    token: Option<&str>,
    input: impl std::io::BufRead + Send,
    output: impl std::io::Write,
) -> Result<()> {
    use std::collections::HashMap;
    use std::sync::Arc;

    use umup::engine::backend::wire;
    use umup::engine::LruPool;
    use umup::runtime::Session;
    use umup::train::Runner;

    // open the registry *before* the hello frame: a bad --artifacts
    // path kills the handshake (and therefore the parent's health
    // probe) instead of the first job
    let reg = Registry::open(Path::new(artifacts))?;
    let mut sessions: LruPool<Runner> = LruPool::new(cap);
    // corpora are deterministic functions of their generator config;
    // cache them per config like the parent's ExpContext does
    let mut corpora: HashMap<String, Arc<Corpus>> = HashMap::new();
    wire::serve_authed(input, output, token, |job| {
        let man = reg.manifest(&job.manifest)?;
        let corpus = Arc::clone(
            corpora
                .entry(format!("{:?}", job.corpus))
                .or_insert_with(|| Arc::new(Corpus::generate(job.corpus.clone()))),
        );
        let runner = sessions.get_or_create(&job.manifest, || {
            let session = Session::open(Arc::clone(&man)).with_context(|| {
                format!("opening worker session for {}", job.manifest)
            })?;
            Ok(Runner::new(Arc::new(session)))
        })?;
        runner.run(&job.config, &corpus)
    })
}

#[cfg(not(feature = "xla"))]
fn worker_xla_serve(_args: &Args) -> Result<()> {
    bail!(
        "`repro worker` without --mock needs the XLA runtime; rebuild without \
         --no-default-features (or pass --mock for the deterministic test executor)"
    )
}

/// `repro serve`: the long-lived coordinator daemon — owns one engine
/// (over any backend) and answers submit/status/cancel/cache-stats/
/// events/shutdown RPCs on a JSONL socket (`repro ctl` is the client;
/// the protocol lives in `umup::engine::serve`).
fn serve_cmd(args: &Args) -> Result<()> {
    use std::io::Write as _;
    use std::sync::Arc;

    use umup::engine::{serve, Backend, EngineConfig, MockBackend, NetworkBackend, ProcessBackend};

    let addr = args.get("addr", "127.0.0.1:0");
    let workers_flag = args.get("workers", "4");
    // an endpoint list implies the network backend; a bare count
    // defaults to mock (serve works in no-XLA builds)
    let endpoint_list = workers_flag.contains(':');
    let backend_flag = args.get("backend", if endpoint_list { "network" } else { "mock" });
    let max_restarts: usize =
        args.get("max-restarts", "2").parse().context("bad --max-restarts")?;
    // unset keeps each backend's default in-flight window (process: 1
    // = lockstep; network: 4)
    let pipeline_depth: Option<usize> = args
        .flags
        .get("pipeline-depth")
        .map(|d| d.parse().context("bad --pipeline-depth"))
        .transpose()?;
    let job_timeout = job_timeout_flag(args)?;
    // one fleet secret: the daemon's own control socket requires it,
    // and the network backend presents it to token-armed workers
    let token = token_flag(args);
    let artifacts = args.get("artifacts", "artifacts");
    let sessions = EngineConfig::default().max_sessions_per_worker;
    let (workers, backend): (usize, Arc<dyn Backend>) = match backend_flag.as_str() {
        "network" => {
            if !endpoint_list {
                bail!(
                    "--backend network needs --workers host:port[,host:port,...] (or \
                     unix:/path)"
                );
            }
            let mut b = NetworkBackend::new(&workers_flag)?
                .with_max_restarts(max_restarts)
                .with_job_timeout(job_timeout)
                .with_token(token.clone());
            if let Some(d) = pipeline_depth {
                b = b.with_pipeline_depth(d);
            }
            (b.n_endpoints(), Arc::new(b))
        }
        "mock" => {
            (workers_flag.parse().context("bad --workers")?, Arc::new(MockBackend::deterministic()))
        }
        "process" => {
            let mut b = ProcessBackend::repro_worker(&artifacts, args.has("mock"), sessions)?
                .with_max_restarts(max_restarts)
                .with_job_timeout(job_timeout);
            if let Some(d) = pipeline_depth {
                b = b.with_pipeline_depth(d);
            }
            (workers_flag.parse().context("bad --workers")?, Arc::new(b))
        }
        "in-process" => {
            (workers_flag.parse().context("bad --workers")?, in_process_backend(sessions)?)
        }
        other => bail!(
            "unknown --backend {other:?} (expected network, process, mock or in-process)"
        ),
    };
    let (cache_dir, resume) = args.cache_opts();
    // graceful drain: SIGTERM/SIGINT latch the process-wide flag; a
    // bridge thread mirrors it into the engine owner loop's drain flag,
    // which cancels and drains every sweep (persist-before-report
    // intact), then stops the daemon
    umup::util::signal::install_drain_handler();
    let drain = Arc::new(std::sync::atomic::AtomicBool::new(false));
    {
        let drain = Arc::clone(&drain);
        std::thread::spawn(move || {
            while !umup::util::signal::drain_requested() {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            drain.store(true, std::sync::atomic::Ordering::SeqCst);
        });
    }
    let opts = serve::ServeOptions {
        endpoint: addr,
        engine: EngineConfig { workers, cache_dir, resume, ..EngineConfig::default() },
        artifacts: PathBuf::from(&artifacts),
        // only in-process execution reads tokens/manifests on this
        // host; every out-of-process backend resolves them worker-side
        materialize_corpora: backend_flag == "in-process",
        token,
        drain: Some(Arc::clone(&drain)),
    };
    println!("serve: backend {} with {workers} engine workers", backend.name());
    serve::serve(opts, backend, |desc| {
        println!("serving {desc}");
        let _ = std::io::stdout().flush();
    })?;
    if umup::util::signal::drain_requested() {
        // the unix socket (if any) was unlinked when the listener
        // dropped inside serve(); the distinct code marks a drain
        eprintln!("serve: drained on signal; exiting");
        std::process::exit(umup::util::signal::EXIT_DRAINED);
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn in_process_backend(sessions: usize) -> Result<std::sync::Arc<dyn umup::engine::Backend>> {
    Ok(std::sync::Arc::new(umup::engine::XlaBackend::new(sessions)))
}

#[cfg(not(feature = "xla"))]
fn in_process_backend(_sessions: usize) -> Result<std::sync::Arc<dyn umup::engine::Backend>> {
    bail!(
        "`serve --backend in-process` needs the XLA runtime; rebuild without \
         --no-default-features (or serve an out-of-process backend)"
    )
}

/// `repro ctl <verb>`: one RPC against a live `repro serve` daemon.
/// Prints the verb's JSON result on stdout; server-side errors become
/// a non-zero exit.  `ctl watch` is the exception: it subscribes to
/// the daemon's `events` stream and prints one JSONL envelope per
/// event until the daemon exits.
fn ctl_cmd(args: &Args) -> Result<()> {
    use std::io::BufReader;

    use umup::engine::backend::wire;
    use umup::engine::Endpoint;
    use umup::util::Json;

    const USAGE: &str = "usage: repro ctl <submit|status|cancel|cache-stats|watch|shutdown> \
                         --addr HOST:PORT|unix:/path [--jobs FILE] [--sweep N] \
                         [--timeout SECS] [--token SECRET]";
    let verb = args.positional.get(1).map(String::as_str).unwrap_or("");
    let params = match verb {
        "submit" => {
            let path = args
                .flags
                .get("jobs")
                .context("ctl submit needs --jobs FILE (one wire job frame per line)")?;
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let mut jobs = Vec::new();
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                jobs.push(Json::parse(line).context("parsing --jobs line")?);
            }
            let mut m = BTreeMap::new();
            m.insert("jobs".to_string(), Json::Arr(jobs));
            Json::Obj(m)
        }
        "status" => match args.flags.get("sweep") {
            Some(s) => {
                let mut m = BTreeMap::new();
                m.insert(
                    "sweep".to_string(),
                    Json::Num(s.parse::<u64>().context("bad --sweep")? as f64),
                );
                Json::Obj(m)
            }
            None => Json::Obj(BTreeMap::new()),
        },
        "cancel" => {
            let s: u64 = args.get("sweep", "").parse().context("ctl cancel needs --sweep N")?;
            let mut m = BTreeMap::new();
            m.insert("sweep".to_string(), Json::Num(s as f64));
            Json::Obj(m)
        }
        "cache-stats" | "shutdown" | "watch" => Json::Obj(BTreeMap::new()),
        other => bail!("unknown ctl verb {other:?}\n{USAGE}"),
    };
    let addr = match args.flags.get("addr") {
        Some(a) => a.clone(),
        None => bail!("ctl needs --addr (the serve daemon's endpoint)\n{USAGE}"),
    };
    let ep = Endpoint::parse(&addr).context("bad --addr")?;
    // --timeout SECS (default 30) bounds the dial and every read: a
    // wedged daemon becomes a pointed error instead of a hung ctl.
    // `watch` tails an unbounded stream, so it only gets a deadline
    // when one is passed explicitly; --timeout 0 disables the bound.
    let timeout_secs: u64 = args.get("timeout", "30").parse().context("bad --timeout")?;
    let timeout = if timeout_secs == 0 || (verb == "watch" && !args.has("timeout")) {
        None
    } else {
        Some(std::time::Duration::from_secs(timeout_secs))
    };
    let deadline_hint = |e: anyhow::Error| {
        let timed_out = e.chain().any(|c| {
            c.downcast_ref::<std::io::Error>().map_or(false, |io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                )
            })
        });
        if timed_out {
            e.context(format!(
                "no reply from {addr} within {timeout_secs}s — the daemon may be wedged \
                 or the address wrong; raise --timeout (0 disables the deadline)"
            ))
        } else {
            e
        }
    };
    let (r, mut w) = ep.connect_with_deadline(timeout).map_err(&deadline_hint)?;
    let mut r = BufReader::new(r);
    let hello = wire::read_frame(&mut r)
        .map_err(&deadline_hint)?
        .context("server hung up before its hello frame")?;
    // a worker socket here fails with the cross-wiring hint from wire.rs
    wire::check_serve_hello(&hello)?;
    // a token-armed daemon wants the shared secret before any RPC
    if wire::hello_advertises_auth(&hello) {
        let token = token_flag(args).context(
            "this daemon requires a shared-secret token — pass --token or set \
             UMUP_TOKEN to match the one `repro serve` was started with",
        )?;
        wire::write_frame(&mut w, &wire::token_frame(&token))?;
    }
    // `watch` is the tailing client of the daemon's `events` stream
    // verb: print each event envelope as it arrives, until the daemon
    // exits (EOF) or the stream errors
    if verb == "watch" {
        wire::write_frame(&mut w, &wire::rpc_request_line(1, "events", &params))?;
        while let Some(line) = wire::read_frame(&mut r).map_err(&deadline_hint)? {
            match wire::decode_rpc_reply(&line)? {
                wire::RpcReply::Ok { result, .. } => println!("{}", result.dump()),
                wire::RpcReply::Err { error, .. } => bail!("server error: {error}"),
            }
        }
        return Ok(());
    }
    wire::write_frame(&mut w, &wire::rpc_request_line(1, verb, &params))?;
    let line = wire::read_frame(&mut r)
        .map_err(&deadline_hint)?
        .context("server hung up before replying")?;
    match wire::decode_rpc_reply(&line)? {
        wire::RpcReply::Ok { id, result } => {
            if id != 1 {
                bail!("server replied to request {id}, expected 1 (protocol desync)");
            }
            println!("{}", result.dump());
            Ok(())
        }
        wire::RpcReply::Err { error, .. } => bail!("server error: {error}"),
    }
}

/// `repro chaos --listen A --upstream B [--faults SPEC]`: the
/// deterministic fault-injecting proxy (see
/// `umup::engine::backend::chaos`).  Sits between an engine and a real
/// `repro worker --listen`, forwarding the wire protocol verbatim
/// except for the faults the plan names by global reply ordinal.  The
/// bound endpoint is announced as one `listening <addr>` line on
/// stdout — the same format as `worker --listen`, so harnesses reuse
/// one spawn-and-read-back helper for both.
fn chaos_cmd(args: &Args) -> Result<()> {
    use std::io::Write as _;

    use umup::engine::{Endpoint, FaultPlan, Listener};

    let listen = args
        .flags
        .get("listen")
        .context("chaos needs --listen HOST:PORT|unix:/path (the endpoint engines dial)")?;
    let upstream = args.flags.get("upstream").context(
        "chaos needs --upstream HOST:PORT|unix:/path (the real worker behind the proxy)",
    )?;
    let spec = match args.flags.get("faults") {
        Some(s) => s.clone(),
        None => std::env::var("UMUP_FAULTS").unwrap_or_default(),
    };
    let plan = FaultPlan::parse(&spec).context("bad --faults/UMUP_FAULTS")?;
    if plan.is_passthrough() {
        eprintln!("chaos: empty fault plan — acting as a pure passthrough proxy");
    }
    let upstream = Endpoint::parse(upstream).context("bad --upstream endpoint")?;
    let listener = Listener::bind(&Endpoint::parse(listen).context("bad --listen endpoint")?)?;
    println!("listening {}", listener.local_desc());
    std::io::stdout().flush()?;
    umup::engine::backend::chaos::run_proxy(listener, upstream, plan)
}

#[cfg(not(feature = "xla"))]
fn check(_args: &Args) -> Result<()> {
    bail!("`repro check` needs the XLA runtime; rebuild without --no-default-features")
}

#[cfg(not(feature = "xla"))]
fn train(_args: &Args) -> Result<()> {
    bail!("`repro train` needs the XLA runtime; rebuild without --no-default-features")
}

#[cfg(not(feature = "xla"))]
fn exp(_args: &Args) -> Result<()> {
    bail!("`repro exp` needs the XLA runtime; rebuild without --no-default-features")
}

#[cfg(not(feature = "xla"))]
fn drive_cmd(_args: &Args) -> Result<()> {
    bail!(
        "`repro drive` spawns `repro exp` shard processes, which need the XLA \
         runtime; rebuild without --no-default-features"
    )
}

/// Run-cache lifecycle: `repro cache <stats|gc|compact>` (works without
/// XLA — cache segments are plain JSONL).
fn cache_cmd(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("stats");
    let dir = PathBuf::from(args.get("cache-dir", "results/run-cache"));
    match sub {
        "stats" => {
            let st = stats(&dir)?;
            println!("run cache at {}:", dir.display());
            if st.segments.is_empty() {
                println!("  (no segments)");
                return Ok(());
            }
            for seg in &st.segments {
                println!(
                    "  {:24} {:6} entries  {:3} corrupt  {:9} bytes",
                    seg.name, seg.entries, seg.corrupt, seg.bytes
                );
            }
            println!(
                "  total: {} entries, {} unique keys, {} cross-segment duplicates, \
                 {} corrupt lines, {} bytes",
                st.total_entries,
                st.unique_keys,
                st.duplicate_keys,
                st.corrupt_lines,
                st.total_bytes
            );
            for (manifest, n) in &st.per_manifest {
                println!("  manifest {manifest:24} {n} runs");
            }
            if let (Some(lo), Some(hi)) = (st.oldest_ts, st.newest_ts) {
                println!("  recorded between unix ts {lo} and {hi}");
            }
            Ok(())
        }
        "gc" => {
            let opts = GcOptions {
                older_than: match args.flags.get("older-than") {
                    Some(s) => Some(parse_duration(s).context("bad --older-than")?),
                    None => None,
                },
                manifest: args.flags.get("manifest").cloned(),
                max_bytes: match args.flags.get("max-bytes") {
                    Some(s) => Some(parse_bytes(s).context("bad --max-bytes")?),
                    None => None,
                },
                dry_run: args.has("dry-run"),
                chunk_entries: match args.flags.get("chunk-entries") {
                    Some(s) => Some(s.parse().context("bad --chunk-entries")?),
                    None => None,
                },
            };
            let rep = gc(&dir, &opts)?;
            let verb = if opts.dry_run { "would keep" } else { "kept" };
            println!(
                "gc {}: scanned {} entries in {} segments; {verb} {}, pruned {}, \
                 evicted {} over budget, dropped {} duplicates + {} corrupt lines \
                 ({} -> {} bytes)",
                dir.display(),
                rep.scanned,
                rep.segments_before,
                rep.kept,
                rep.pruned,
                rep.evicted,
                rep.deduped,
                rep.corrupt_dropped,
                rep.bytes_before,
                rep.bytes_after
            );
            Ok(())
        }
        "compact" => {
            let compactor = Compactor::new(&dir);
            let max_steps: usize =
                args.get("max-steps", "0").parse().context("bad --max-steps")?;
            let mut merges = 0usize;
            loop {
                if max_steps != 0 && merges >= max_steps {
                    break;
                }
                match compactor.step()? {
                    Some(r) => {
                        merges += 1;
                        println!(
                            "compact: merged {} segments into {} ({} entries, {} duplicate \
                             lines + {} corrupt dropped, {} -> {} bytes)",
                            r.inputs.len(),
                            r.output,
                            r.entries,
                            r.deduped,
                            r.corrupt_dropped,
                            r.bytes_in,
                            r.bytes_out
                        );
                    }
                    None => break,
                }
            }
            if merges == 0 {
                println!(
                    "compact {}: nothing to merge (no group of similar-sized segments \
                     was free to lock)",
                    dir.display()
                );
            } else {
                println!("compact {}: {merges} tier merge(s) done", dir.display());
            }
            Ok(())
        }
        other => bail!("unknown cache subcommand {other:?} (expected stats, gc, or compact)"),
    }
}

fn report(args: &Args) -> Result<()> {
    let out = args.get("out", "results");
    let mut combined = String::from("# Collated experiment reports\n\n");
    let mut found = 0;
    if let Ok(entries) = std::fs::read_dir(&out) {
        let mut dirs: Vec<_> = entries.flatten().map(|e| e.path()).collect();
        dirs.sort();
        for d in dirs {
            let f = d.join("summary.md");
            if f.exists() {
                combined.push_str(&std::fs::read_to_string(&f)?);
                combined.push('\n');
                found += 1;
            }
        }
    }
    std::fs::write(Path::new(&out).join("REPORT.md"), &combined)?;
    println!("collated {found} summaries into {out}/REPORT.md");
    Ok(())
}

fn corpus_info(args: &Args) -> Result<()> {
    let vocab: usize = args.get("vocab", "256").parse()?;
    let c = Corpus::generate(CorpusConfig { vocab, ..Default::default() });
    println!("vocab {vocab}: tokens={}", c.tokens.len());
    println!(
        "unigram entropy  H1 = {:.4} nats ({:.3} bits)",
        c.unigram_entropy(),
        c.unigram_entropy() / 2f64.ln()
    );
    println!(
        "bigram  entropy  H2 = {:.4} nats ({:.3} bits)",
        c.bigram_entropy(),
        c.bigram_entropy() / 2f64.ln()
    );
    println!("train/valid = {}/{}", c.train_slice().len(), c.valid_slice().len());
    Ok(())
}
