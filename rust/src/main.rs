//! `repro` — the u-μP coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   rules                       print the Table 1/2/11 rule evaluation
//!   check                       validate every artifact + manifest
//!   train [opts]                one training run
//!   exp <id|all|list> [--quick] reproduce a paper figure/table
//!   report                      collate results/ into EXPERIMENTS-style md
//!
//! Dependency-light by design (offline env): argument parsing is the
//! in-tree `Args` helper below.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use umup::coordinator::{list_experiments, run_experiment, ExpContext};
use umup::data::{Corpus, CorpusConfig};
use umup::engine::{Engine, EngineConfig};
use umup::parametrization::{Abc, HpSet, Parametrization, Precision, Scheme};
use umup::runtime::Registry;
use umup::train::{RunConfig, Schedule};

/// Minimal flag parser: positional args + `--key value` + `--flag`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap(),
                    _ => "true".to_string(),
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// The engine's run-cache flags, shared by `train` and `exp`.
    fn cache_opts(&self) -> (Option<PathBuf>, bool) {
        (self.flags.get("cache-dir").map(PathBuf::from), self.has("resume"))
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "rules" => rules(&args),
        "check" => check(&args),
        "train" => train(&args),
        "exp" => exp(&args),
        "report" => report(&args),
        "corpus" => corpus_info(&args),
        _ => {
            println!(
                "repro — u-muP reproduction coordinator\n\n\
                 usage: repro <command> [--flags]\n\n\
                 commands:\n\
                 \x20 rules   [--scheme umup] [--width 256] [--depth 4]   print A/B/C per tensor\n\
                 \x20 check   [--artifacts artifacts]                     validate artifacts\n\
                 \x20 train   [--scheme umup] [--width 64] [--depth 4] [--batch 16]\n\
                 \x20         [--lr 0.5] [--steps 256] [--precision fp32|fp8|fp8-paper] [--seed 7]\n\
                 \x20 exp     <id|all|list> [--quick] [--workers N]       reproduce figures/tables\n\
                 \x20\n\
                 \x20 train/exp also take [--cache-dir DIR] [--resume]:  --cache-dir records\n\
                 \x20 completed runs to DIR/runs.jsonl (content-addressed; identical configs\n\
                 \x20 dedupe); --resume reloads them so a restarted sweep skips finished jobs\n\
                 \x20 (without --resume an existing cache file is truncated)\n\
                 \x20 report  [--out results]                             collate summaries\n\
                 \x20 corpus  [--vocab 256]                               corpus statistics\n"
            );
            Ok(())
        }
    }
}

/// Print the evaluated parametrization table (Tables 1/2/11 made concrete).
fn rules(args: &Args) -> Result<()> {
    let scheme = Scheme::parse(&args.get("scheme", "umup")).context("bad --scheme")?;
    let width: usize = args.get("width", "256").parse()?;
    let depth: usize = args.get("depth", "4").parse()?;
    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    // use the manifest at the requested shape if present, else any other
    // as the tensor-name template
    let man = reg
        .find(width, depth, 16)
        .or_else(|_| reg.manifest("w64_d4_b16_t64_v256"))?;
    let p = Parametrization::new(scheme);
    let hp = HpSet::default();
    println!("{} rules at width {width}, depth {depth} (eta=1):", scheme.name());
    println!(
        "{:24} {:>12} {:>12} {:>12} {:>12}",
        "tensor", "A (param)", "A bwd", "B (init)", "C (lr)"
    );
    for t in &man.tensors {
        let abc = Abc::of(&p, &hp, t, width, depth);
        println!(
            "{:24} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            t.name, abc.a, abc.a_bwd, abc.b, abc.c
        );
    }
    Ok(())
}

/// Validate all artifacts: manifests parse, HLO compiles, one step runs.
fn check(args: &Args) -> Result<()> {
    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    let engine = Engine::new(EngineConfig { workers: 1, ..EngineConfig::default() })?;
    for man in reg.manifests() {
        print!("{:28}", man.name);
        let session = engine.session(man)?;
        let vecs = umup::parametrization::RuntimeVectors::build(
            man,
            &Parametrization::new(Scheme::Umup),
            &HpSet::with_eta(0.5),
            Precision::Fp32,
        )?;
        let mut ts =
            session.init(0, &vecs.init_std, &vecs.scales, &vecs.lr_scale, &vecs.qmask)?;
        let tokens: Vec<i32> = (0..man.spec.batch * (man.spec.seq + 1))
            .map(|i| (i % man.spec.vocab) as i32)
            .collect();
        let hyp = umup::train::AdamConfig::default().hyp(0.1, 1);
        let loss = session.step(&mut ts, &tokens, &hyp)?;
        if !loss.is_finite() {
            bail!("{}: non-finite loss", man.name);
        }
        println!(" ok   n_params={:9}  step loss={loss:.4}", man.n_params);
    }
    println!("all artifacts OK");
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let scheme = Scheme::parse(&args.get("scheme", "umup")).context("bad --scheme")?;
    let width: usize = args.get("width", "64").parse()?;
    let depth: usize = args.get("depth", "4").parse()?;
    let batch: usize = args.get("batch", "16").parse()?;
    let steps: u64 = args.get("steps", "256").parse()?;
    let lr: f64 =
        args.get("lr", if scheme == Scheme::Umup { "0.5" } else { "0.005" }).parse()?;
    let precision =
        Precision::parse(&args.get("precision", "fp32")).context("bad --precision")?;
    let reg = Registry::open(Path::new(&args.get("artifacts", "artifacts")))?;
    let man = reg.find(width, depth, batch)?;
    let corpus = Arc::new(Corpus::generate(CorpusConfig {
        vocab: man.spec.vocab,
        n_tokens: 2_000_000,
        ..Default::default()
    }));
    let (cache_dir, resume) = args.cache_opts();
    let engine = Engine::new(EngineConfig {
        workers: 1,
        cache_dir,
        resume,
        ..EngineConfig::default()
    })?;
    let mut cfg = RunConfig::quick(
        &format!("{}-{}", scheme.name(), precision.name()),
        Parametrization::new(scheme),
        HpSet::with_eta(lr),
        steps,
    );
    cfg.precision = precision;
    cfg.seed = args.get("seed", "7").parse()?;
    cfg.schedule = Schedule::standard(lr, steps, (steps / 4).max(1));
    println!("training {} on {} for {steps} steps (lr {lr})", cfg.label, man.name);
    let rec = engine.run_single(&man, &corpus, cfg)?.record;
    for &(t, l) in &rec.train_curve {
        println!("step {t:6}  train loss {l:.4}");
    }
    let cached = if engine.stats().cache_hits > 0 { "  (from run cache)" } else { "" };
    println!(
        "final valid loss {:.4}  (diverged: {})  [{:.1}s]{cached}",
        rec.final_valid_loss, rec.diverged, rec.wall_seconds
    );
    Ok(())
}

fn exp(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("list");
    if id == "list" {
        println!("{}", list_experiments());
        return Ok(());
    }
    let workers: usize = args.get("workers", "4").parse()?;
    let (cache_dir, resume) = args.cache_opts();
    let ctx = ExpContext::with_cache(
        &args.get("artifacts", "artifacts"),
        &args.get("out", "results"),
        args.has("quick"),
        workers,
        cache_dir,
        resume,
    )?;
    let md = run_experiment(&ctx, id)?;
    println!("{md}");
    let s = ctx.engine.stats();
    println!(
        "engine: {} runs executed, {} cache hits, {} deduped, {} failed ({} records cached)",
        s.executed,
        s.cache_hits,
        s.deduped,
        s.failed,
        ctx.engine.cache_len()
    );
    Ok(())
}

fn report(args: &Args) -> Result<()> {
    let out = args.get("out", "results");
    let mut combined = String::from("# Collated experiment reports\n\n");
    let mut found = 0;
    if let Ok(entries) = std::fs::read_dir(&out) {
        let mut dirs: Vec<_> = entries.flatten().map(|e| e.path()).collect();
        dirs.sort();
        for d in dirs {
            let f = d.join("summary.md");
            if f.exists() {
                combined.push_str(&std::fs::read_to_string(&f)?);
                combined.push('\n');
                found += 1;
            }
        }
    }
    std::fs::write(Path::new(&out).join("REPORT.md"), &combined)?;
    println!("collated {found} summaries into {out}/REPORT.md");
    Ok(())
}

fn corpus_info(args: &Args) -> Result<()> {
    let vocab: usize = args.get("vocab", "256").parse()?;
    let c = Corpus::generate(CorpusConfig { vocab, ..Default::default() });
    println!("vocab {vocab}: tokens={}", c.tokens.len());
    println!(
        "unigram entropy  H1 = {:.4} nats ({:.3} bits)",
        c.unigram_entropy(),
        c.unigram_entropy() / 2f64.ln()
    );
    println!(
        "bigram  entropy  H2 = {:.4} nats ({:.3} bits)",
        c.bigram_entropy(),
        c.bigram_entropy() / 2f64.ln()
    );
    println!("train/valid = {}/{}", c.train_slice().len(), c.valid_slice().len());
    Ok(())
}
