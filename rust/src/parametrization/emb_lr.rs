//! The embedding LR rule (paper §4.4, Fig 3).
//!
//! μP's Table 1 gives the input (embedding) weight a *constant* Adam LR
//! rule (c_emb = 1).  The paper shows this transfers poorly across width
//! and replaces it with c_emb = 1/sqrt(fan-out) for u-μP; Fig 3 compares
//! the two as sqrt(base-width/width) scaling of η̂_emb under μP.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmbLrRule {
    /// c_emb = 1 (Tensor Programs V / Table 1).
    Constant,
    /// c_emb = 1/sqrt(fan-out) (u-μP, §4.4). Under μP this is expressed
    /// relative to the base shape: sqrt(base-width/width).
    InvSqrtFanOut,
}

impl EmbLrRule {
    /// LR factor for the embedding tensor.
    ///
    /// For u-μP the caller passes `base_ratio = 1/fan_out` so the factor
    /// is the absolute 1/sqrt(fan-out); for μP it passes
    /// base_width/width so the factor is sqrt(base-width/width) (the Fig
    /// 3 form, equal to 1 at the base shape).
    pub fn factor(&self, _fan_out: f64, base_ratio: f64) -> f64 {
        match self {
            EmbLrRule::Constant => 1.0,
            EmbLrRule::InvSqrtFanOut => base_ratio.sqrt(),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "constant" | "const" => EmbLrRule::Constant,
            "sqrt" | "inv-sqrt-fan-out" => EmbLrRule::InvSqrtFanOut,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        assert_eq!(EmbLrRule::Constant.factor(4096.0, 0.5), 1.0);
    }

    #[test]
    fn sqrt_rule_halves_per_4x_width() {
        // fig 3: width 256 -> 1024 at base 256 gives sqrt(1/4) = 1/2
        let f = EmbLrRule::InvSqrtFanOut.factor(1024.0, 256.0 / 1024.0);
        assert!((f - 0.5).abs() < 1e-12);
        // absolute u-μP form
        let f = EmbLrRule::InvSqrtFanOut.factor(1024.0, 1.0 / 1024.0);
        assert!((f - 1.0 / 32.0).abs() < 1e-12);
    }
}
