//! S3 — the Unit Scaling rule compendium (paper Table 8, Appendix B).
//!
//! Closed-form and empirical scaling factors that make each op emit
//! unit-RMS outputs given unit-RMS inputs.  The coordinator folds these
//! into the runtime `scales` vector; the L2 graph just multiplies.

/// log-space interpolation used by the empirical models (Appendix B):
/// exp(a·ln(upper) + (1-a)·ln(lower)).
pub fn log_interpolate(a: f64, upper: f64, lower: f64) -> f64 {
    (a * upper.ln() + (1.0 - a) * lower.ln()).exp()
}

/// Unit-scaled matmul factors (§E.2 and Table 8):
/// output 1/sqrt(fan-in), grad-input 1/sqrt(fan-out),
/// grad-weight 1/sqrt(batch) where batch counts the contracted rows
/// (tokens = batch·seq for our activations).
pub fn matmul_scales(fan_in: usize, fan_out: usize, batch_rows: usize) -> (f64, f64, f64) {
    (
        1.0 / (fan_in as f64).sqrt(),
        1.0 / (fan_out as f64).sqrt(),
        1.0 / (batch_rows as f64).sqrt(),
    )
}

/// Empirical scale model of causal dot-product attention (Table 8):
/// sigma(attention) = log_interpolate(1/(1 + 4·d_head/α²), 1, sqrt(ln s / s));
/// the op divides by this, so the returned value is the *multiplier* 1/σ.
pub fn attention_out_scale(alpha_attn: f64, d_head: usize, seq: usize) -> f64 {
    let a = 1.0 / (1.0 + 4.0 * d_head as f64 / (alpha_attn * alpha_attn));
    let s = seq as f64;
    let sigma = log_interpolate(a, 1.0, (s.ln() / s).sqrt());
    1.0 / sigma
}

/// Empirical scale model of the gated SiLU (Table 8):
/// sigma = log_interpolate(1/(1 + 1/α²), 1/sqrt(2), 1/2); returns 1/σ.
pub fn gated_silu_scale(alpha_ffn_act: f64) -> f64 {
    let a = 1.0 / (1.0 + 1.0 / (alpha_ffn_act * alpha_ffn_act));
    let sigma = log_interpolate(a, 1.0 / 2f64.sqrt(), 0.5);
    1.0 / sigma
}

/// Unit-scaled softmax cross-entropy backward factor β = s/sqrt(s-1)
/// (Table 8), boosting the ~1/s-sized xent gradients to unit scale.
pub fn xent_grad_scale(vocab: usize) -> f64 {
    let s = vocab as f64;
    s / (s - 1.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_interpolate_endpoints() {
        assert!((log_interpolate(1.0, 3.0, 0.1) - 3.0).abs() < 1e-12);
        assert!((log_interpolate(0.0, 3.0, 0.1) - 0.1).abs() < 1e-12);
        // geometric midpoint at a = 0.5
        let mid = log_interpolate(0.5, 4.0, 1.0);
        assert!((mid - 2.0).abs() < 1e-12);
    }

    #[test]
    fn matmul_rule_matches_e2() {
        // §E.2: sqrt(d_fan_in)·σ_W·σ_X ⇒ factor 1/sqrt(fan-in)
        let (out, gx, gw) = matmul_scales(256, 1024, 64 * 64);
        assert!((out - 1.0 / 16.0).abs() < 1e-12);
        assert!((gx - 1.0 / 32.0).abs() < 1e-12);
        assert!((gw - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn attention_scale_limits() {
        // α → 0 (uniform attention / running mean): σ → sqrt(ln s / s) < 1,
        // so the multiplier is > 1
        let s = attention_out_scale(1e-6, 16, 64);
        let expect = 1.0 / ((64f64.ln()) / 64.0).sqrt();
        assert!((s - expect).abs() / expect < 1e-3);
        // α → ∞ (one-hot attention): σ → 1
        let s = attention_out_scale(1e6, 16, 64);
        assert!((s - 1.0).abs() < 1e-3);
        // monotone in α
        assert!(attention_out_scale(0.5, 16, 64) > attention_out_scale(4.0, 16, 64));
    }

    #[test]
    fn silu_scale_limits() {
        // α → ∞: gate saturates to |x_gate| ⇒ σ → 1/sqrt(2), mult sqrt(2)
        assert!((gated_silu_scale(1e8) - 2f64.sqrt()).abs() < 1e-3);
        // α → 0: sigmoid → 1/2 ⇒ σ → 1/2, mult 2
        assert!((gated_silu_scale(1e-8) - 2.0).abs() < 1e-3);
    }

    #[test]
    fn xent_scale() {
        let b = xent_grad_scale(256);
        assert!((b - 256.0 / 255f64.sqrt()).abs() < 1e-12);
    }
}
