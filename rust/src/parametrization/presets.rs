//! S12 — training-setup presets and baselines (paper §3.1, Fig 2, Table 6).
//!
//! Fig 2's three setups differ in *training configuration*, not in the
//! μP rules: (a) the Tensor Programs V setup (constant LR, plain Adam,
//! trainable norms, overfitting regime), (b) the standard Llama setup
//! (cosine LR, coupled AdamW, trainable norms), (c) the fixed setup
//! (non-parametric norms + independent weight decay) that restores
//! μTransfer.  SP presets carry the Pythia init + Llama-3 LR heuristic
//! used as the paper's large-scale baseline (§5.5 / Fig 18).

use crate::train::{AdamConfig, Schedule, ScheduleKind};

use super::{Parametrization, Scheme};

/// Which Fig 2 training setup to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetupFlavor {
    /// (a) Tensor Programs V: constant LR, plain Adam, trainable norms.
    TensorPrograms5,
    /// (b) standard Llama: cosine LR, *coupled* AdamW, trainable norms.
    LlamaStandard,
    /// (c) Llama + stability fixes: non-parametric norms, independent WD.
    LlamaFixed,
}

impl SetupFlavor {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "tp5" | "tensorprograms5" => SetupFlavor::TensorPrograms5,
            "llama" | "llama-standard" => SetupFlavor::LlamaStandard,
            "fixed" | "llama-fixed" => SetupFlavor::LlamaFixed,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SetupFlavor::TensorPrograms5 => "tp5",
            SetupFlavor::LlamaStandard => "llama-standard",
            SetupFlavor::LlamaFixed => "llama-fixed",
        }
    }

    /// Does this setup use trainable norm gains? (Selects the `_tn`
    /// artifact variant.)
    pub fn trainable_norms(&self) -> bool {
        !matches!(self, SetupFlavor::LlamaFixed)
    }

    pub fn adam(&self) -> AdamConfig {
        match self {
            SetupFlavor::TensorPrograms5 => AdamConfig::plain_adam(),
            SetupFlavor::LlamaStandard => AdamConfig::coupled(),
            SetupFlavor::LlamaFixed => AdamConfig::default(), // independent
        }
    }

    pub fn schedule(&self, peak_lr: f64, steps: u64, warmup: u64) -> Schedule {
        match self {
            SetupFlavor::TensorPrograms5 => Schedule {
                kind: ScheduleKind::Constant,
                peak_lr,
                warmup_steps: 0,
                total_steps: steps,
            },
            _ => Schedule::standard(peak_lr, steps, warmup),
        }
    }

    /// TP5 trained many epochs on tiny data; emulated by shrinking the
    /// effective corpus so the sampler revisits data (overfit regime).
    pub fn corpus_fraction(&self) -> f64 {
        match self {
            SetupFlavor::TensorPrograms5 => 0.02,
            _ => 1.0,
        }
    }
}

/// A named (scheme, setup) pair with the SP transfer heuristic.
#[derive(Debug, Clone, Copy)]
pub struct Preset {
    pub parametrization: Parametrization,
    pub setup: SetupFlavor,
}

impl Preset {
    pub fn new(scheme: Scheme, setup: SetupFlavor) -> Preset {
        Preset { parametrization: Parametrization::new(scheme), setup }
    }

    /// The η actually used at `width` when transferring a proxy LR found
    /// at `base_width`.  μP/u-μP transfer η as-is (that is the point);
    /// SP uses the Llama-3 heuristic η·base_width/width (§A.7).
    pub fn transfer_lr(&self, proxy_eta: f64, base_width: usize, width: usize) -> f64 {
        match self.parametrization.scheme {
            Scheme::Sp => proxy_eta * base_width as f64 / width as f64,
            _ => proxy_eta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flavors_differ_as_in_table6() {
        let tp5 = SetupFlavor::TensorPrograms5;
        assert!(matches!(tp5.schedule(1.0, 10, 2).kind, ScheduleKind::Constant));
        assert_eq!(tp5.adam().wd_indep, 0.0);
        assert_eq!(tp5.adam().wd_coupled, 0.0);
        assert!(tp5.trainable_norms());

        let llama = SetupFlavor::LlamaStandard;
        assert!(matches!(llama.schedule(1.0, 10, 2).kind, ScheduleKind::CosineTo(_)));
        assert!(llama.adam().wd_coupled > 0.0);
        assert!(llama.trainable_norms());

        let fixed = SetupFlavor::LlamaFixed;
        assert!(fixed.adam().wd_indep > 0.0);
        assert_eq!(fixed.adam().wd_coupled, 0.0);
        assert!(!fixed.trainable_norms());
    }

    #[test]
    fn sp_lr_heuristic() {
        let p = Preset::new(Scheme::Sp, SetupFlavor::LlamaFixed);
        assert!((p.transfer_lr(0.01, 64, 256) - 0.0025).abs() < 1e-12);
        let u = Preset::new(Scheme::Umup, SetupFlavor::LlamaFixed);
        assert_eq!(u.transfer_lr(0.01, 64, 256), 0.01);
    }
}
