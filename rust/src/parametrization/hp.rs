//! μTransferable hyperparameters (paper Table 3 / Table 5).
//!
//! One struct carries the union of the SP, μP and u-μP HP sets; each
//! scheme reads the fields it defines and ignores the rest.  All values
//! are multipliers with default 1 (the u-μP "drop the HP" default), so an
//! LR-only sweep leaves everything else at unit scale — the property that
//! makes independent search work (§4.5).

/// Union of the schemes' μTransferable HP sets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HpSet {
    /// Global learning rate η (always swept).
    pub eta: f64,
    /// μP/SP: global initialization multiplier σ_init.
    pub sigma_init: f64,
    /// μP: embedding forward multiplier α_emb.
    pub alpha_emb: f64,
    /// μP: embedding LR multiplier η̂_emb.
    pub eta_emb_hat: f64,
    /// μP & u-μP: attention-softmax multiplier α_attn(-softmax).
    pub alpha_attn: f64,
    /// μP & u-μP: output/head multiplier α_out(put).
    pub alpha_out: f64,
    /// u-μP: FFN activation multiplier α_ffn-act.
    pub alpha_ffn_act: f64,
    /// u-μP: residual contribution α_res.
    pub alpha_res: f64,
    /// u-μP: attention/FFN residual ratio α_res-attn-ratio.
    pub alpha_res_attn_ratio: f64,
    /// u-μP: loss softmax (inverse) temperature α_loss-softmax.
    pub alpha_loss: f64,
}

impl Default for HpSet {
    fn default() -> Self {
        HpSet {
            eta: 1.0,
            sigma_init: 1.0,
            alpha_emb: 1.0,
            eta_emb_hat: 1.0,
            alpha_attn: 1.0,
            alpha_out: 1.0,
            alpha_ffn_act: 1.0,
            alpha_res: 1.0,
            alpha_res_attn_ratio: 1.0,
            alpha_loss: 1.0,
        }
    }
}

/// Stable field names (used by sweep spaces, CSV output, CLI flags).
pub const HP_NAMES: [&str; 10] = [
    "eta",
    "sigma_init",
    "alpha_emb",
    "eta_emb_hat",
    "alpha_attn",
    "alpha_out",
    "alpha_ffn_act",
    "alpha_res",
    "alpha_res_attn_ratio",
    "alpha_loss",
];

impl HpSet {
    pub fn with_eta(eta: f64) -> Self {
        HpSet { eta, ..Default::default() }
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        Some(match name {
            "eta" => self.eta,
            "sigma_init" => self.sigma_init,
            "alpha_emb" => self.alpha_emb,
            "eta_emb_hat" => self.eta_emb_hat,
            "alpha_attn" => self.alpha_attn,
            "alpha_out" => self.alpha_out,
            "alpha_ffn_act" => self.alpha_ffn_act,
            "alpha_res" => self.alpha_res,
            "alpha_res_attn_ratio" => self.alpha_res_attn_ratio,
            "alpha_loss" => self.alpha_loss,
            _ => return None,
        })
    }

    pub fn set(&mut self, name: &str, v: f64) -> bool {
        match name {
            "eta" => self.eta = v,
            "sigma_init" => self.sigma_init = v,
            "alpha_emb" => self.alpha_emb = v,
            "eta_emb_hat" => self.eta_emb_hat = v,
            "alpha_attn" => self.alpha_attn = v,
            "alpha_out" => self.alpha_out = v,
            "alpha_ffn_act" => self.alpha_ffn_act = v,
            "alpha_res" => self.alpha_res = v,
            "alpha_res_attn_ratio" => self.alpha_res_attn_ratio = v,
            "alpha_loss" => self.alpha_loss = v,
            _ => return false,
        }
        true
    }

    /// The non-LR HP names swept per scheme (paper Table 3 *extended*).
    pub fn sweepable(scheme: super::Scheme) -> &'static [&'static str] {
        use super::Scheme::*;
        match scheme {
            Sp => &[],
            Mup | Intermediate => {
                &["sigma_init", "alpha_emb", "eta_emb_hat", "alpha_attn", "alpha_out"]
            }
            Umup => &[
                "alpha_attn",
                "alpha_out",
                "alpha_ffn_act",
                "alpha_res",
                "alpha_res_attn_ratio",
                "alpha_loss",
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_round_trip() {
        let mut hp = HpSet::default();
        for (i, name) in HP_NAMES.iter().enumerate() {
            assert!(hp.set(name, 2.0 + i as f64));
        }
        for (i, name) in HP_NAMES.iter().enumerate() {
            assert_eq!(hp.get(name), Some(2.0 + i as f64));
        }
        assert_eq!(hp.get("nope"), None);
        assert!(!hp.set("nope", 1.0));
    }

    #[test]
    fn defaults_are_unit() {
        let hp = HpSet::default();
        for name in HP_NAMES {
            assert_eq!(hp.get(name), Some(1.0), "{name}");
        }
    }
}
