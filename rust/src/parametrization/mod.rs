//! S2+S3 — The abc-parametrization engine: the paper's contribution as a
//! first-class coordinator feature.
//!
//! Given an artifact manifest (tensor shapes, fan-in/out, scale-site
//! table) plus a scheme (SP / μP / intermediate Table 11 / u-μP) and a set
//! of μTransferable HPs, [`assemble::RuntimeVectors::build`] evaluates
//! Tables 1, 2, 8 and 11 and Appendices F/G/H of the paper into the three
//! runtime vectors the compiled graph consumes:
//!
//! * `scales[n_sites]` — every A_W forward multiplier, backward scale,
//!   op multiplier and residual coefficient;
//! * `init_std[n_tensors]` — every B_W;
//! * `lr_scale[n_tensors]` — every C_W / η (the per-tensor Adam LR rule).
//!
//! Because these are runtime inputs, one compiled artifact realizes every
//! parametrization and every HP point (DESIGN.md §2).

mod abc;
mod assemble;
mod emb_lr;
mod hp;
mod presets;
mod residual;
mod unit_scaling;

pub use abc::{Abc, Parametrization, Scheme};
pub use assemble::{Precision, RuntimeVectors};
pub use emb_lr::EmbLrRule;
pub use hp::{HpSet, HP_NAMES};
pub use presets::{Preset, SetupFlavor};
pub use residual::{mup_residual, plain_prenorm_skip_rms, umup_residual, ResidualCoeffs};
pub use unit_scaling::{
    attention_out_scale, gated_silu_scale, log_interpolate, matmul_scales, xent_grad_scale,
};
