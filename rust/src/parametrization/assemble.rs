//! Assemble the runtime vectors: evaluate the whole parametrization for a
//! given artifact into `scales` / `init_std` / `lr_scale` / `qmask`.
//!
//! This is where the paper's Tables 1/2/8/11, the residual τ-scheme and
//! the cut-edge constraints all land in one place (DESIGN.md §2).

use anyhow::Result;

use crate::runtime::Manifest;

use super::{
    attention_out_scale, gated_silu_scale, matmul_scales, mup_residual, umup_residual,
    xent_grad_scale, Abc, HpSet, Parametrization, Scheme,
};

/// FP8 execution mode: which quantization flags are raised (paper §4.2,
/// Fig 1c). The formats are baked into the graph (E4M3 fwd / E5M2 grad);
/// the mask only selects sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// No quantization: the FP32 reference (stand-in for BF16 at this
    /// scale — see DESIGN.md §4 substitutions).
    Fp32,
    /// Fig 1(c): naive `.to(float8)` on every matmul's inputs/weights
    /// (E4M3) and output gradients (E5M2), including critical tensors.
    Fp8Naive,
    /// §4.2 mixed-precision scheme: non-critical matmuls (q, k, v, gate,
    /// up) in FP8; critical ones (attn out-projection, FFN down, decoder
    /// head) kept in high precision.
    Fp8Paper,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fp32" | "bf16" => Precision::Fp32,
            "fp8" | "fp8-naive" => Precision::Fp8Naive,
            "fp8-paper" | "fp8-mixed" => Precision::Fp8Paper,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp8Naive => "fp8-naive",
            Precision::Fp8Paper => "fp8-paper",
        }
    }
}

/// The evaluated parametrization, ready to feed to the runtime.
#[derive(Debug, Clone)]
pub struct RuntimeVectors {
    pub scales: Vec<f32>,
    pub init_std: Vec<f32>,
    pub lr_scale: Vec<f32>,
    pub qmask: Vec<f32>,
}

impl RuntimeVectors {
    pub fn build(
        man: &Manifest,
        p: &Parametrization,
        hp: &HpSet,
        precision: Precision,
    ) -> Result<RuntimeVectors> {
        let width = man.spec.width;
        let depth = man.spec.depth;
        let seq = man.spec.seq;
        let vocab = man.spec.vocab;
        let d_head = man.spec.head_dim;
        let tokens = man.spec.batch * seq;
        let unit = p.scheme == Scheme::Umup;

        // ---------------- per-tensor A/B/C ----------------
        let mut init_std = Vec::with_capacity(man.tensors.len());
        let mut lr_scale = Vec::with_capacity(man.tensors.len());
        let mut abcs = Vec::with_capacity(man.tensors.len());
        for t in &man.tensors {
            let abc = Abc::of(p, hp, t, width, depth);
            init_std.push(abc.b as f32);
            // the global η is folded into C by Abc::of; the graph applies
            // lr·lr_scale so we divide the η back out and pass it in hyp.
            lr_scale.push((abc.c / hp.eta) as f32);
            abcs.push((t.name.clone(), abc));
        }
        let abc_of = |name: &str| -> Abc {
            abcs.iter().find(|(n, _)| n == name).map(|(_, a)| a.clone()).unwrap()
        };

        // ---------------- scale sites ----------------
        let mut scales = vec![0.0f32; man.n_scale_sites];
        let mut set = |name: String, v: f64| {
            let idx = *man
                .scale_sites
                .get(&name)
                .unwrap_or_else(|| panic!("missing scale site {name}"));
            scales[idx] = v as f32;
        };

        // embedding: forward multiplier A_emb; table-grad scale is 1
        // (Adam is scale-invariant; Unit Scaling leaves gathers alone)
        let emb = abc_of("emb");
        set("emb.scale".into(), emb.a);
        set("emb.gw".into(), emb.a_bwd);

        // matmul sites: fwd = A_W; backward scales depend on the scheme.
        // μP/SP: honest gradients (gx = gw = A_W, since y = A·(x@W)).
        // u-μP: Table 8 — gx constrained to the forward scale on
        // non-cut edges, gw free at 1/sqrt(batch-rows) (cut edge).
        let mm = |set: &mut dyn FnMut(String, f64), site: String, abc: &Abc, fan_out: usize| {
            let (_, _us_gx, us_gw) = matmul_scales(1, fan_out, tokens);
            if unit {
                set(format!("{site}.out"), abc.a);
                set(format!("{site}.gx"), abc.a_bwd);
                set(format!("{site}.gw"), us_gw);
            } else {
                set(format!("{site}.out"), abc.a);
                set(format!("{site}.gx"), abc.a_bwd);
                set(format!("{site}.gw"), abc.a);
            }
        };

        for l in 0..depth {
            for name in ["attn.q", "attn.k", "attn.v", "attn.o", "ffn.gate", "ffn.up", "ffn.down"] {
                let tname = format!("l{l}.{name}");
                let t = man.tensor(&tname)?;
                let abc = abc_of(&tname);
                mm(&mut set, tname.clone(), &abc, t.fan_out);
            }
            // attention logit multiplier: α_attn · (1/d for μP & u-μP,
            // 1/sqrt(d) for SP) — §B "Unit-scaled dot-product attention"
            let logit = match p.scheme {
                Scheme::Sp => hp.alpha_attn / (d_head as f64).sqrt(),
                _ => hp.alpha_attn / d_head as f64,
            };
            set(format!("l{l}.attn.logit_mult"), logit);
            // attention output scale: Unit Scaling empirical model, else 1
            let out_scale =
                if unit { attention_out_scale(hp.alpha_attn, d_head, seq) } else { 1.0 };
            set(format!("l{l}.attn.out_scale"), out_scale);
            // FFN activation multiplier + Unit Scaling factor
            set(format!("l{l}.ffn.act_alpha"), hp.alpha_ffn_act);
            let act_scale = if unit { gated_silu_scale(hp.alpha_ffn_act) } else { 1.0 };
            set(format!("l{l}.ffn.act_scale"), act_scale);
            // residual coefficients
            let rc = if unit {
                umup_residual(l, depth, hp.alpha_res, hp.alpha_res_attn_ratio)
            } else {
                mup_residual(depth, p.base_depth, p.depth_mup && p.scheme != Scheme::Sp)
            };
            set(format!("l{l}.res.attn.a"), rc.attn_a);
            set(format!("l{l}.res.attn.b"), rc.attn_b);
            set(format!("l{l}.res.ffn.a"), rc.ffn_a);
            set(format!("l{l}.res.ffn.b"), rc.ffn_b);
        }

        // decoder head
        let head = abc_of("head");
        let t_head = man.tensor("head")?;
        mm(&mut set, "head".into(), &head, t_head.fan_out);

        // loss: α_loss-softmax pre-multiplier; u-μP backward grad boost
        set("loss.alpha".into(), hp.alpha_loss);
        set("loss.beta".into(), if unit { xent_grad_scale(vocab) } else { 1.0 });

        // ---------------- quantization mask ----------------
        let qmask = Self::qmask(man, precision);

        Ok(RuntimeVectors { scales, init_std, lr_scale, qmask })
    }

    /// Raise the per-site quantization flags for a precision mode.
    pub fn qmask(man: &Manifest, precision: Precision) -> Vec<f32> {
        let mut qmask = vec![0.0f32; man.n_quant_sites];
        if precision == Precision::Fp32 {
            return qmask;
        }
        for (site, &idx) in &man.quant_sites {
            let critical = site.contains("attn.o")
                || site.contains("ffn.down")
                || site.starts_with("head");
            let on = match precision {
                Precision::Fp32 => false,
                Precision::Fp8Naive => true,
                Precision::Fp8Paper => !critical,
            };
            qmask[idx] = if on { 1.0 } else { 0.0 };
        }
        qmask
    }
}

#[cfg(test)]
mod tests {
    // RuntimeVectors requires a Manifest; integration coverage lives in
    // tests/parametrization_vectors.rs against the real artifacts.
    use super::*;

    #[test]
    fn precision_parse() {
        assert_eq!(Precision::parse("fp8"), Some(Precision::Fp8Naive));
        assert_eq!(Precision::parse("FP8-paper"), Some(Precision::Fp8Paper));
        assert_eq!(Precision::parse("bf16"), Some(Precision::Fp32));
        assert_eq!(Precision::parse("int4"), None);
    }
}
