//! The abc-parametrization (paper §2.1, Eq. 1-3; Tables 1, 2, 11).
//!
//! A parametrization assigns every weight tensor three multipliers:
//! A_W (parameter), B_W (initialization), C_W (Adam LR).  [`Abc::of`]
//! evaluates the chosen scheme's rules for one tensor; the abc-symmetry
//! θ-shift (Eq. 2) is exposed for the property tests that check dynamics
//! invariance.

use crate::runtime::{TensorMeta, WeightKind};

use super::{EmbLrRule, HpSet};

/// Which rule table to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Standard parametrization (Pythia-style init; global LR).
    Sp,
    /// μP, Table 2 (top half), with base shapes and extended HPs.
    Mup,
    /// The intermediate scheme of Table 11 (μP with σ_W and base-fan-in
    /// dropped) — the ablation stepping stone from μP to u-μP.
    Intermediate,
    /// u-μP, Table 2 (bottom half).
    Umup,
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sp" => Scheme::Sp,
            "mup" | "μp" => Scheme::Mup,
            "intermediate" | "table11" => Scheme::Intermediate,
            "umup" | "u-mup" | "u-μp" => Scheme::Umup,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Sp => "SP",
            Scheme::Mup => "muP",
            Scheme::Intermediate => "intermediate",
            Scheme::Umup => "u-muP",
        }
    }
}

/// Scheme + its non-HP configuration.
#[derive(Debug, Clone, Copy)]
pub struct Parametrization {
    pub scheme: Scheme,
    /// μP base shape (§2.1 "Base shape"; u-μP drops it).
    pub base_width: usize,
    pub base_depth: usize,
    /// Embedding LR rule (§4.4): μP default Constant, u-μP InvSqrtFanOut.
    pub emb_lr_rule: EmbLrRule,
    /// Apply depth-μP residual/LR scaling for μP (Table 2 Residual col).
    pub depth_mup: bool,
}

impl Parametrization {
    pub fn new(scheme: Scheme) -> Self {
        Parametrization {
            scheme,
            base_width: 64,
            base_depth: 4,
            emb_lr_rule: match scheme {
                Scheme::Umup => EmbLrRule::InvSqrtFanOut,
                _ => EmbLrRule::Constant,
            },
            depth_mup: true,
        }
    }
}

/// The three multipliers for one tensor. `a_bwd` covers the output
/// layer's cut-edge deviation (u-μP uses 1/sqrt(fan-in) backward where
/// the forward is 1/fan-in — Table 2 footnote ‡ / Appendix H).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Abc {
    pub a: f64,
    pub a_bwd: f64,
    pub b: f64,
    pub c: f64,
}

impl Abc {
    /// abc-symmetry shift (Eq. 2): A·θ, B/θ, C/θ leaves Adam training
    /// dynamics invariant. Used by property tests.
    pub fn theta_shift(&self, theta: f64) -> Abc {
        Abc {
            a: self.a * theta,
            a_bwd: self.a_bwd * theta,
            b: self.b / theta,
            c: self.c / theta,
        }
    }

    /// Evaluate the scheme's A/B/C for one tensor (Tables 1, 2, 11).
    pub fn of(p: &Parametrization, hp: &HpSet, t: &TensorMeta, width: usize, depth: usize) -> Abc {
        let fan_in = t.fan_in as f64;
        let fan_out = t.fan_out as f64;
        // base-shape ratio: width-proportional dims shrink by bw/w
        let base_ratio = p.base_width as f64 / width as f64;
        let depth_lr = if p.depth_mup && matches!(p.scheme, Scheme::Mup | Scheme::Intermediate) {
            (p.base_depth as f64 / depth as f64).sqrt()
        } else if p.scheme == Scheme::Umup {
            1.0 / (depth as f64).sqrt()
        } else {
            1.0
        };
        match (p.scheme, t.kind) {
            // ---------------- SP (Pythia init, global LR) ----------------
            (Scheme::Sp, WeightKind::Input) => {
                Abc { a: hp.alpha_emb, a_bwd: hp.alpha_emb, b: hp.sigma_init, c: hp.eta }
            }
            (Scheme::Sp, WeightKind::Hidden) => {
                // Pythia: N(0, sqrt(2/(5*d))) — width-dependent but NOT
                // the μP scaling (σ ∝ 1/sqrt(width) for fan-in ∝ width).
                let b = hp.sigma_init * (2.0 / (5.0 * fan_in)).sqrt();
                Abc { a: 1.0, a_bwd: 1.0, b, c: hp.eta }
            }
            (Scheme::Sp, WeightKind::Output) => {
                let b = hp.sigma_init * (2.0 / (5.0 * fan_in)).sqrt();
                Abc { a: hp.alpha_out, a_bwd: hp.alpha_out, b, c: hp.eta }
            }

            // ---------------- μP (Table 2 top) ----------------
            (Scheme::Mup, WeightKind::Input) => Abc {
                a: hp.alpha_emb,
                a_bwd: hp.alpha_emb,
                b: hp.sigma_init,
                c: hp.eta * hp.eta_emb_hat * p.emb_lr_rule.factor(fan_out, base_ratio),
            },
            (Scheme::Mup, WeightKind::Hidden) => {
                // Table 2: B = σ_init·sqrt(base-fan-in/fan-in), with
                // σ_init interpreted (as in TP5 / the mup library) as a
                // multiplier on the 1/sqrt(base-fan-in) standard init —
                // i.e. absolute std σ_init/sqrt(fan-in).
                let base_fan_in = fan_in * base_ratio;
                Abc {
                    a: 1.0,
                    a_bwd: 1.0,
                    b: hp.sigma_init * base_ratio.sqrt() / base_fan_in.sqrt(),
                    c: hp.eta * base_ratio * depth_lr, // η·(base-fan-in/fan-in)
                }
            }
            (Scheme::Mup, WeightKind::Output) => {
                // B = σ_init (constant in width) at the base-normalized
                // scale σ_init/sqrt(base-fan-in); A = α_out·base/fan-in.
                let base_fan_in = fan_in * base_ratio;
                Abc {
                    a: hp.alpha_out * base_ratio,
                    a_bwd: hp.alpha_out * base_ratio,
                    b: hp.sigma_init / base_fan_in.sqrt(),
                    c: hp.eta,
                }
            }

            // ---------------- intermediate (Table 11) ----------------
            (Scheme::Intermediate, WeightKind::Input) => {
                Abc { a: 1.0, a_bwd: 1.0, b: 1.0, c: hp.eta }
            }
            (Scheme::Intermediate, WeightKind::Hidden) => Abc {
                a: 1.0,
                a_bwd: 1.0,
                b: 1.0 / fan_in.sqrt(),
                c: hp.eta / fan_in * depth_lr,
            },
            (Scheme::Intermediate, WeightKind::Output) => Abc {
                a: hp.alpha_out / fan_in,
                a_bwd: hp.alpha_out / fan_in,
                b: 1.0,
                c: hp.eta,
            },

            // ---------------- u-μP (Table 2 bottom) ----------------
            (Scheme::Umup, WeightKind::Input) => Abc {
                a: 1.0,
                a_bwd: 1.0,
                b: 1.0,
                c: hp.eta * p.emb_lr_rule.factor(fan_out, 1.0 / fan_out),
            },
            (Scheme::Umup, WeightKind::Hidden) => Abc {
                a: 1.0 / fan_in.sqrt(),
                a_bwd: 1.0 / fan_in.sqrt(),
                b: 1.0,
                c: hp.eta / fan_in.sqrt() * depth_lr,
            },
            (Scheme::Umup, WeightKind::Output) => Abc {
                a: hp.alpha_out / fan_in,
                a_bwd: hp.alpha_out / fan_in.sqrt(), // cut-edge rule, App. H
                b: 1.0,
                c: hp.eta,
            },

            // norm gains: unit init, global LR, no multiplier
            (_, WeightKind::Norm) => Abc { a: 1.0, a_bwd: 1.0, b: 1.0, c: hp.eta },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hidden(width: usize) -> TensorMeta {
        TensorMeta {
            name: "l0.attn.q".into(),
            shape: vec![width, width],
            kind: WeightKind::Hidden,
            fan_in: width,
            fan_out: width,
            offset: 0,
            size: width * width,
        }
    }

    #[test]
    fn umup_hidden_matches_table2() {
        let p = Parametrization::new(Scheme::Umup);
        let hp = HpSet::with_eta(1.0);
        let abc = Abc::of(&p, &hp, &hidden(256), 256, 4);
        assert!((abc.a - 1.0 / 16.0).abs() < 1e-12); // 1/sqrt(256)
        assert_eq!(abc.b, 1.0);
        assert!((abc.c - 1.0 / 16.0 / 2.0).abs() < 1e-12); // 1/sqrt(256)·1/sqrt(4)
    }

    #[test]
    fn mup_hidden_matches_table2_at_base() {
        // at the base shape μP == its own base: ratios are 1
        let mut p = Parametrization::new(Scheme::Mup);
        p.base_width = 256;
        p.base_depth = 4;
        let hp = HpSet { eta: 0.01, sigma_init: 0.5, ..Default::default() };
        let abc = Abc::of(&p, &hp, &hidden(256), 256, 4);
        assert_eq!(abc.a, 1.0);
        assert!((abc.b - 0.5 / 16.0).abs() < 1e-12); // σ/sqrt(fan-in)
        assert!((abc.c - 0.01).abs() < 1e-15);
        // doubling width: init shrinks by sqrt2, lr by 2
        let abc2 = Abc::of(&p, &hp, &hidden(512), 512, 4);
        assert!((abc2.b - abc.b / 2f64.sqrt()).abs() < 1e-12);
        assert!((abc2.c - 0.005).abs() < 1e-15);
    }

    #[test]
    fn umup_is_theta_shift_of_intermediate() {
        // §4.1 Eq. 4→5: the u-μP hidden rule is the Table 11 rule shifted
        // by θ = sqrt(fan-in) under abc-symmetry, with the LR moving from
        // η/fan-in to η/sqrt(fan-in).
        let w = 128;
        let mut pi = Parametrization::new(Scheme::Intermediate);
        pi.depth_mup = false;
        let mut pu = Parametrization::new(Scheme::Umup);
        pu.emb_lr_rule = EmbLrRule::Constant;
        let hp = HpSet::with_eta(1.0);
        let t = hidden(w);
        let inter = Abc::of(&pi, &hp, &t, w, 4);
        let shifted = Abc {
            // θ-shift of the *SGD-style* triple moves C by 1/θ; for Adam
            // the LR is scale-free so the paper shifts A,B and re-derives
            // C = η/sqrt(fan-in) (Eq. 5). Check A and B exactly:
            ..inter.theta_shift(1.0 / (w as f64).sqrt())
        };
        let umup = Abc::of(&pu, &hp, &t, w, 4);
        assert!((shifted.a - umup.a).abs() < 1e-12);
        assert!((shifted.b - umup.b).abs() < 1e-12);
        // and C matches Eq. 5 directly (÷ the u-μP depth rule 1/sqrt(L)):
        assert!((umup.c * 2.0 - 1.0 / (w as f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn output_cut_edge_only_for_umup() {
        let t = TensorMeta {
            name: "head".into(),
            shape: vec![64, 256],
            kind: WeightKind::Output,
            fan_in: 64,
            fan_out: 256,
            offset: 0,
            size: 64 * 256,
        };
        let hp = HpSet::default();
        let u = Abc::of(&Parametrization::new(Scheme::Umup), &hp, &t, 64, 4);
        assert!((u.a - 1.0 / 64.0).abs() < 1e-15);
        assert!((u.a_bwd - 0.125).abs() < 1e-15); // 1/sqrt(64)
        let m = Abc::of(&Parametrization::new(Scheme::Mup), &hp, &t, 64, 4);
        assert_eq!(m.a, m.a_bwd);
    }
}
