//! Residual-branch coefficient schemes (paper Appendices F and G.2.2).
//!
//! u-μP replaces the plain pre-norm residual `f(x) + x` with
//! `a_l·f(x) + b_l·x` where a_l²+b_l²=1 preserves unit variance and the
//! ratio τ_l = a_l/b_l reproduces the dynamics of the (α_emb, α_attn-res,
//! α_ffn-res) baseline — Lemma F.1 proves the two networks are equal up
//! to a per-layer constant that the next 0-homogeneous norm absorbs.
//!
//! HPs: α_res (residual-vs-embedding contribution) and α_res-attn-ratio
//! (attention-vs-FFN contribution), Eqs. 25-31.

/// Per-branch coefficients for one transformer layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidualCoeffs {
    pub attn_a: f64,
    pub attn_b: f64,
    pub ffn_a: f64,
    pub ffn_b: f64,
}

/// u-μP residual scheme (G.2.2, Eqs. 25-31).
///
/// `layer` is 0-based; `n_layers` is the transformer depth (the paper's
/// L counts branches, so L = 2·n_layers and L/2 = n_layers).
pub fn umup_residual(
    layer: usize,
    n_layers: usize,
    alpha_res: f64,
    alpha_ratio: f64,
) -> ResidualCoeffs {
    let half_l = n_layers as f64;
    let af2 = 2.0 / (alpha_ratio * alpha_ratio + 1.0) * alpha_res * alpha_res; // Eq. 31
    let aa2 = alpha_ratio * alpha_ratio * af2; // Eq. 30
    // branch indices: attention branch l_odd = 2·layer+1, ffn l_even = 2·layer+2
    let ell = layer as f64; // ⌊(l-1)/2⌋ for both branches of this layer
    let tau2_attn = aa2 / (half_l + ell * aa2 + ell * af2); // Eq. 29, odd
    let tau2_ffn = af2 / (half_l + (ell + 1.0) * aa2 + ell * af2); // Eq. 29, even
    let ab = |tau2: f64| {
        let a = (tau2 / (tau2 + 1.0)).sqrt();
        let b = (1.0 / (tau2 + 1.0)).sqrt();
        (a, b)
    };
    let (attn_a, attn_b) = ab(tau2_attn);
    let (ffn_a, ffn_b) = ab(tau2_ffn);
    ResidualCoeffs { attn_a, attn_b, ffn_a, ffn_b }
}

/// μP / SP residual scheme: plain skip (b = 1) with the depth-μP branch
/// multiplier sqrt(base-depth/depth) when enabled (Table 2 Residual col).
pub fn mup_residual(n_layers: usize, base_depth: usize, depth_mup: bool) -> ResidualCoeffs {
    let a = if depth_mup { (base_depth as f64 / n_layers as f64).sqrt() } else { 1.0 };
    ResidualCoeffs { attn_a: a, attn_b: 1.0, ffn_a: a, ffn_b: 1.0 }
}

impl ResidualCoeffs {
    /// Unit-variance invariant of the u-μP scheme (Eq. 13).
    pub fn is_unit_preserving(&self, tol: f64) -> bool {
        (self.attn_a * self.attn_a + self.attn_b * self.attn_b - 1.0).abs() < tol
            && (self.ffn_a * self.ffn_a + self.ffn_b * self.ffn_b - 1.0).abs() < tol
    }
}

/// Simulated skip-stream RMS after `n_layers` of the *plain* pre-norm
/// network (Eq. 9 / Appendix F.1) — used by the Fig 25 / App. L analysis
/// and by tests that check the u-μP scheme removes this growth.
/// (also exercised by the fig25 experiment)
pub fn plain_prenorm_skip_rms(n_layers: usize, r_emb: f64, r_branch: f64) -> f64 {
    let mut var = r_emb * r_emb;
    for _ in 0..(2 * n_layers) {
        var += r_branch * r_branch;
    }
    var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_preserving_for_all_depths() {
        for n_layers in [1, 2, 4, 8, 32] {
            for layer in 0..n_layers {
                for (r, rho) in [(1.0, 1.0), (0.5, 2.0), (4.0, 0.25)] {
                    let c = umup_residual(layer, n_layers, r, rho);
                    assert!(c.is_unit_preserving(1e-12), "{n_layers} {layer} {r} {rho}");
                }
            }
        }
    }

    /// Lemma F.1: the rescaled network equals the plain network divided by
    /// the running scale sqrt(Σ r_i²). We simulate both recursions on
    /// scalar "scales" and check the cumulative products agree.
    #[test]
    fn lemma_f1_scale_equivalence() {
        let n_layers = 6;
        let (alpha_res, alpha_ratio) = (1.3, 0.7);
        // baseline per-branch multipliers (Eqs. 19-21, with depth-μP
        // branch scaling folded in exactly as G.2.2 does)
        let half_l = n_layers as f64;
        let af2 = 2.0 / (alpha_ratio * alpha_ratio + 1.0) * alpha_res * alpha_res;
        let aa2 = alpha_ratio * alpha_ratio * af2;
        // plain network variance recursion: var_l = var_{l-1} + r_l²,
        // r² alternating aa2/half_l, af2/half_l, var_0 = 1 (α_emb = 1)
        let mut var = 1.0f64;
        let mut taus = Vec::new();
        for l in 0..n_layers {
            for (b, r2) in [(0, aa2 / half_l), (1, af2 / half_l)] {
                let tau2 = r2 / var;
                var += r2;
                let c = umup_residual(l, n_layers, alpha_res, alpha_ratio);
                let got = if b == 0 {
                    c.attn_a / c.attn_b
                } else {
                    c.ffn_a / c.ffn_b
                };
                taus.push((tau2.sqrt(), got));
            }
        }
        for (expect, got) in taus {
            assert!((expect - got).abs() < 1e-9, "tau {expect} vs {got}");
        }
    }

    /// Eq. 9: plain pre-norm scale grows with depth; the u-μP scheme holds
    /// the simulated skip RMS at exactly 1.
    #[test]
    fn skip_growth_eliminated() {
        let grown = plain_prenorm_skip_rms(8, 1.0, 0.25);
        assert!(grown > 1.2);
        // simulate the u-μP recursion with unit-RMS branch outputs
        let mut rms2 = 1.0f64;
        for l in 0..8 {
            let c = umup_residual(l, 8, 1.0, 1.0);
            rms2 = c.attn_a * c.attn_a + c.attn_b * c.attn_b * rms2;
            rms2 = c.ffn_a * c.ffn_a + c.ffn_b * c.ffn_b * rms2;
        }
        assert!((rms2.sqrt() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mup_depth_scaling() {
        let c = mup_residual(16, 4, true);
        assert!((c.attn_a - 0.5).abs() < 1e-12);
        assert_eq!(c.attn_b, 1.0);
        let c = mup_residual(16, 4, false);
        assert_eq!(c.attn_a, 1.0);
    }
}
