//! The training run loop: drive an AOT-compiled step executable over the
//! corpus under a parametrization, schedule and precision mode.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::data::{BatchSampler, Corpus};
use crate::parametrization::RuntimeVectors;
use crate::runtime::Session;
use crate::train::{RunConfig, RunRecord};

/// Apply Fig 13-style per-tensor LR multipliers on top of the rule.
fn apply_lr_tweaks(
    man: &crate::runtime::Manifest,
    vecs: &mut RuntimeVectors,
    tweaks: &[(String, f64)],
) {
    for (pat, mult) in tweaks {
        for (i, t) in man.tensors.iter().enumerate() {
            if t.name.ends_with(pat.as_str()) || t.name == *pat {
                vecs.lr_scale[i] *= *mult as f32;
            }
        }
    }
}

/// Runs [`RunConfig`]s against one compiled session.
pub struct Runner {
    pub session: Arc<Session>,
}

impl Runner {
    pub fn new(session: Arc<Session>) -> Self {
        Runner { session }
    }

    pub fn run(&self, cfg: &RunConfig, corpus: &Corpus) -> Result<RunRecord> {
        Ok(self.run_full(cfg, corpus)?.0)
    }

    /// Like [`Runner::run`] but also returns the final on-device state
    /// (for downstream probe evaluation, Fig 7 / Table 4).
    pub fn run_full(
        &self,
        cfg: &RunConfig,
        corpus: &Corpus,
    ) -> Result<(RunRecord, crate::runtime::TrainState)> {
        let t0 = Instant::now();
        let man = self.session.manifest.clone();
        let mut vecs =
            RuntimeVectors::build(&man, &cfg.parametrization, &cfg.hp, cfg.precision)?;
        apply_lr_tweaks(&man, &mut vecs, &cfg.lr_tweaks);
        let mut ts = self.session.init(
            cfg.seed,
            &vecs.init_std,
            &vecs.scales,
            &vecs.lr_scale,
            &vecs.qmask,
        )?;

        let mut train =
            BatchSampler::new(corpus.train_slice(), man.spec.batch, man.spec.seq, cfg.seed as u64);
        let mut valid = BatchSampler::new(
            corpus.valid_slice(),
            man.spec.batch,
            man.spec.seq,
            777,
        );

        let rms_idx: Vec<(String, usize)> = cfg
            .rms_sites
            .iter()
            .filter_map(|s| man.rms_index(s).ok().map(|i| (s.clone(), i)))
            .collect();

        let mut train_curve = Vec::new();
        let mut valid_curve = Vec::new();
        let mut rms_curves: BTreeMap<String, Vec<(u64, f64)>> = BTreeMap::new();
        let mut diverged = false;
        let mut first_loss: Option<f64> = None;

        // §Perf: the telemetry tail is only fetched at the logging
        // cadence (divergence is checked there too) — between cadence
        // points the state chains on-device with no host sync.  One
        // token buffer serves every step and the validation pass: at
        // production step counts a fresh batch*(seq+1) Vec per step is
        // pure allocator churn.
        let mut tokens: Vec<i32> = Vec::with_capacity(man.spec.batch * (man.spec.seq + 1));
        let cadence = cfg.log_every.max(1);
        for t in 1..=cfg.schedule.total_steps {
            let lr = cfg.schedule.lr_at(t);
            let hyp = cfg.adam.hyp(lr, t);
            train.sample_into(&mut tokens);
            let at_cadence =
                t % cadence == 0 || t == cfg.schedule.total_steps || t == 1;
            let loss = if at_cadence {
                self.session.step(&mut ts, &tokens, &hyp)? as f64
            } else {
                self.session.step_chain(&mut ts, &tokens, &hyp)?;
                continue;
            };
            if first_loss.is_none() {
                first_loss = Some(loss);
            }
            if !loss.is_finite() || loss > first_loss.unwrap() * 3.0 + 5.0 {
                diverged = true;
                train_curve.push((t, loss));
                break;
            }
            if cfg.log_every > 0 {
                train_curve.push((t, loss));
                if !rms_idx.is_empty() {
                    let (_, rms) = self.session.telemetry(&ts);
                    for (name, i) in &rms_idx {
                        rms_curves
                            .entry(name.clone())
                            .or_default()
                            .push((t, rms[*i] as f64));
                    }
                }
            }
        }

        // validation objective
        let final_valid_loss = if diverged {
            f64::INFINITY
        } else {
            valid.reset();
            let mut acc = 0.0;
            let n = cfg.valid_batches.max(1);
            for _ in 0..n {
                valid.next_sequential_into(&mut tokens);
                acc += self.session.eval(&ts, &tokens)?.loss as f64;
            }
            let v = acc / n as f64;
            valid_curve.push((cfg.schedule.total_steps, v));
            v
        };

        let (_, rms_tail) = self.session.telemetry(&ts);
        let final_rms: Vec<(String, f64)> = man
            .rms_sites
            .iter()
            .cloned()
            .zip(rms_tail.iter().map(|&x| x as f64))
            .collect();

        let record = RunRecord {
            label: cfg.label.clone(),
            train_curve,
            valid_curve,
            final_valid_loss,
            rms_curves,
            final_rms,
            diverged,
            wall_seconds: t0.elapsed().as_secs_f64(),
        };
        Ok((record, ts))
    }

    /// Evaluate a trained state on another corpus (mean loss over
    /// `n_batches` sequential validation windows).
    pub fn eval_on(
        &self,
        ts: &crate::runtime::TrainState,
        corpus: &Corpus,
        n_batches: usize,
    ) -> Result<f64> {
        let man = &self.session.manifest;
        let mut sampler =
            BatchSampler::new(corpus.valid_slice(), man.spec.batch, man.spec.seq, 42);
        let mut acc = 0.0;
        let mut tokens: Vec<i32> = Vec::with_capacity(man.spec.batch * (man.spec.seq + 1));
        for _ in 0..n_batches.max(1) {
            sampler.next_sequential_into(&mut tokens);
            acc += self.session.eval(ts, &tokens)?.loss as f64;
        }
        Ok(acc / n_batches.max(1) as f64)
    }

    /// Evaluate the *initial* model (step 0) telemetry — used by Fig 6
    /// (init RMS) and Fig 25 (attention-out growth at init).
    pub fn eval_at_init(
        &self,
        cfg: &RunConfig,
        corpus: &Corpus,
    ) -> Result<(f64, Vec<(String, f64)>)> {
        let man = self.session.manifest.clone();
        let vecs =
            RuntimeVectors::build(&man, &cfg.parametrization, &cfg.hp, cfg.precision)?;
        let ts = self.session.init(
            cfg.seed,
            &vecs.init_std,
            &vecs.scales,
            &vecs.lr_scale,
            &vecs.qmask,
        )?;
        let mut valid =
            BatchSampler::new(corpus.valid_slice(), man.spec.batch, man.spec.seq, 777);
        let out = self.session.eval(&ts, &valid.next_sequential())?;
        let named = man
            .rms_sites
            .iter()
            .cloned()
            .zip(out.rms.iter().map(|&x| x as f64))
            .collect();
        Ok((out.loss as f64, named))
    }
}
