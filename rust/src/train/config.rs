//! Run configuration: what one training run is, independent of how it
//! executes.
//!
//! Split from the [`super::runner`] module (which drives real XLA
//! sessions and is gated behind the `xla` feature) so the engine's
//! content-addressed cache — whose keys hash
//! [`RunConfig::canonical_json`] — works in no-XLA builds too
//! (`repro cache gc`/`stats`, CI check builds, the mock-executor test
//! harness).

use crate::parametrization::{EmbLrRule, HpSet, Parametrization, Precision, HP_NAMES};
use crate::train::{AdamConfig, Schedule, ScheduleKind};
use crate::util::Json;

/// Everything one run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub label: String,
    pub parametrization: Parametrization,
    pub hp: HpSet,
    pub precision: Precision,
    pub schedule: Schedule,
    pub adam: AdamConfig,
    pub seed: i32,
    /// Log train loss / RMS every `log_every` steps (0 = final only).
    pub log_every: u64,
    /// Validation batches averaged for the objective.
    pub valid_batches: usize,
    /// Track these RMS sites over training (Fig 19/20); empty = none.
    pub rms_sites: Vec<String>,
    /// Per-tensor LR multipliers on top of the parametrization rule
    /// (Fig 13 / A.4): (tensor-name substring, multiplier).
    pub lr_tweaks: Vec<(String, f64)>,
}

impl RunConfig {
    pub fn quick(label: &str, p: Parametrization, hp: HpSet, steps: u64) -> Self {
        RunConfig {
            label: label.to_string(),
            parametrization: p,
            hp,
            precision: Precision::Fp32,
            schedule: Schedule::standard(hp.eta, steps, (steps / 4).max(1)),
            adam: AdamConfig::default(),
            seed: 0,
            log_every: (steps / 16).max(1),
            valid_batches: 4,
            rms_sites: Vec::new(),
            lr_tweaks: Vec::new(),
        }
    }

    /// Canonical, content-addressable form of this config — the engine's
    /// cache-key input (see `crate::engine::run_key`).
    ///
    /// Deliberately excludes `label` (presentation only), so the same
    /// baseline config reached from different figures shares one cache
    /// entry.  Includes everything that changes what a run computes *or
    /// records* (`log_every` changes the telemetry cadence captured in
    /// the [`crate::train::RunRecord`]).  Keys are sorted maps all the
    /// way down, so the serialized form is independent of construction
    /// order and stable across processes.
    pub fn canonical_json(&self) -> Json {
        use std::collections::BTreeMap;
        let num = Json::Num;

        let mut p = BTreeMap::new();
        p.insert("scheme".to_string(), Json::Str(self.parametrization.scheme.name().to_string()));
        p.insert("base_width".to_string(), num(self.parametrization.base_width as f64));
        p.insert("base_depth".to_string(), num(self.parametrization.base_depth as f64));
        p.insert(
            "emb_lr_rule".to_string(),
            Json::Str(
                match self.parametrization.emb_lr_rule {
                    EmbLrRule::Constant => "constant",
                    EmbLrRule::InvSqrtFanOut => "inv-sqrt-fan-out",
                }
                .to_string(),
            ),
        );
        p.insert("depth_mup".to_string(), Json::Bool(self.parametrization.depth_mup));

        let mut hp = BTreeMap::new();
        for name in HP_NAMES {
            hp.insert(name.to_string(), num(self.hp.get(name).unwrap_or(f64::NAN)));
        }

        let (kind, kind_arg) = match self.schedule.kind {
            ScheduleKind::Constant => ("constant", 0.0),
            ScheduleKind::CosineTo(f) => ("cosine-to", f),
            ScheduleKind::LinearToZero => ("linear-to-zero", 0.0),
        };
        let mut sch = BTreeMap::new();
        sch.insert("kind".to_string(), Json::Str(kind.to_string()));
        sch.insert("kind_arg".to_string(), num(kind_arg));
        sch.insert("peak_lr".to_string(), num(self.schedule.peak_lr));
        sch.insert("warmup_steps".to_string(), num(self.schedule.warmup_steps as f64));
        sch.insert("total_steps".to_string(), num(self.schedule.total_steps as f64));

        let mut adam = BTreeMap::new();
        adam.insert("beta1".to_string(), num(self.adam.beta1));
        adam.insert("beta2".to_string(), num(self.adam.beta2));
        adam.insert("eps".to_string(), num(self.adam.eps));
        adam.insert("wd_coupled".to_string(), num(self.adam.wd_coupled));
        adam.insert("wd_indep".to_string(), num(self.adam.wd_indep));

        let mut m = BTreeMap::new();
        m.insert("parametrization".to_string(), Json::Obj(p));
        m.insert("hp".to_string(), Json::Obj(hp));
        m.insert("precision".to_string(), Json::Str(self.precision.name().to_string()));
        m.insert("schedule".to_string(), Json::Obj(sch));
        m.insert("adam".to_string(), Json::Obj(adam));
        m.insert("seed".to_string(), num(self.seed as f64));
        m.insert("log_every".to_string(), num(self.log_every as f64));
        m.insert("valid_batches".to_string(), num(self.valid_batches as f64));
        m.insert(
            "rms_sites".to_string(),
            Json::Arr(self.rms_sites.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        m.insert(
            "lr_tweaks".to_string(),
            Json::Arr(
                self.lr_tweaks
                    .iter()
                    .map(|(pat, mult)| Json::Arr(vec![Json::Str(pat.clone()), num(*mult)]))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}
