//! Run configuration: what one training run is, independent of how it
//! executes.
//!
//! Split from the `super::runner` module (which drives real XLA
//! sessions and is gated behind the `xla` feature) so the engine's
//! content-addressed cache — whose keys hash
//! [`RunConfig::canonical_json`] — works in no-XLA builds too
//! (`repro cache gc`/`stats`, CI check builds, the mock-executor test
//! harness).

use anyhow::{bail, Context, Result};

use crate::parametrization::{EmbLrRule, HpSet, Parametrization, Precision, Scheme, HP_NAMES};
use crate::train::{AdamConfig, Schedule, ScheduleKind};
use crate::util::Json;

/// Everything one run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub label: String,
    pub parametrization: Parametrization,
    pub hp: HpSet,
    pub precision: Precision,
    pub schedule: Schedule,
    pub adam: AdamConfig,
    pub seed: i32,
    /// Log train loss / RMS every `log_every` steps (0 = final only).
    pub log_every: u64,
    /// Validation batches averaged for the objective.
    pub valid_batches: usize,
    /// Track these RMS sites over training (Fig 19/20); empty = none.
    pub rms_sites: Vec<String>,
    /// Per-tensor LR multipliers on top of the parametrization rule
    /// (Fig 13 / A.4): (tensor-name substring, multiplier).
    pub lr_tweaks: Vec<(String, f64)>,
}

impl RunConfig {
    pub fn quick(label: &str, p: Parametrization, hp: HpSet, steps: u64) -> Self {
        RunConfig {
            label: label.to_string(),
            parametrization: p,
            hp,
            precision: Precision::Fp32,
            schedule: Schedule::standard(hp.eta, steps, (steps / 4).max(1)),
            adam: AdamConfig::default(),
            seed: 0,
            log_every: (steps / 16).max(1),
            valid_batches: 4,
            rms_sites: Vec::new(),
            lr_tweaks: Vec::new(),
        }
    }

    /// Canonical, content-addressable form of this config — the engine's
    /// cache-key input (see `crate::engine::run_key`).
    ///
    /// Deliberately excludes `label` (presentation only), so the same
    /// baseline config reached from different figures shares one cache
    /// entry.  Includes everything that changes what a run computes *or
    /// records* (`log_every` changes the telemetry cadence captured in
    /// the [`crate::train::RunRecord`]).  Keys are sorted maps all the
    /// way down, so the serialized form is independent of construction
    /// order and stable across processes.
    pub fn canonical_json(&self) -> Json {
        use std::collections::BTreeMap;
        let num = Json::Num;

        let mut p = BTreeMap::new();
        p.insert("scheme".to_string(), Json::Str(self.parametrization.scheme.name().to_string()));
        p.insert("base_width".to_string(), num(self.parametrization.base_width as f64));
        p.insert("base_depth".to_string(), num(self.parametrization.base_depth as f64));
        p.insert(
            "emb_lr_rule".to_string(),
            Json::Str(
                match self.parametrization.emb_lr_rule {
                    EmbLrRule::Constant => "constant",
                    EmbLrRule::InvSqrtFanOut => "inv-sqrt-fan-out",
                }
                .to_string(),
            ),
        );
        p.insert("depth_mup".to_string(), Json::Bool(self.parametrization.depth_mup));

        let mut hp = BTreeMap::new();
        for name in HP_NAMES {
            hp.insert(name.to_string(), num(self.hp.get(name).unwrap_or(f64::NAN)));
        }

        let (kind, kind_arg) = match self.schedule.kind {
            ScheduleKind::Constant => ("constant", 0.0),
            ScheduleKind::CosineTo(f) => ("cosine-to", f),
            ScheduleKind::LinearToZero => ("linear-to-zero", 0.0),
        };
        let mut sch = BTreeMap::new();
        sch.insert("kind".to_string(), Json::Str(kind.to_string()));
        sch.insert("kind_arg".to_string(), num(kind_arg));
        sch.insert("peak_lr".to_string(), num(self.schedule.peak_lr));
        sch.insert("warmup_steps".to_string(), num(self.schedule.warmup_steps as f64));
        sch.insert("total_steps".to_string(), num(self.schedule.total_steps as f64));

        let mut adam = BTreeMap::new();
        adam.insert("beta1".to_string(), num(self.adam.beta1));
        adam.insert("beta2".to_string(), num(self.adam.beta2));
        adam.insert("eps".to_string(), num(self.adam.eps));
        adam.insert("wd_coupled".to_string(), num(self.adam.wd_coupled));
        adam.insert("wd_indep".to_string(), num(self.adam.wd_indep));

        let mut m = BTreeMap::new();
        m.insert("parametrization".to_string(), Json::Obj(p));
        m.insert("hp".to_string(), Json::Obj(hp));
        m.insert("precision".to_string(), Json::Str(self.precision.name().to_string()));
        m.insert("schedule".to_string(), Json::Obj(sch));
        m.insert("adam".to_string(), Json::Obj(adam));
        m.insert("seed".to_string(), num(self.seed as f64));
        m.insert("log_every".to_string(), num(self.log_every as f64));
        m.insert("valid_batches".to_string(), num(self.valid_batches as f64));
        m.insert(
            "rms_sites".to_string(),
            Json::Arr(self.rms_sites.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        m.insert(
            "lr_tweaks".to_string(),
            Json::Arr(
                self.lr_tweaks
                    .iter()
                    .map(|(pat, mult)| Json::Arr(vec![Json::Str(pat.clone()), num(*mult)]))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Decode a config serialized by [`RunConfig::canonical_json`] —
    /// the worker wire protocol's job payload.  The canonical form
    /// deliberately excludes the presentation-only `label`, so it is
    /// supplied separately (the wire carries it alongside).
    ///
    /// Round-trip invariant:
    /// `from_canonical_json(cfg.canonical_json(), label)` yields a
    /// config whose own `canonical_json` dump is byte-identical — which
    /// is what keeps a process-backend drain's cache byte-identical to
    /// an in-process one.
    pub fn from_canonical_json(j: &Json, label: &str) -> Result<RunConfig> {
        let p = j.get("parametrization").context("config missing parametrization")?;
        let scheme_name = p.get("scheme")?.as_str()?;
        let scheme = Scheme::parse(scheme_name)
            .with_context(|| format!("unknown scheme {scheme_name:?}"))?;
        let mut parametrization = Parametrization::new(scheme);
        parametrization.base_width = p.get("base_width")?.as_usize()?;
        parametrization.base_depth = p.get("base_depth")?.as_usize()?;
        parametrization.emb_lr_rule = match p.get("emb_lr_rule")?.as_str()? {
            "constant" => EmbLrRule::Constant,
            "inv-sqrt-fan-out" => EmbLrRule::InvSqrtFanOut,
            other => bail!("unknown emb_lr_rule {other:?}"),
        };
        parametrization.depth_mup = p.get("depth_mup")?.as_bool()?;

        let mut hp = HpSet::default();
        let h = j.get("hp")?;
        for name in HP_NAMES {
            hp.set(name, h.get(name)?.as_f64()?);
        }

        let sch = j.get("schedule")?;
        let kind = match sch.get("kind")?.as_str()? {
            "constant" => ScheduleKind::Constant,
            "cosine-to" => ScheduleKind::CosineTo(sch.get("kind_arg")?.as_f64()?),
            "linear-to-zero" => ScheduleKind::LinearToZero,
            other => bail!("unknown schedule kind {other:?}"),
        };
        let schedule = Schedule {
            kind,
            peak_lr: sch.get("peak_lr")?.as_f64()?,
            warmup_steps: sch.get("warmup_steps")?.as_f64()? as u64,
            total_steps: sch.get("total_steps")?.as_f64()? as u64,
        };

        let a = j.get("adam")?;
        let adam = AdamConfig {
            beta1: a.get("beta1")?.as_f64()?,
            beta2: a.get("beta2")?.as_f64()?,
            eps: a.get("eps")?.as_f64()?,
            wd_coupled: a.get("wd_coupled")?.as_f64()?,
            wd_indep: a.get("wd_indep")?.as_f64()?,
        };

        let precision_name = j.get("precision")?.as_str()?;
        let precision = Precision::parse(precision_name)
            .with_context(|| format!("unknown precision {precision_name:?}"))?;

        let rms_sites = j
            .get("rms_sites")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let lr_tweaks = j
            .get("lr_tweaks")?
            .as_arr()?
            .iter()
            .map(|t| -> Result<(String, f64)> {
                let t = t.as_arr()?;
                if t.len() != 2 {
                    bail!("lr_tweaks entry must be a [pattern, multiplier] pair");
                }
                Ok((t[0].as_str()?.to_string(), t[1].as_f64()?))
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(RunConfig {
            label: label.to_string(),
            parametrization,
            hp,
            precision,
            schedule,
            adam,
            seed: j.get("seed")?.as_f64()? as i32,
            log_every: j.get("log_every")?.as_f64()? as u64,
            valid_batches: j.get("valid_batches")?.as_usize()?,
            rms_sites,
            lr_tweaks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_json_round_trips_through_from_canonical_json() {
        let mut cfg = RunConfig::quick(
            "round-trip",
            Parametrization::new(Scheme::Mup),
            HpSet::with_eta(0.375),
            48,
        );
        cfg.hp.set("alpha_attn", 2.0);
        cfg.hp.set("sigma_init", 0.5);
        cfg.precision = Precision::Fp8Paper;
        cfg.schedule = Schedule {
            kind: ScheduleKind::LinearToZero,
            peak_lr: 0.375,
            warmup_steps: 12,
            total_steps: 48,
        };
        cfg.adam = AdamConfig::coupled();
        cfg.seed = -3;
        cfg.log_every = 7;
        cfg.valid_batches = 9;
        cfg.rms_sites = vec!["w.head".to_string(), "w.emb".to_string()];
        cfg.lr_tweaks = vec![("emb".to_string(), 4.0), ("head".to_string(), 0.25)];

        let canonical = cfg.canonical_json();
        let back = RunConfig::from_canonical_json(&canonical, "round-trip").unwrap();
        assert_eq!(back.label, "round-trip");
        assert_eq!(
            back.canonical_json().dump(),
            canonical.dump(),
            "decode must be the exact inverse of the canonical encoding"
        );
        // spot-check non-defaults actually survived (not just defaulted)
        assert_eq!(back.hp.alpha_attn, 2.0);
        assert_eq!(back.seed, -3);
        assert_eq!(back.valid_batches, 9);
        assert_eq!(back.lr_tweaks[1], ("head".to_string(), 0.25));

        // a u-muP default config round-trips too (different scheme arm)
        let base = RunConfig::quick(
            "base",
            Parametrization::new(Scheme::Umup),
            HpSet::default(),
            16,
        );
        let back = RunConfig::from_canonical_json(&base.canonical_json(), "base").unwrap();
        assert_eq!(back.canonical_json().dump(), base.canonical_json().dump());
    }

    #[test]
    fn from_canonical_json_rejects_malformed_bodies() {
        let good = RunConfig::quick(
            "g",
            Parametrization::new(Scheme::Umup),
            HpSet::default(),
            8,
        )
        .canonical_json();
        // a non-object and a missing section both error cleanly
        assert!(RunConfig::from_canonical_json(&Json::Num(3.0), "g").is_err());
        let mut m = match good {
            Json::Obj(m) => m,
            _ => unreachable!("canonical form is an object"),
        };
        m.remove("schedule");
        assert!(RunConfig::from_canonical_json(&Json::Obj(m), "g").is_err());
    }
}
