//! S9 — training driver: LR schedules, the run loop, run records.

mod record;
mod runner;
mod schedule;

pub use record::RunRecord;
pub use runner::{RunConfig, Runner};
pub use schedule::{AdamConfig, Schedule, ScheduleKind};
