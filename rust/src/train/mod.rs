//! S9 — training driver: LR schedules, the run loop, run records.

mod config;
mod record;
#[cfg(feature = "xla")]
mod runner;
mod schedule;

pub use config::RunConfig;
pub use record::RunRecord;
#[cfg(feature = "xla")]
pub use runner::Runner;
pub use schedule::{AdamConfig, Schedule, ScheduleKind};
