//! Run records: everything one training run produced, serializable to
//! JSON for the experiment reports.

use std::collections::BTreeMap;

use crate::util::Json;

/// The outcome of one training run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub label: String,
    /// (step, training loss) at the logging cadence.
    pub train_curve: Vec<(u64, f64)>,
    /// (step, validation loss).
    pub valid_curve: Vec<(u64, f64)>,
    /// Final smoothed validation loss (the sweep objective).
    pub final_valid_loss: f64,
    /// RMS telemetry snapshots: site name -> (step, rms) series.
    pub rms_curves: BTreeMap<String, Vec<(u64, f64)>>,
    /// Full end-of-training RMS telemetry (site name, rms) — Fig 6 right.
    pub final_rms: Vec<(String, f64)>,
    pub diverged: bool,
    pub wall_seconds: f64,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let curve = |c: &Vec<(u64, f64)>| {
            Json::Arr(
                c.iter()
                    .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l)]))
                    .collect(),
            )
        };
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("train_curve".into(), curve(&self.train_curve));
        m.insert("valid_curve".into(), curve(&self.valid_curve));
        m.insert("final_valid_loss".into(), Json::Num(self.final_valid_loss));
        m.insert("diverged".into(), Json::Bool(self.diverged));
        m.insert("wall_seconds".into(), Json::Num(self.wall_seconds));
        let rms: BTreeMap<String, Json> =
            self.rms_curves.iter().map(|(k, v)| (k.clone(), curve(v))).collect();
        m.insert("rms_curves".into(), Json::Obj(rms));
        m.insert(
            "final_rms".into(),
            Json::Arr(
                self.final_rms
                    .iter()
                    .map(|(n, v)| Json::Arr(vec![Json::Str(n.clone()), Json::Num(*v)]))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// The sweep objective: final validation loss, with divergence mapped
    /// to +inf so argmin never picks an exploded run.
    pub fn objective(&self) -> f64 {
        if self.diverged || !self.final_valid_loss.is_finite() {
            f64::INFINITY
        } else {
            self.final_valid_loss
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips() {
        let mut rms = BTreeMap::new();
        rms.insert("w.emb".to_string(), vec![(0u64, 1.0f64), (10, 1.1)]);
        let r = RunRecord {
            label: "test".into(),
            train_curve: vec![(1, 5.0), (2, 4.5)],
            valid_curve: vec![(2, 4.8)],
            final_valid_loss: 4.8,
            rms_curves: rms,
            final_rms: vec![("w.emb".into(), 1.0)],
            diverged: false,
            wall_seconds: 1.5,
        };
        let j = r.to_json().dump();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("final_valid_loss").unwrap().as_f64().unwrap(), 4.8);
    }

    #[test]
    fn diverged_objective_is_inf() {
        let r = RunRecord {
            label: "x".into(),
            train_curve: vec![],
            valid_curve: vec![],
            final_valid_loss: 2.0,
            rms_curves: BTreeMap::new(),
            final_rms: vec![],
            diverged: true,
            wall_seconds: 0.0,
        };
        assert_eq!(r.objective(), f64::INFINITY);
    }
}
