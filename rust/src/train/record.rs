//! Run records: everything one training run produced, serializable to
//! JSON for the experiment reports.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::util::{write_json_num, write_json_str, Json};

/// The outcome of one training run.
///
/// `PartialEq` is derived for the wire/cache round-trip tests (NaN
/// losses compare unequal, as IEEE semantics dictate — the codec maps
/// them to `+inf` anyway, see [`RunRecord::from_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub label: String,
    /// (step, training loss) at the logging cadence.
    pub train_curve: Vec<(u64, f64)>,
    /// (step, validation loss).
    pub valid_curve: Vec<(u64, f64)>,
    /// Final smoothed validation loss (the sweep objective).
    pub final_valid_loss: f64,
    /// RMS telemetry snapshots: site name -> (step, rms) series.
    pub rms_curves: BTreeMap<String, Vec<(u64, f64)>>,
    /// Full end-of-training RMS telemetry (site name, rms) — Fig 6 right.
    pub final_rms: Vec<(String, f64)>,
    pub diverged: bool,
    pub wall_seconds: f64,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let curve = |c: &Vec<(u64, f64)>| {
            Json::Arr(
                c.iter()
                    .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l)]))
                    .collect(),
            )
        };
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("train_curve".into(), curve(&self.train_curve));
        m.insert("valid_curve".into(), curve(&self.valid_curve));
        m.insert("final_valid_loss".into(), Json::Num(self.final_valid_loss));
        m.insert("diverged".into(), Json::Bool(self.diverged));
        m.insert("wall_seconds".into(), Json::Num(self.wall_seconds));
        let rms: BTreeMap<String, Json> =
            self.rms_curves.iter().map(|(k, v)| (k.clone(), curve(v))).collect();
        m.insert("rms_curves".into(), Json::Obj(rms));
        m.insert(
            "final_rms".into(),
            Json::Arr(
                self.final_rms
                    .iter()
                    .map(|(n, v)| Json::Arr(vec![Json::Str(n.clone()), Json::Num(*v)]))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Append this record's JSON object to `out`, byte-identical to
    /// `self.to_json().dump()` but without building the value tree —
    /// the allocation-free half of the wire codec's `_into` hot path.
    /// Field order is the tree writer's `BTreeMap` (alphabetical)
    /// order; the byte-equality contract is pinned by a unit test
    /// below, so any field added to [`RunRecord::to_json`] must be
    /// mirrored here.
    pub fn json_into(&self, out: &mut String) {
        fn curve_into(c: &[(u64, f64)], out: &mut String) {
            out.push('[');
            for (i, &(s, l)) in c.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('[');
                write_json_num(s as f64, out);
                out.push(',');
                write_json_num(l, out);
                out.push(']');
            }
            out.push(']');
        }
        out.push_str("{\"diverged\":");
        out.push_str(if self.diverged { "true" } else { "false" });
        out.push_str(",\"final_rms\":[");
        for (i, (site, v)) in self.final_rms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            write_json_str(site, out);
            out.push(',');
            write_json_num(*v, out);
            out.push(']');
        }
        out.push_str("],\"final_valid_loss\":");
        write_json_num(self.final_valid_loss, out);
        out.push_str(",\"label\":");
        write_json_str(&self.label, out);
        out.push_str(",\"rms_curves\":{");
        for (i, (site, c)) in self.rms_curves.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_json_str(site, out);
            out.push(':');
            curve_into(c, out);
        }
        out.push_str("},\"train_curve\":");
        curve_into(&self.train_curve, out);
        out.push_str(",\"valid_curve\":");
        curve_into(&self.valid_curve, out);
        out.push_str(",\"wall_seconds\":");
        write_json_num(self.wall_seconds, out);
        out.push('}');
    }

    /// Parse a record serialized by [`RunRecord::to_json`] (the run
    /// cache's JSONL payload).  Non-finite losses are dumped as JSON
    /// `null` and read back as +inf — the divergence sentinel.
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        fn num(j: &Json) -> Result<f64> {
            match j {
                Json::Null => Ok(f64::INFINITY),
                _ => j.as_f64(),
            }
        }
        fn curve(j: &Json) -> Result<Vec<(u64, f64)>> {
            j.as_arr()?
                .iter()
                .map(|p| -> Result<(u64, f64)> {
                    let p = p.as_arr()?;
                    ensure!(p.len() == 2, "curve point must be a [step, value] pair");
                    Ok((p[0].as_f64()? as u64, num(&p[1])?))
                })
                .collect()
        }
        let mut rms_curves = BTreeMap::new();
        for (k, v) in j.get("rms_curves")?.as_obj()? {
            rms_curves.insert(k.clone(), curve(v)?);
        }
        let final_rms = j
            .get("final_rms")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<(String, f64)> {
                let p = p.as_arr()?;
                ensure!(p.len() == 2, "final_rms entry must be a [site, value] pair");
                Ok((p[0].as_str()?.to_string(), num(&p[1])?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunRecord {
            label: j.get("label")?.as_str()?.to_string(),
            train_curve: curve(j.get("train_curve")?)?,
            valid_curve: curve(j.get("valid_curve")?)?,
            final_valid_loss: num(j.get("final_valid_loss")?)?,
            rms_curves,
            final_rms,
            diverged: j.get("diverged")?.as_bool()?,
            wall_seconds: j.get("wall_seconds")?.as_f64()?,
        })
    }

    /// The sweep objective: final validation loss, with divergence mapped
    /// to +inf so argmin never picks an exploded run.
    pub fn objective(&self) -> f64 {
        if self.diverged || !self.final_valid_loss.is_finite() {
            f64::INFINITY
        } else {
            self.final_valid_loss
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips() {
        let mut rms = BTreeMap::new();
        rms.insert("w.emb".to_string(), vec![(0u64, 1.0f64), (10, 1.1)]);
        let r = RunRecord {
            label: "test".into(),
            train_curve: vec![(1, 5.0), (2, 4.5)],
            valid_curve: vec![(2, 4.8)],
            final_valid_loss: 4.8,
            rms_curves: rms,
            final_rms: vec![("w.emb".into(), 1.0)],
            diverged: false,
            wall_seconds: 1.5,
        };
        let j = r.to_json().dump();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("final_valid_loss").unwrap().as_f64().unwrap(), 4.8);
    }

    #[test]
    fn from_json_round_trips_including_divergence() {
        let mut rms = BTreeMap::new();
        rms.insert("w.head".to_string(), vec![(1u64, 0.9f64), (8, 1.4)]);
        let r = RunRecord {
            label: "boom".into(),
            train_curve: vec![(1, 5.0), (2, f64::NAN)],
            valid_curve: vec![],
            final_valid_loss: f64::INFINITY,
            rms_curves: rms,
            final_rms: vec![("w.head".into(), 1.4)],
            diverged: true,
            wall_seconds: 0.25,
        };
        let parsed = Json::parse(&r.to_json().dump()).unwrap();
        let back = RunRecord::from_json(&parsed).unwrap();
        assert_eq!(back.label, "boom");
        assert!(back.diverged);
        assert_eq!(back.final_valid_loss, f64::INFINITY);
        assert_eq!(back.objective(), f64::INFINITY);
        assert_eq!(back.train_curve[0], (1, 5.0));
        // NaN in a curve is stored as null and read back as +inf
        assert_eq!(back.train_curve[1].0, 2);
        assert!(back.train_curve[1].1.is_infinite());
        assert_eq!(back.rms_curves["w.head"], vec![(1, 0.9), (8, 1.4)]);
        assert_eq!(back.final_rms, vec![("w.head".to_string(), 1.4)]);
        assert_eq!(back.wall_seconds, 0.25);
    }

    /// The hand-rolled writer must stay byte-identical to the tree
    /// writer — the cache/wire byte-determinism contract rides on it.
    #[test]
    fn json_into_matches_to_json_dump_byte_for_byte() {
        let mut rms = BTreeMap::new();
        rms.insert("w.emb".to_string(), vec![(0u64, 1.0f64), (10, 1.125)]);
        rms.insert("w.head\"q\u{1}".to_string(), vec![(8, f64::NAN)]);
        let records = [
            RunRecord {
                label: "päy\nlöad \"x\"".into(),
                train_curve: vec![(1, 5.0), (2, 4.5), (3, f64::INFINITY)],
                valid_curve: vec![(2, 4.8125)],
                final_valid_loss: 4.8125,
                rms_curves: rms,
                final_rms: vec![("w.emb".into(), 1.0), ("w.\\q".into(), f64::NAN)],
                diverged: false,
                wall_seconds: 1.5,
            },
            RunRecord {
                label: String::new(),
                train_curve: vec![],
                valid_curve: vec![],
                final_valid_loss: f64::INFINITY,
                rms_curves: BTreeMap::new(),
                final_rms: vec![],
                diverged: true,
                wall_seconds: 1e16 + 0.25,
            },
        ];
        for r in &records {
            let mut hand = String::from("prefix-preserved:");
            r.json_into(&mut hand);
            assert_eq!(hand, format!("prefix-preserved:{}", r.to_json().dump()));
        }
    }

    #[test]
    fn diverged_objective_is_inf() {
        let r = RunRecord {
            label: "x".into(),
            train_curve: vec![],
            valid_curve: vec![],
            final_valid_loss: 2.0,
            rms_curves: BTreeMap::new(),
            final_rms: vec![],
            diverged: true,
            wall_seconds: 0.0,
        };
        assert_eq!(r.objective(), f64::INFINITY);
    }
}
