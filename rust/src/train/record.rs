//! Run records: everything one training run produced, serializable to
//! JSON for the experiment reports.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::util::Json;

/// The outcome of one training run.
///
/// `PartialEq` is derived for the wire/cache round-trip tests (NaN
/// losses compare unequal, as IEEE semantics dictate — the codec maps
/// them to `+inf` anyway, see [`RunRecord::from_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub label: String,
    /// (step, training loss) at the logging cadence.
    pub train_curve: Vec<(u64, f64)>,
    /// (step, validation loss).
    pub valid_curve: Vec<(u64, f64)>,
    /// Final smoothed validation loss (the sweep objective).
    pub final_valid_loss: f64,
    /// RMS telemetry snapshots: site name -> (step, rms) series.
    pub rms_curves: BTreeMap<String, Vec<(u64, f64)>>,
    /// Full end-of-training RMS telemetry (site name, rms) — Fig 6 right.
    pub final_rms: Vec<(String, f64)>,
    pub diverged: bool,
    pub wall_seconds: f64,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let curve = |c: &Vec<(u64, f64)>| {
            Json::Arr(
                c.iter()
                    .map(|&(s, l)| Json::Arr(vec![Json::Num(s as f64), Json::Num(l)]))
                    .collect(),
            )
        };
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("train_curve".into(), curve(&self.train_curve));
        m.insert("valid_curve".into(), curve(&self.valid_curve));
        m.insert("final_valid_loss".into(), Json::Num(self.final_valid_loss));
        m.insert("diverged".into(), Json::Bool(self.diverged));
        m.insert("wall_seconds".into(), Json::Num(self.wall_seconds));
        let rms: BTreeMap<String, Json> =
            self.rms_curves.iter().map(|(k, v)| (k.clone(), curve(v))).collect();
        m.insert("rms_curves".into(), Json::Obj(rms));
        m.insert(
            "final_rms".into(),
            Json::Arr(
                self.final_rms
                    .iter()
                    .map(|(n, v)| Json::Arr(vec![Json::Str(n.clone()), Json::Num(*v)]))
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Parse a record serialized by [`RunRecord::to_json`] (the run
    /// cache's JSONL payload).  Non-finite losses are dumped as JSON
    /// `null` and read back as +inf — the divergence sentinel.
    pub fn from_json(j: &Json) -> Result<RunRecord> {
        fn num(j: &Json) -> Result<f64> {
            match j {
                Json::Null => Ok(f64::INFINITY),
                _ => j.as_f64(),
            }
        }
        fn curve(j: &Json) -> Result<Vec<(u64, f64)>> {
            j.as_arr()?
                .iter()
                .map(|p| -> Result<(u64, f64)> {
                    let p = p.as_arr()?;
                    ensure!(p.len() == 2, "curve point must be a [step, value] pair");
                    Ok((p[0].as_f64()? as u64, num(&p[1])?))
                })
                .collect()
        }
        let mut rms_curves = BTreeMap::new();
        for (k, v) in j.get("rms_curves")?.as_obj()? {
            rms_curves.insert(k.clone(), curve(v)?);
        }
        let final_rms = j
            .get("final_rms")?
            .as_arr()?
            .iter()
            .map(|p| -> Result<(String, f64)> {
                let p = p.as_arr()?;
                ensure!(p.len() == 2, "final_rms entry must be a [site, value] pair");
                Ok((p[0].as_str()?.to_string(), num(&p[1])?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RunRecord {
            label: j.get("label")?.as_str()?.to_string(),
            train_curve: curve(j.get("train_curve")?)?,
            valid_curve: curve(j.get("valid_curve")?)?,
            final_valid_loss: num(j.get("final_valid_loss")?)?,
            rms_curves,
            final_rms,
            diverged: j.get("diverged")?.as_bool()?,
            wall_seconds: j.get("wall_seconds")?.as_f64()?,
        })
    }

    /// The sweep objective: final validation loss, with divergence mapped
    /// to +inf so argmin never picks an exploded run.
    pub fn objective(&self) -> f64 {
        if self.diverged || !self.final_valid_loss.is_finite() {
            f64::INFINITY
        } else {
            self.final_valid_loss
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips() {
        let mut rms = BTreeMap::new();
        rms.insert("w.emb".to_string(), vec![(0u64, 1.0f64), (10, 1.1)]);
        let r = RunRecord {
            label: "test".into(),
            train_curve: vec![(1, 5.0), (2, 4.5)],
            valid_curve: vec![(2, 4.8)],
            final_valid_loss: 4.8,
            rms_curves: rms,
            final_rms: vec![("w.emb".into(), 1.0)],
            diverged: false,
            wall_seconds: 1.5,
        };
        let j = r.to_json().dump();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("final_valid_loss").unwrap().as_f64().unwrap(), 4.8);
    }

    #[test]
    fn from_json_round_trips_including_divergence() {
        let mut rms = BTreeMap::new();
        rms.insert("w.head".to_string(), vec![(1u64, 0.9f64), (8, 1.4)]);
        let r = RunRecord {
            label: "boom".into(),
            train_curve: vec![(1, 5.0), (2, f64::NAN)],
            valid_curve: vec![],
            final_valid_loss: f64::INFINITY,
            rms_curves: rms,
            final_rms: vec![("w.head".into(), 1.4)],
            diverged: true,
            wall_seconds: 0.25,
        };
        let parsed = Json::parse(&r.to_json().dump()).unwrap();
        let back = RunRecord::from_json(&parsed).unwrap();
        assert_eq!(back.label, "boom");
        assert!(back.diverged);
        assert_eq!(back.final_valid_loss, f64::INFINITY);
        assert_eq!(back.objective(), f64::INFINITY);
        assert_eq!(back.train_curve[0], (1, 5.0));
        // NaN in a curve is stored as null and read back as +inf
        assert_eq!(back.train_curve[1].0, 2);
        assert!(back.train_curve[1].1.is_infinite());
        assert_eq!(back.rms_curves["w.head"], vec![(1, 0.9), (8, 1.4)]);
        assert_eq!(back.final_rms, vec![("w.head".to_string(), 1.4)]);
        assert_eq!(back.wall_seconds, 0.25);
    }

    #[test]
    fn diverged_objective_is_inf() {
        let r = RunRecord {
            label: "x".into(),
            train_curve: vec![],
            valid_curve: vec![],
            final_valid_loss: 2.0,
            rms_curves: BTreeMap::new(),
            final_rms: vec![],
            diverged: true,
            wall_seconds: 0.0,
        };
        assert_eq!(r.objective(), f64::INFINITY);
    }
}
