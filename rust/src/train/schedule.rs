//! LR schedules and optimizer configuration (paper Table 5 / A.3).
//!
//! The coordinator owns the step counter, so the schedule and Adam
//! bias-correction are computed here and shipped to the compiled step as
//! the 8-float `hyp` vector (python/compile/optim.py mirror).

/// Which decay shape to use after warmup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleKind {
    /// Constant LR (the Tensor Programs V setup, Fig 2a).
    Constant,
    /// Cosine decay to `final_frac`·peak (Table 5: 10%).
    CosineTo(f64),
    /// Linear decay to zero (A.3.3, "straight to zero").
    LinearToZero,
}

/// A complete schedule: warmup then decay over `total_steps`.
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub kind: ScheduleKind,
    pub peak_lr: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl Schedule {
    /// Table 5 default: cosine to 10% with warmup.
    pub fn standard(peak_lr: f64, total_steps: u64, warmup_steps: u64) -> Schedule {
        Schedule { kind: ScheduleKind::CosineTo(0.1), peak_lr, warmup_steps, total_steps }
    }

    /// LR at 1-based step `t`.
    pub fn lr_at(&self, t: u64) -> f64 {
        if self.total_steps == 0 {
            return self.peak_lr;
        }
        if t <= self.warmup_steps && self.warmup_steps > 0 {
            return self.peak_lr * t as f64 / self.warmup_steps as f64;
        }
        let t = t.min(self.total_steps);
        let span = (self.total_steps - self.warmup_steps).max(1) as f64;
        let frac = (t - self.warmup_steps) as f64 / span;
        match self.kind {
            ScheduleKind::Constant => self.peak_lr,
            ScheduleKind::CosineTo(final_frac) => {
                let floor = self.peak_lr * final_frac;
                floor
                    + 0.5 * (self.peak_lr - floor) * (1.0 + (std::f64::consts::PI * frac).cos())
            }
            ScheduleKind::LinearToZero => self.peak_lr * (1.0 - frac),
        }
    }
}

/// AdamW configuration (Table 5: β=(0.9, 0.999), ε=1e-8, wd 2^-13
/// independent).
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Coupled decay coefficient (standard AdamW: inside the lr product).
    pub wd_coupled: f64,
    /// Independent decay coefficient (Wortsman et al.; the §3.1 fix).
    pub wd_indep: f64,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            wd_coupled: 0.0,
            wd_indep: (2.0f64).powi(-13),
        }
    }
}

impl AdamConfig {
    /// Plain Adam (the Tensor Programs V setup).
    pub fn plain_adam() -> Self {
        AdamConfig { wd_coupled: 0.0, wd_indep: 0.0, ..Default::default() }
    }

    /// Standard (coupled) AdamW at the Table 5 decay strength.
    pub fn coupled() -> Self {
        AdamConfig { wd_coupled: (2.0f64).powi(-13), wd_indep: 0.0, ..Default::default() }
    }

    /// The `hyp` step input for 1-based step `t` at learning rate `lr`.
    pub fn hyp(&self, lr: f64, t: u64) -> [f32; 8] {
        let bc1 = 1.0 / (1.0 - self.beta1.powi(t as i32));
        let bc2 = 1.0 / (1.0 - self.beta2.powi(t as i32));
        [
            lr as f32,
            self.wd_coupled as f32,
            self.wd_indep as f32,
            self.beta1 as f32,
            self.beta2 as f32,
            self.eps as f32,
            bc1 as f32,
            bc2 as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_is_linear() {
        let s = Schedule::standard(1.0, 100, 10);
        assert!((s.lr_at(1) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(5) - 0.5).abs() < 1e-12);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_hits_floor() {
        let s = Schedule::standard(1.0, 100, 10);
        assert!((s.lr_at(100) - 0.1).abs() < 1e-9);
        // midpoint of decay ≈ mean of peak and floor
        let mid = s.lr_at(55);
        assert!((mid - 0.55).abs() < 0.01);
    }

    #[test]
    fn linear_to_zero() {
        let s = Schedule {
            kind: ScheduleKind::LinearToZero,
            peak_lr: 2.0,
            warmup_steps: 0,
            total_steps: 10,
        };
        assert!((s.lr_at(10) - 0.0).abs() < 1e-12);
        assert!((s.lr_at(5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_stays() {
        let s = Schedule {
            kind: ScheduleKind::Constant,
            peak_lr: 0.3,
            warmup_steps: 0,
            total_steps: 50,
        };
        assert_eq!(s.lr_at(1), 0.3);
        assert_eq!(s.lr_at(50), 0.3);
    }

    #[test]
    fn bias_correction() {
        let a = AdamConfig::default();
        let h = a.hyp(0.5, 1);
        assert!((h[6] - 10.0).abs() < 1e-4); // 1/(1-0.9)
        assert!((h[7] - 1000.0).abs() < 0.5); // 1/(1-0.999)
        let h = a.hyp(0.5, 10_000);
        assert!((h[6] - 1.0).abs() < 1e-5);
    }
}
