//! # umup — u-μP: The Unit-Scaled Maximal Update Parametrization
//!
//! A three-layer Rust + JAX + Pallas reproduction of *u-μP: The
//! Unit-Scaled Maximal Update Parametrization* (Blake, Eichenberg et al.,
//! 2024).
//!
//! Layering (see DESIGN.md):
//! * **L1** (Pallas, `python/compile/kernels/`): FP8 grid-quantizer and
//!   tiled unit-scaled matmul kernels.
//! * **L2** (JAX, `python/compile/`): the scaled Llama-style transformer
//!   with runtime *scale hooks*, AOT-lowered to HLO-text artifacts.
//! * **L3** (this crate): everything at runtime — the numeric-format
//!   substrate, the abc-parametrization engine (the paper's contribution),
//!   the PJRT runtime, training/sweep/experiment coordination. Python is
//!   never on the training path.
//!
//! The PJRT runtime is behind the `xla` cargo feature (on by default).
//! With `--no-default-features` everything pure still builds — the
//! parametrization rules, sweep planning, the engine's sharded run
//! cache and its `repro cache gc`/`stats` lifecycle, the execution
//! backend layer (`engine::backend`, including the `ProcessBackend`
//! wire protocol and the `repro worker --mock` child), and the
//! mock-backend test suites — which is what the no-XLA CI job checks.

#[cfg(feature = "xla")]
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod formats;
pub mod parametrization;
pub mod runtime;
pub mod sweep;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
