//! Job and outcome types for the engine.

use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, Result};

use crate::data::Corpus;
use crate::runtime::Manifest;
use crate::train::{RunConfig, RunRecord};

/// One queued run: a config plus the artifact and data it runs against.
///
/// Jobs in one `Engine::run` batch may span different manifests (shapes)
/// — the queue is multi-manifest by construction, so cross-width
/// transfer sweeps are drained by one worker pool instead of being
/// serialized per shape.
///
/// Construct via [`EngineJob::new`]: the job carries a lazily-computed,
/// clone-shared memo of its canonical identity (the sorted-key config
/// JSON and the FNV content address derived from it), so the canonical
/// form is serialized **once** per job — `submit` hashes it for the
/// run-cache key and the process backend splices the same bytes into
/// its wire frame, instead of each rebuilding the tree.
///
/// **Invariant:** `manifest`/`corpus`/`config` must not be mutated once
/// [`EngineJob::key`] has been observed — the memo (shared by clones)
/// would go stale and the job would execute under the wrong content
/// address.  Build the config fully, then construct the job; debug
/// builds assert the memo still matches on every access.
#[derive(Clone)]
pub struct EngineJob {
    pub manifest: Arc<Manifest>,
    pub corpus: Arc<Corpus>,
    pub config: RunConfig,
    /// Arbitrary tag carried through to the result (e.g. HP values).
    pub tag: Vec<(String, f64)>,
    /// Memoized canonical identity; private so every construction path
    /// goes through [`EngineJob::new`] and clones share the memo.
    canon: OnceLock<Arc<JobCanon>>,
}

/// The expensive-to-compute parts of a job's identity, computed at most
/// once per job (shared across clones via `Arc`).
struct JobCanon {
    /// `config.canonical_json().dump()` — the label-free sorted-key
    /// serialization that is hashed into the run key and shipped as the
    /// wire frame's `config` member.
    config_json: String,
    /// The 16-hex-digit content address ([`crate::engine::run_key`]).
    key: String,
}

impl EngineJob {
    pub fn new(
        manifest: Arc<Manifest>,
        corpus: Arc<Corpus>,
        config: RunConfig,
        tag: Vec<(String, f64)>,
    ) -> EngineJob {
        EngineJob { manifest, corpus, config, tag, canon: OnceLock::new() }
    }

    fn canon(&self) -> &JobCanon {
        let canon = self.canon.get_or_init(|| {
            let config_json = self.config.canonical_json().dump();
            let key = crate::engine::cache::run_key_from_dumps(
                &self.manifest.name,
                &crate::engine::cache::corpus_json(&self.corpus.config).dump(),
                &config_json,
            );
            Arc::new(JobCanon { config_json, key })
        });
        debug_assert_eq!(
            canon.config_json,
            self.config.canonical_json().dump(),
            "EngineJob config mutated after its identity was memoized (label {:?})",
            self.config.label
        );
        canon
    }

    /// This job's content address — the run-cache key and the identity
    /// carried on the worker wire protocol.  Computed once per job
    /// (clones share the memo).
    pub fn key(&self) -> String {
        self.canon().key.clone()
    }

    /// The canonical (label-free, sorted-key) config serialization this
    /// job's key was hashed from — reused verbatim by the process
    /// backend's wire frame.  Computed once per job.
    pub fn canonical_config_json(&self) -> &str {
        &self.canon().config_json
    }
}

/// A manifest-agnostic sweep job: the caller supplies the manifest and
/// corpus once for the whole batch (`Engine::run_sweep`).
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub config: RunConfig,
    /// Arbitrary tag carried through to the result (e.g. HP values).
    pub tag: Vec<(String, f64)>,
}

#[derive(Debug, Clone)]
pub struct SweepResult {
    pub job: SweepJob,
    pub record: RunRecord,
}

/// How one job concluded.  Streams out of a
/// [`crate::engine::SweepHandle`] in completion order.
#[derive(Clone)]
pub struct JobOutcome {
    /// This job's index within its submission (stable addressing for
    /// streaming consumers; `EngineReport.outcomes[idx]` is this job).
    pub idx: usize,
    pub job: EngineJob,
    /// Per-job result; errors are stringified so one bad job never
    /// poisons the rest of the batch.
    pub outcome: Result<RunRecord, String>,
    /// True when the record came from the run cache or a deduplicated
    /// sibling job rather than a fresh run.
    pub cached: bool,
    /// True when a sharded engine declined the job because its content
    /// address belongs to another shard (the `outcome` is then an `Err`
    /// naming the owning shard).  Skips are not failures: the owning
    /// shard process runs the job, and a later `--resume` pass over the
    /// shared cache dir resolves it as a cache hit.
    pub skipped: bool,
    /// True when the submission was cancelled while this job was still
    /// queued: it never executed (the `outcome` is a cancellation
    /// `Err`).  In-flight jobs are *not* cancelled — they complete and
    /// report normally.
    pub cancelled: bool,
}

/// Everything one submission produced: per-job outcomes in submission
/// order plus progress counters ([`crate::engine::SweepHandle::wait`]).
pub struct EngineReport {
    pub outcomes: Vec<JobOutcome>,
    /// Jobs that ended with a record (fresh, cached or deduplicated).
    pub completed: usize,
    /// Jobs that genuinely errored (excludes shard skips and
    /// cancellations).
    pub failed: usize,
    pub cache_hits: usize,
    /// Jobs resolved by an identical job earlier in the same batch.
    pub deduped: usize,
    /// Jobs declined because their key belongs to another shard.
    pub skipped: usize,
    /// Jobs that actually ran on a worker.
    pub executed: usize,
    /// Jobs cancelled while still queued (never executed).
    pub cancelled: usize,
}

impl EngineReport {
    /// One-line progress summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "{} jobs: {} run, {} cached, {} deduped, {} skipped, {} cancelled, {} failed",
            self.outcomes.len(),
            self.executed,
            self.cache_hits,
            self.deduped,
            self.skipped,
            self.cancelled,
            self.failed
        )
    }

    /// Strict view: job-ordered results, or the first per-job error.
    /// Every job was still attempted — an error here never means work
    /// was silently abandoned.
    pub fn into_sweep_results(self) -> Result<Vec<SweepResult>> {
        let mut out = Vec::with_capacity(self.outcomes.len());
        for (i, o) in self.outcomes.into_iter().enumerate() {
            match o.outcome {
                Ok(record) => out.push(SweepResult {
                    job: SweepJob { config: o.job.config, tag: o.job.tag },
                    record,
                }),
                Err(e) => {
                    return Err(anyhow!("sweep job {i} ({}): {e}", o.job.config.label));
                }
            }
        }
        Ok(out)
    }
}
