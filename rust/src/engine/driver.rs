//! The shard driver: spawn, monitor, and restart the N shard processes
//! of a sharded sweep against one shared cache directory.
//!
//! PR 2 made N *manually started* processes (`repro exp --shard i/n
//! --cache-dir D --resume`) drain disjoint slices of one sweep into one
//! directory.  This module closes the remaining gap from the ROADMAP:
//! one parent process owns the topology.  [`drive`] launches one child
//! per shard from a caller-supplied command factory, polls them,
//! restarts crashed children (bounded per shard — a crashed child's
//! stale segment lock is reclaimed automatically on restart, and its
//! already-persisted runs are picked up via `--resume`), and streams
//! merged progress by watching the shared cache directory's segments
//! grow.  The CLI front end is `repro drive --shards n`.
//!
//! Progress is observed through a [`CacheWatcher`] — the run cache's
//! incremental, lock-free tail reader — so each poll costs bytes
//! *appended since the last poll*, not a full re-read of every segment:
//! at a 500 ms poll interval over a 10⁵-entry cache the difference is
//! the drive loop being free versus the drive loop being the second
//! hottest thing on the machine.
//!
//! The same idle path can optionally run background tiered merges
//! ([`DriveConfig::background_compaction`]): every few seconds the
//! driver offers the [`Compactor`] one step, folding similar-sized
//! finished segments so a long sweep ends with a handful of large
//! segments instead of one per restart.  Merge locks are non-blocking,
//! so a live child never waits on the parent.
//!
//! The driver is deliberately execution-agnostic: it never talks to the
//! engine, only to child processes and the cache dir, so it builds (and
//! is integration-tested) without the XLA runtime — the test harness
//! drives mock-executor children through exactly this code path.

use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::cache::{CacheWatcher, Compactor, Shard};
use super::events::{Event, EventBus};

/// How often the drive loop attempts a background tier-merge step when
/// [`DriveConfig::background_compaction`] is on.
const COMPACT_EVERY: Duration = Duration::from_secs(5);

/// Driver options.
#[derive(Debug, Clone)]
pub struct DriveConfig {
    /// Number of shard processes (each runs shard `i/shards`).
    pub shards: usize,
    /// The shared cache directory the children drain into; merged
    /// progress is read from its segments (no locks taken).
    pub cache_dir: PathBuf,
    /// Restart budget *per shard*: a child may crash and be relaunched
    /// this many times before the drive is declared failed.
    pub max_restarts_per_shard: usize,
    /// How often to poll children and cache progress.
    pub poll_interval: Duration,
    /// Print merged progress lines to stderr as results accumulate.
    pub progress: bool,
    /// Step a size-tiered [`Compactor`] against the cache dir from the
    /// drive loop's idle path (every [`COMPACT_EVERY`]), folding
    /// similar-sized finished segments while the sweep still runs.
    /// Merges take only non-blocking locks, so a live child's segment
    /// is never touched.  Off by default: merging rewrites segment
    /// files mid-drive, and callers that assert on byte-identical
    /// drive output (the deterministic test harness) must opt in.
    pub background_compaction: bool,
    /// Telemetry bus for the drive's own lifecycle events
    /// (`shard_spawned` / `shard_exit` / `shard_restarted` /
    /// `snapshot`).  `None` (the default) keeps the drive loop
    /// event-free and its stderr output byte-identical to a bus-less
    /// build — events are purely additive.
    pub events: Option<EventBus>,
    /// JSONL event files written by the shard children (each child runs
    /// with `--progress jsonl:<file>`).  The driver tails every file
    /// incrementally from its poll loop and forwards each complete line
    /// verbatim ([`Event::ChildLine`]) onto [`DriveConfig::events`], so
    /// one merged stream carries parent and child telemetry.  Ignored
    /// when `events` is `None`.
    pub child_event_files: Vec<PathBuf>,
    /// Graceful-drain flag (wired to [`crate::util::signal`] by `repro
    /// drive`, or flipped directly in tests): when it goes true the
    /// poll loop stops with an error naming the signal, and [`drive`]'s
    /// normal error teardown kills the surviving children — their
    /// already-persisted runs stay resumable in the cache dir.
    pub stop: Option<Arc<AtomicBool>>,
}

impl Default for DriveConfig {
    fn default() -> Self {
        DriveConfig {
            shards: 2,
            cache_dir: PathBuf::from("results/run-cache"),
            max_restarts_per_shard: 2,
            poll_interval: Duration::from_millis(500),
            progress: true,
            background_compaction: false,
            events: None,
            child_event_files: Vec::new(),
            stop: None,
        }
    }
}

/// Terminal state of one shard's slot.
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    pub shard: usize,
    /// Launches performed (1 = no restarts).
    pub attempts: usize,
    pub success: bool,
}

/// What one [`drive`] call did.
#[derive(Debug, Clone)]
pub struct DriveReport {
    pub shard_outcomes: Vec<ShardOutcome>,
    /// Total restarts across all shards.
    pub restarts: usize,
    /// Unique run keys visible in the cache dir when the drive ended.
    pub cache_entries: usize,
    pub elapsed: Duration,
}

/// One child slot in the drive loop.
struct Slot {
    shard: Shard,
    child: Option<Child>,
    attempts: usize,
    done: bool,
}

/// Incremental tail over one child's JSONL event file: each poll reads
/// only the bytes appended since the last one and yields *complete*
/// lines (a torn line mid-append is held back until its newline
/// arrives).  The file may not exist yet — children create their own
/// streams — so open failures just mean "nothing new".
struct FileTail {
    path: PathBuf,
    offset: u64,
    partial: String,
}

impl FileTail {
    fn new(path: &Path) -> FileTail {
        FileTail { path: path.to_path_buf(), offset: 0, partial: String::new() }
    }

    fn poll(&mut self) -> Vec<String> {
        let Ok(mut f) = std::fs::File::open(&self.path) else {
            return Vec::new();
        };
        if f.seek(SeekFrom::Start(self.offset)).is_err() {
            return Vec::new();
        }
        let mut buf = String::new();
        let Ok(n) = f.read_to_string(&mut buf) else {
            return Vec::new();
        };
        self.offset += n as u64;
        self.partial.push_str(&buf);
        let mut lines = Vec::new();
        while let Some(nl) = self.partial.find('\n') {
            let line: String = self.partial.drain(..=nl).collect();
            let line = line.trim_end();
            if !line.is_empty() {
                lines.push(line.to_string());
            }
        }
        lines
    }
}

/// Spawn `cfg.shards` children via `make_cmd(shard)` and babysit them to
/// completion.  Children's stdout is silenced (the parent owns the
/// terminal; progress is merged from the cache dir), stderr is
/// inherited so failures stay visible.  Returns an error — after
/// killing the surviving children — if any shard exhausts its restart
/// budget.
pub fn drive<F>(cfg: &DriveConfig, mut make_cmd: F) -> Result<DriveReport>
where
    F: FnMut(Shard) -> Command,
{
    if cfg.shards == 0 {
        bail!("drive needs at least one shard");
    }
    let t0 = Instant::now();
    let mut slots: Vec<Slot> = (0..cfg.shards)
        .map(|i| Slot {
            shard: Shard { index: i, count: cfg.shards },
            child: None,
            attempts: 0,
            done: false,
        })
        .collect();
    // incremental progress reader over the shared cache dir (no locks;
    // children appending concurrently surface at worst one poll late)
    let mut watcher = CacheWatcher::new(&cfg.cache_dir);
    // every error path — budget exhaustion, a failed (re)launch, a
    // poll error — tears the surviving children down before returning,
    // so a failed drive never leaves orphans holding segment locks
    match run_to_completion(cfg, &mut slots, &mut watcher, &mut make_cmd) {
        Ok(restarts) => {
            watcher.poll();
            let cache_entries = watcher.unique_keys();
            Ok(DriveReport {
                shard_outcomes: slots
                    .iter()
                    .map(|s| ShardOutcome {
                        shard: s.shard.index,
                        attempts: s.attempts,
                        success: s.done,
                    })
                    .collect(),
                restarts,
                cache_entries,
                elapsed: t0.elapsed(),
            })
        }
        Err(e) => {
            kill_all(&mut slots);
            Err(e)
        }
    }
}

/// Launch and babysit every slot; returns the total restart count once
/// all children have exited successfully.  Errors leave `slots` as-is —
/// the caller owns teardown.
fn run_to_completion<F>(
    cfg: &DriveConfig,
    slots: &mut [Slot],
    watcher: &mut CacheWatcher,
    make_cmd: &mut F,
) -> Result<usize>
where
    F: FnMut(Shard) -> Command,
{
    let t0 = Instant::now();
    let bus = cfg.events.clone().unwrap_or_default();
    let mut tails: Vec<FileTail> =
        cfg.child_event_files.iter().map(|p| FileTail::new(p)).collect();
    for slot in slots.iter_mut() {
        launch(slot, make_cmd)?;
        bus.publish(Event::ShardSpawned { shard: slot.shard.index, attempt: slot.attempts });
    }
    if cfg.progress {
        eprintln!(
            "drive: launched {} shard processes against {}",
            cfg.shards,
            cfg.cache_dir.display()
        );
    }

    let mut restarts = 0usize;
    let mut last_entries = usize::MAX;
    let mut last_compact = Instant::now();
    loop {
        // a drain signal stops the drive through the normal error path:
        // drive() kills the surviving children, and every run they
        // already persisted stays resumable
        if cfg.stop.as_ref().map_or(false, |s| s.load(Ordering::SeqCst)) {
            bail!(
                "drive: stop requested by signal; partial results remain resumable in {}",
                cfg.cache_dir.display()
            );
        }
        let mut all_done = true;
        for slot in slots.iter_mut() {
            if slot.done {
                continue;
            }
            all_done = false;
            let Some(child) = slot.child.as_mut() else { continue };
            let status = child
                .try_wait()
                .with_context(|| format!("polling shard {} child", slot.shard))?;
            match status {
                None => {} // still running
                Some(st) if st.success() => {
                    slot.done = true;
                    slot.child = None;
                    bus.publish(Event::ShardExit {
                        shard: slot.shard.index,
                        ok: true,
                        detail: st.to_string(),
                    });
                    if cfg.progress {
                        eprintln!("drive: shard {} finished", slot.shard);
                    }
                }
                Some(st) => {
                    slot.child = None;
                    bus.publish(Event::ShardExit {
                        shard: slot.shard.index,
                        ok: false,
                        detail: st.to_string(),
                    });
                    if slot.attempts > cfg.max_restarts_per_shard {
                        bail!(
                            "drive: shard {} failed ({st}) after {} attempts \
                             (restart budget {}); partial results remain resumable in {}",
                            slot.shard,
                            slot.attempts,
                            cfg.max_restarts_per_shard,
                            cfg.cache_dir.display()
                        );
                    }
                    restarts += 1;
                    eprintln!(
                        "drive: shard {} exited with {st}; restarting \
                         (attempt {} of {})",
                        slot.shard,
                        slot.attempts + 1,
                        cfg.max_restarts_per_shard + 1
                    );
                    bus.publish(Event::ShardRestarted {
                        shard: slot.shard.index,
                        attempt: slot.attempts + 1,
                        max_attempts: cfg.max_restarts_per_shard + 1,
                    });
                    launch(slot, make_cmd)?;
                    bus.publish(Event::ShardSpawned {
                        shard: slot.shard.index,
                        attempt: slot.attempts,
                    });
                }
            }
        }
        if all_done {
            // final drain: pick up any event lines the children flushed
            // in their last instants before exiting
            if bus.is_active() {
                for tail in tails.iter_mut() {
                    for line in tail.poll() {
                        bus.publish(Event::ChildLine { line });
                    }
                }
            }
            return Ok(restarts);
        }

        // forward the children's own event streams: tail each JSONL
        // file for newly completed lines and re-publish them verbatim
        // (the children stamped their own shard-tagged envelopes)
        if bus.is_active() {
            for tail in tails.iter_mut() {
                for line in tail.poll() {
                    bus.publish(Event::ChildLine { line });
                }
            }
        }
        // merged progress: tail only the bytes children appended since
        // the last poll (read-only, lock-free; concurrent appends at
        // worst show up a poll late)
        if cfg.progress || bus.is_active() {
            watcher.poll();
            if watcher.unique_keys() != last_entries {
                last_entries = watcher.unique_keys();
                let live = slots.iter().filter(|s| !s.done).count();
                if cfg.progress {
                    eprintln!(
                        "drive: {} runs cached across {} segments ({live} shard{} live)",
                        watcher.unique_keys(),
                        watcher.segments(),
                        if live == 1 { "" } else { "s" }
                    );
                }
                let secs = t0.elapsed().as_secs_f64();
                bus.publish(Event::Snapshot {
                    done: watcher.unique_keys(),
                    total: None,
                    cached_keys: watcher.unique_keys(),
                    segments: watcher.segments(),
                    throughput: if secs > 0.0 {
                        watcher.unique_keys() as f64 / secs
                    } else {
                        0.0
                    },
                    eta_s: None,
                    pool_hits: 0,
                    pool_steals: 0,
                    dropped: bus.dropped(),
                });
            }
        }
        // idle-path tiered merges: fold finished segments while the
        // sweep runs.  try-locked per group, so a live child's segment
        // is never touched; errors are logged, never fatal to the drive
        if cfg.background_compaction && last_compact.elapsed() >= COMPACT_EVERY {
            last_compact = Instant::now();
            match Compactor::new(&cfg.cache_dir).step() {
                Ok(Some(r)) if cfg.progress => eprintln!(
                    "drive: tier-merged {} segments into {} ({} entries, {} duplicate \
                     lines dropped)",
                    r.inputs.len(),
                    r.output,
                    r.entries,
                    r.deduped
                ),
                Ok(_) => {}
                Err(e) => eprintln!("drive: background compaction step skipped: {e:#}"),
            }
        }
        std::thread::sleep(cfg.poll_interval);
    }
}

fn launch<F>(slot: &mut Slot, make_cmd: &mut F) -> Result<()>
where
    F: FnMut(Shard) -> Command,
{
    let mut cmd = make_cmd(slot.shard);
    cmd.stdout(Stdio::null()).stderr(Stdio::inherit());
    let child = cmd
        .spawn()
        .with_context(|| format!("spawning shard {} child", slot.shard))?;
    slot.attempts += 1;
    slot.child = Some(child);
    Ok(())
}

fn kill_all(slots: &mut [Slot]) {
    for slot in slots {
        if let Some(mut child) = slot.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}
