//! Content-addressed run cache.
//!
//! A run is addressed by a stable 64-bit FNV-1a hash of
//! `(manifest name, corpus config, canonical RunConfig)` — see
//! [`crate::train::RunConfig::canonical_json`] for what is (and is not)
//! part of the address; notably the presentation-only `label` is
//! excluded, so the same baseline config reached from different figures
//! deduplicates.  The corpus participates through its generator config
//! ([`CorpusConfig`]): corpora are deterministic functions of it, and
//! without it a quick-mode (200k-token) record would silently satisfy a
//! full-corpus run of the same config.  The canonical form serializes
//! through the in-tree JSON writer with sorted keys and
//! shortest-round-trip floats, and FNV-1a is a fixed function, so keys
//! are stable across field-construction order *and* across process runs
//! — which is what makes the on-disk cache a resume mechanism.
//!
//! Persistence is line-oriented JSONL (`runs.jsonl`): one
//! `{"key":…,"manifest":…,"record":…}` object per completed run,
//! appended and flushed as results arrive so a killed sweep loses at
//! most the in-flight runs.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::data::{Corpus, CorpusConfig};
use crate::train::{RunConfig, RunRecord};
use crate::util::hash::fnv1a64;
use crate::util::Json;

/// Canonical form of the corpus generator config (sorted keys).
fn corpus_json(c: &CorpusConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("vocab".to_string(), Json::Num(c.vocab as f64));
    m.insert("n_tokens".to_string(), Json::Num(c.n_tokens as f64));
    m.insert("seed".to_string(), Json::Num(c.seed as f64));
    m.insert("zipf_s".to_string(), Json::Num(c.zipf_s));
    m.insert("k_succ".to_string(), Json::Num(c.k_succ as f64));
    m.insert("smoothing".to_string(), Json::Num(c.smoothing));
    m.insert("valid_frac".to_string(), Json::Num(c.valid_frac));
    Json::Obj(m)
}

/// The content address of one run, as a 16-hex-digit string.
pub fn run_key(manifest: &str, corpus: &Corpus, cfg: &RunConfig) -> String {
    let payload = format!(
        "{manifest}\n{}\n{}",
        corpus_json(&corpus.config).dump(),
        cfg.canonical_json().dump()
    );
    format!("{:016x}", fnv1a64(payload.as_bytes()))
}

/// key -> [`RunRecord`] map with optional JSONL persistence.
pub struct RunCache {
    entries: HashMap<String, RunRecord>,
    file: Option<File>,
    path: Option<PathBuf>,
}

impl RunCache {
    /// A process-local cache (still deduplicates within a sweep and
    /// across an engine's lifetime; nothing is written to disk).
    pub fn in_memory() -> RunCache {
        RunCache { entries: HashMap::new(), file: None, path: None }
    }

    /// Open the persistent cache at `dir/runs.jsonl`.
    ///
    /// With `resume`, pre-existing entries are loaded (corrupt lines are
    /// skipped with a warning — a truncated tail from a killed process
    /// must not poison the sweep).  Without `resume` the file is
    /// truncated: a fresh recording.
    pub fn open(dir: &Path, resume: bool) -> Result<RunCache> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let path = dir.join("runs.jsonl");
        let mut entries = HashMap::new();
        if resume && path.exists() {
            let f = File::open(&path)
                .with_context(|| format!("opening run cache {}", path.display()))?;
            for (lineno, line) in BufReader::new(f).lines().enumerate() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                match parse_entry(&line) {
                    Ok((key, record)) => {
                        entries.insert(key, record);
                    }
                    Err(e) => eprintln!(
                        "run-cache: skipping corrupt line {} of {}: {e:#}",
                        lineno + 1,
                        path.display()
                    ),
                }
            }
        }
        let file = if resume {
            OpenOptions::new().create(true).append(true).open(&path)
        } else {
            File::create(&path)
        }
        .with_context(|| format!("opening run cache {} for append", path.display()))?;
        Ok(RunCache { entries, file: Some(file), path: Some(path) })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get(&self, key: &str) -> Option<&RunRecord> {
        self.entries.get(key)
    }

    /// Record a completed run (idempotent per key) and, if persistent,
    /// append + flush its JSONL line.
    pub fn put(&mut self, key: &str, manifest: &str, record: &RunRecord) -> Result<()> {
        if self.entries.contains_key(key) {
            return Ok(());
        }
        self.entries.insert(key.to_string(), record.clone());
        if let Some(f) = &mut self.file {
            let mut obj = BTreeMap::new();
            obj.insert("key".to_string(), Json::Str(key.to_string()));
            obj.insert("manifest".to_string(), Json::Str(manifest.to_string()));
            obj.insert("record".to_string(), record.to_json());
            writeln!(f, "{}", Json::Obj(obj).dump()).context("appending run-cache line")?;
            f.flush().context("flushing run cache")?;
        }
        Ok(())
    }
}

fn parse_entry(line: &str) -> Result<(String, RunRecord)> {
    let j = Json::parse(line)?;
    let key = j.get("key")?.as_str()?.to_string();
    let record = RunRecord::from_json(j.get("record")?)?;
    Ok((key, record))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_depends_on_manifest_and_corpus() {
        let cfg = RunConfig::quick(
            "x",
            crate::parametrization::Parametrization::new(crate::parametrization::Scheme::Umup),
            crate::parametrization::HpSet::default(),
            8,
        );
        let corpus = |n_tokens: usize| Corpus {
            config: CorpusConfig { vocab: 64, n_tokens, ..Default::default() },
            tokens: vec![],
            n_train: 0,
        };
        let (small, big) = (corpus(1000), corpus(2000));
        assert_eq!(run_key("m1", &small, &cfg), run_key("m1", &small, &cfg));
        assert_ne!(run_key("m1", &small, &cfg), run_key("m2", &small, &cfg));
        // a quick-mode corpus must never satisfy a full-corpus run
        assert_ne!(run_key("m1", &small, &cfg), run_key("m1", &big, &cfg));
    }
}
