//! Content-addressed run cache with sharded, lock-safe segments and a
//! lifecycle (GC / compaction / stats).
//!
//! # Addressing
//!
//! A run is addressed by a stable 64-bit FNV-1a hash of
//! `(manifest name, corpus config, canonical RunConfig)` — see
//! [`crate::train::RunConfig::canonical_json`] for what is (and is not)
//! part of the address; notably the presentation-only `label` is
//! excluded, so the same baseline config reached from different figures
//! deduplicates.  The corpus participates through its generator config
//! ([`CorpusConfig`]): corpora are deterministic functions of it, and
//! without it a quick-mode (200k-token) record would silently satisfy a
//! full-corpus run of the same config.  The canonical form serializes
//! through the in-tree JSON writer with sorted keys and
//! shortest-round-trip floats, and FNV-1a is a fixed function, so keys
//! are stable across field-construction order *and* across process runs
//! — which is what makes the on-disk cache a resume mechanism.
//!
//! # Cache layout & lifecycle
//!
//! A cache directory holds one or more JSONL *segments*:
//!
//! * `runs.jsonl` — the unsharded (single-process) segment, also the
//!   output of compaction;
//! * `runs.<k>.jsonl` — the segment written by shard `k` of a sharded
//!   sweep (`--shard k/n`).
//!
//! Each line is one completed run:
//! `{"key":…,"manifest":…,"record":…,"ts":…}` — appended and flushed as
//! results arrive, so a killed sweep loses at most the in-flight runs.
//! `ts` is the unix-seconds completion time (overridable via the
//! `UMUP_CACHE_TS` env var, which the deterministic concurrency harness
//! uses to make whole segments byte-for-byte reproducible).
//!
//! *Reads* merge: opening a cache with `resume` loads **every** segment
//! in the directory (sorted by file name, last write per key wins), so N
//! shard processes draining disjoint slices of one sweep into one shared
//! directory produce a cache any later process can consume wholesale.
//!
//! *Writes* are single-writer per segment: each opener appends only to
//! its own segment, guarded by an advisory lock file
//! (`<segment>.lock`, containing the holder pid).  A stale lock — its
//! pid no longer alive — is reclaimed with a warning; a live holder is a
//! hard error, so two processes can never interleave writes within one
//! segment.  Distinct shards write distinct segments, which is what
//! makes a sharded sweep safe without any cross-process byte-level
//! locking.
//!
//! *Lifecycle*: [`stats`] summarizes a cache directory (per-segment
//! entry/corruption/byte counts, duplicate keys across segments,
//! per-manifest totals); [`gc`] prunes by age (`ts`) and/or manifest,
//! evicts oldest-first down to a byte budget (`--max-bytes`), and
//! compacts all segments into a single key-sorted `runs.jsonl`,
//! taking every segment lock first so it never races a live writer.
//! An *unsharded* open with `resume` auto-compacts (best-effort) once a
//! directory accretes more than [`AUTO_COMPACT_SEGMENT_THRESHOLD`]
//! segments, so long-lived sharded caches don't degrade every open
//! into an N-file merge (shard children never compact — they open one
//! directory concurrently and must not steal each other's locks).
//!
//! # Crash safety
//!
//! A process killed mid-append leaves a truncated (possibly non-UTF-8)
//! final line.  The segment reader is byte-oriented and lossy: corrupt
//! or torn lines are *skipped with a warning*, never propagated, so a
//! `--resume` after a crash re-runs at most the torn job.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, ErrorKind, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::{Corpus, CorpusConfig};
use crate::train::{RunConfig, RunRecord};
use crate::util::hash::fnv1a64;
use crate::util::Json;

/// Canonical form of the corpus generator config (sorted keys).  Also
/// the `corpus` field of a worker wire-protocol job frame (see
/// `crate::engine::backend::wire`), so key hashing and the wire agree
/// on what a corpus *is*.
pub(crate) fn corpus_json(c: &CorpusConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("vocab".to_string(), Json::Num(c.vocab as f64));
    m.insert("n_tokens".to_string(), Json::Num(c.n_tokens as f64));
    m.insert("seed".to_string(), Json::Num(c.seed as f64));
    m.insert("zipf_s".to_string(), Json::Num(c.zipf_s));
    m.insert("k_succ".to_string(), Json::Num(c.k_succ as f64));
    m.insert("smoothing".to_string(), Json::Num(c.smoothing));
    m.insert("valid_frac".to_string(), Json::Num(c.valid_frac));
    Json::Obj(m)
}

/// The content address of one run, as a 16-hex-digit string.
pub fn run_key(manifest: &str, corpus: &Corpus, cfg: &RunConfig) -> String {
    let payload = format!(
        "{manifest}\n{}\n{}",
        corpus_json(&corpus.config).dump(),
        cfg.canonical_json().dump()
    );
    format!("{:016x}", fnv1a64(payload.as_bytes()))
}

// ------------------------------------------------------------- sharding

/// One slice of a sharded sweep: this process owns every run key whose
/// hash lands in residue class `index` mod `count`.
///
/// Ownership is a pure function of the content address, so N processes
/// given the same job list and the same `count` partition it into
/// disjoint, deterministic slices without any coordination — the slices
/// are hash-balanced, not contiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub count: usize,
}

impl Shard {
    /// Parse the CLI form `i/n` (0-based, `i < n`).
    pub fn parse(s: &str) -> Result<Shard> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("bad shard spec {s:?} (expected i/n, e.g. 0/4)"))?;
        let index: usize = i.trim().parse().with_context(|| format!("bad shard index {i:?}"))?;
        let count: usize = n.trim().parse().with_context(|| format!("bad shard count {n:?}"))?;
        if count == 0 {
            bail!("shard count must be >= 1");
        }
        if index >= count {
            bail!("shard index {index} out of range for count {count} (0-based)");
        }
        Ok(Shard { index, count })
    }

    /// Does this shard own the run with content address `key`?
    pub fn owns(&self, key: &str) -> bool {
        self.index_of(key) == self.index
    }

    /// Which shard (0..count) owns `key`.
    pub fn index_of(&self, key: &str) -> usize {
        // run keys are 16-hex FNV digests; fall back to re-hashing for
        // anything else so arbitrary strings still partition stably
        let h = u64::from_str_radix(key, 16).unwrap_or_else(|_| fnv1a64(key.as_bytes()));
        (mix64(h) % self.count as u64) as usize
    }
}

/// splitmix64 finalizer.  FNV-1a's multiply only carries differences
/// *upward*, so related payloads cluster in the digest's low bits —
/// taking `h % count` directly can park an entire sweep in one shard
/// (observed: 8/8 same-parity keys for an eta-only grid).  Mixing
/// high bits back down first makes the partition track the whole
/// digest.  Partition assignment only — never part of the on-disk key.
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

impl std::fmt::Display for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

// ------------------------------------------------------------- segments

/// The segment file this opener appends to.
fn segment_name(shard: Option<Shard>) -> String {
    match shard {
        Some(s) => format!("runs.{}.jsonl", s.index),
        None => "runs.jsonl".to_string(),
    }
}

/// Is `name` a cache segment file (`runs.jsonl` or `runs.<k>.jsonl`)?
fn is_segment_name(name: &str) -> bool {
    if name == "runs.jsonl" {
        return true;
    }
    name.strip_prefix("runs.")
        .and_then(|rest| rest.strip_suffix(".jsonl"))
        .is_some_and(|mid| !mid.is_empty() && mid.bytes().all(|b| b.is_ascii_digit()))
}

/// Every segment in `dir`, sorted by file name (a missing directory is
/// an empty cache).
pub fn list_segments(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(e).with_context(|| format!("reading cache dir {}", dir.display()))
        }
    };
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if path.is_file() && is_segment_name(name) {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

// ---------------------------------------------------------- lock files

fn lock_path(segment: &Path) -> PathBuf {
    let mut name = segment.file_name().unwrap_or_default().to_os_string();
    name.push(".lock");
    segment.with_file_name(name)
}

fn pid_is_alive(pid: u32) -> bool {
    if pid == std::process::id() {
        return true;
    }
    if cfg!(target_os = "linux") {
        Path::new(&format!("/proc/{pid}")).exists()
    } else {
        // no portable liveness probe without libc: assume alive and make
        // the operator remove the lock file by hand
        true
    }
}

/// An advisory per-segment writer lock: a `<segment>.lock` file created
/// atomically (`create_new`) and holding the owner pid.  Stale locks
/// (dead pid) are reclaimed with a warning; live holders are an error.
struct SegmentLock {
    path: PathBuf,
}

impl SegmentLock {
    fn acquire(segment: &Path) -> Result<SegmentLock> {
        let path = lock_path(segment);
        for _ in 0..4 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Ok(SegmentLock { path });
                }
                Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    match holder {
                        Some(pid) if pid_is_alive(pid) => bail!(
                            "cache segment {} is locked by live process {pid} \
                             (another writer is draining this shard; pick a \
                             different --shard index or wait, then retry)",
                            segment.display()
                        ),
                        Some(pid) => {
                            // positively dead: reclaim and retry; if a
                            // racing process re-creates the lock first,
                            // the next round sees its live pid and errors
                            eprintln!(
                                "run-cache: reclaiming stale lock {} (holder {pid} is gone)",
                                path.display()
                            );
                            let _ = std::fs::remove_file(&path);
                        }
                        None => {
                            // a racing writer may have created the file
                            // but not flushed its pid line yet — never
                            // steal on an unreadable holder, just give
                            // it a beat and look again
                            std::thread::sleep(std::time::Duration::from_millis(20));
                        }
                    }
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating lock file {}", path.display()));
                }
            }
        }
        bail!(
            "could not acquire lock for segment {} after retries (if its writer is \
             gone, delete {} by hand)",
            segment.display(),
            lock_path(segment).display()
        )
    }
}

impl Drop for SegmentLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ------------------------------------------------------------- entries

/// Completion timestamp for new cache lines: unix seconds, overridable
/// via `UMUP_CACHE_TS` (the deterministic test harness pins it so whole
/// segments become byte-for-byte reproducible).
pub(crate) fn now_ts() -> u64 {
    if let Ok(v) = std::env::var("UMUP_CACHE_TS") {
        if let Ok(ts) = v.trim().parse::<u64>() {
            return ts;
        }
    }
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Serialize one cache line (the canonical, sorted-key form; also the
/// compaction output, so merged caches round-trip byte-identically —
/// and the worker wire protocol's success-reply codec, so the wire
/// format is the cache format).
pub(crate) fn entry_line(key: &str, manifest: &str, ts: u64, record: &RunRecord) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("key".to_string(), Json::Str(key.to_string()));
    obj.insert("manifest".to_string(), Json::Str(manifest.to_string()));
    obj.insert("record".to_string(), record.to_json());
    obj.insert("ts".to_string(), Json::Num(ts as f64));
    Json::Obj(obj).dump()
}

/// One parsed cache line.  `ts` is 0 for pre-lifecycle lines (treated as
/// arbitrarily old by age-based GC).
pub(crate) struct Entry {
    pub(crate) key: String,
    pub(crate) manifest: String,
    pub(crate) ts: u64,
    pub(crate) record: RunRecord,
}

pub(crate) fn parse_full_entry(line: &str) -> Result<Entry> {
    let j = Json::parse(line)?;
    let key = j.get("key")?.as_str()?.to_string();
    let manifest = j.get("manifest")?.as_str()?.to_string();
    let ts = match j.get("ts") {
        Ok(v) => v.as_f64()? as u64,
        Err(_) => 0,
    };
    let record = RunRecord::from_json(j.get("record")?)?;
    Ok(Entry { key, manifest, ts, record })
}

fn parse_entry(line: &str) -> Result<(String, RunRecord)> {
    let e = parse_full_entry(line)?;
    Ok((e.key, e.record))
}

/// Does `path` end mid-line (non-empty, no trailing newline)?  The
/// signature a writer was killed mid-append.
fn tail_is_torn(path: &Path) -> bool {
    let Ok(mut f) = File::open(path) else { return false };
    let Ok(len) = f.metadata().map(|m| m.len()) else { return false };
    if len == 0 || f.seek(SeekFrom::End(-1)).is_err() {
        return false;
    }
    let mut last = [0u8; 1];
    f.read_exact(&mut last).is_ok() && last[0] != b'\n'
}

/// Byte-oriented, lossy line iteration: a torn final line from a killed
/// writer (possibly invalid UTF-8) must never abort a resume.  I/O
/// errors mid-file stop the scan with a warning instead of propagating.
fn for_each_line(path: &Path, mut f: impl FnMut(&str)) -> Result<()> {
    let file = match File::open(path) {
        Ok(file) => file,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("opening {}", path.display())),
    };
    let mut reader = BufReader::new(file);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()),
            Ok(_) => {
                let line = String::from_utf8_lossy(&buf);
                f(line.trim_end_matches(['\n', '\r']));
            }
            Err(e) => {
                eprintln!("run-cache: stopping scan of {}: {e}", path.display());
                return Ok(());
            }
        }
    }
}

/// Load one segment into `entries` (later lines win), returning
/// (loaded, corrupt-skipped) counts.
fn load_segment(path: &Path, entries: &mut HashMap<String, RunRecord>) -> (usize, usize) {
    let (mut loaded, mut corrupt) = (0usize, 0usize);
    let mut lineno = 0usize;
    let res = for_each_line(path, |line| {
        lineno += 1;
        if line.trim().is_empty() {
            return;
        }
        match parse_entry(line) {
            Ok((key, record)) => {
                entries.insert(key, record);
                loaded += 1;
            }
            Err(e) => {
                corrupt += 1;
                eprintln!(
                    "run-cache: skipping corrupt line {} of {}: {e:#}",
                    lineno,
                    path.display()
                );
            }
        }
    });
    if let Err(e) = res {
        eprintln!("run-cache: could not read segment {}: {e:#}", path.display());
    }
    (loaded, corrupt)
}

// ----------------------------------------------------------- RunCache

/// key -> [`RunRecord`] map with optional segmented JSONL persistence.
pub struct RunCache {
    entries: HashMap<String, RunRecord>,
    file: Option<File>,
    path: Option<PathBuf>,
    /// Held for the cache's lifetime; releases (deletes) on drop.
    _lock: Option<SegmentLock>,
}

impl RunCache {
    /// A process-local cache (still deduplicates within a sweep and
    /// across an engine's lifetime; nothing is written to disk).
    pub fn in_memory() -> RunCache {
        RunCache { entries: HashMap::new(), file: None, path: None, _lock: None }
    }

    /// Open the persistent, unsharded cache at `dir/runs.jsonl`
    /// (equivalent to [`RunCache::open_sharded`] with no shard).
    pub fn open(dir: &Path, resume: bool) -> Result<RunCache> {
        Self::open_sharded(dir, None, resume)
    }

    /// Open the persistent cache in `dir`, appending to this opener's
    /// segment (`runs.jsonl`, or `runs.<k>.jsonl` for shard `k`).
    ///
    /// The segment is locked against concurrent writers for the cache's
    /// lifetime.  With `resume`, pre-existing entries from **all**
    /// segments are merged in (corrupt lines are skipped with a warning
    /// — a truncated tail from a killed process must not poison the
    /// sweep), and — for *unsharded* openers only, since shard children
    /// open one directory concurrently — a directory that has accreted
    /// more than [`AUTO_COMPACT_SEGMENT_THRESHOLD`] segments is first
    /// compacted into one (best-effort: skipped with a note if any
    /// segment has a live writer).  Without `resume`, this opener's own
    /// segment is
    /// truncated (a fresh recording); other shards' segments are left
    /// alone, since their writers may be live — use `repro cache gc` to
    /// clear a directory wholesale.
    pub fn open_sharded(dir: &Path, shard: Option<Shard>, resume: bool) -> Result<RunCache> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        if resume && shard.is_none() {
            // auto-compaction: a long-lived sharded cache dir otherwise
            // turns every open into an N-file merge.  Runs before this
            // opener takes its own segment lock (gc wants them all).
            // Unsharded opens only: N shard children resume-open one dir
            // *concurrently*, and a child's gc would grab every sibling's
            // segment lock and fail their opens mid-drive — the final
            // unsharded --resume pass (or the next single-process open)
            // is the natural compaction point instead.
            let n_segments = list_segments(dir)?.len();
            if n_segments > AUTO_COMPACT_SEGMENT_THRESHOLD {
                match gc(dir, &GcOptions::default()) {
                    Ok(rep) => eprintln!(
                        "run-cache: auto-compacted {} segments into runs.jsonl \
                         ({} entries, {} duplicate lines dropped)",
                        rep.segments_before, rep.kept, rep.deduped
                    ),
                    Err(e) => eprintln!(
                        "run-cache: auto-compaction of {n_segments} segments skipped \
                         (live writer?): {e:#}"
                    ),
                }
            }
        }
        let path = dir.join(segment_name(shard));
        let lock = SegmentLock::acquire(&path)?;
        let mut entries = HashMap::new();
        if resume {
            for seg in list_segments(dir)? {
                load_segment(&seg, &mut entries);
            }
        }
        let mut file = if resume {
            OpenOptions::new().create(true).append(true).open(&path)
        } else {
            File::create(&path)
        }
        .with_context(|| format!("opening run cache {} for append", path.display()))?;
        if resume && tail_is_torn(&path) {
            // a killed writer left a line without its newline: start the
            // next append on a fresh line so the new record isn't
            // concatenated onto (and lost with) the torn one
            file.write_all(b"\n").context("healing torn run-cache tail")?;
        }
        Ok(RunCache { entries, file: Some(file), path: Some(path), _lock: Some(lock) })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn get(&self, key: &str) -> Option<&RunRecord> {
        self.entries.get(key)
    }

    /// Merge in any entries *other* writers appended to this cache
    /// directory since open — a sharded drain polls this between rounds
    /// to pick up sibling shards' results.  Returns the number of newly
    /// visible records.  No-op (0) for in-memory caches.
    pub fn refresh_from_disk(&mut self) -> usize {
        let Some(own) = self.path.clone() else {
            return 0;
        };
        let Some(dir) = own.parent() else {
            return 0;
        };
        let before = self.entries.len();
        match list_segments(dir) {
            Ok(segments) => {
                for seg in segments {
                    // own segment is already in memory in full
                    if seg == own {
                        continue;
                    }
                    load_segment(&seg, &mut self.entries);
                }
            }
            Err(e) => eprintln!("run-cache: refresh failed: {e:#}"),
        }
        self.entries.len() - before
    }

    /// Record a completed run (idempotent per key) and, if persistent,
    /// append + flush its JSONL line to this opener's segment.
    pub fn put(&mut self, key: &str, manifest: &str, record: &RunRecord) -> Result<()> {
        if self.entries.contains_key(key) {
            return Ok(());
        }
        self.entries.insert(key.to_string(), record.clone());
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", entry_line(key, manifest, now_ts(), record))
                .context("appending run-cache line")?;
            f.flush().context("flushing run cache")?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------ lifecycle

/// Per-segment summary from [`stats`].
#[derive(Debug, Clone)]
pub struct SegmentStats {
    pub name: String,
    pub entries: usize,
    pub corrupt: usize,
    pub bytes: u64,
}

/// Whole-directory summary from [`stats`].
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub segments: Vec<SegmentStats>,
    /// Total lines parsed across segments (including cross-segment
    /// duplicates of one key).
    pub total_entries: usize,
    pub unique_keys: usize,
    /// `total_entries - unique_keys`: same key recorded in several
    /// segments (compaction removes these).
    pub duplicate_keys: usize,
    pub corrupt_lines: usize,
    pub total_bytes: u64,
    /// Unique keys per manifest name.
    pub per_manifest: BTreeMap<String, usize>,
    pub oldest_ts: Option<u64>,
    pub newest_ts: Option<u64>,
}

/// Summarize a cache directory without taking any locks (read-only; a
/// line being appended concurrently may be counted as corrupt).
pub fn stats(dir: &Path) -> Result<CacheStats> {
    let mut st = CacheStats::default();
    let mut manifest_of: HashMap<String, String> = HashMap::new();
    for seg in list_segments(dir)? {
        let bytes = std::fs::metadata(&seg).map(|m| m.len()).unwrap_or(0);
        let (mut loaded, mut corrupt) = (0usize, 0usize);
        for_each_line(&seg, |line| {
            if line.trim().is_empty() {
                return;
            }
            match parse_full_entry(line) {
                Ok(e) => {
                    loaded += 1;
                    if e.ts > 0 {
                        st.oldest_ts = Some(st.oldest_ts.map_or(e.ts, |t| t.min(e.ts)));
                        st.newest_ts = Some(st.newest_ts.map_or(e.ts, |t| t.max(e.ts)));
                    }
                    manifest_of.insert(e.key, e.manifest);
                }
                Err(_) => corrupt += 1,
            }
        })?;
        st.total_entries += loaded;
        st.corrupt_lines += corrupt;
        st.total_bytes += bytes;
        st.segments.push(SegmentStats {
            name: seg.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string(),
            entries: loaded,
            corrupt,
            bytes,
        });
    }
    st.unique_keys = manifest_of.len();
    st.duplicate_keys = st.total_entries - st.unique_keys;
    for manifest in manifest_of.into_values() {
        *st.per_manifest.entry(manifest).or_insert(0) += 1;
    }
    Ok(st)
}

/// Opening a cache dir with `resume` auto-compacts it first when it
/// holds more than this many segments (see [`RunCache::open_sharded`]).
pub const AUTO_COMPACT_SEGMENT_THRESHOLD: usize = 8;

/// What [`gc`] should prune.  With no filters set, GC is a pure
/// compaction: segments merge into one key-sorted `runs.jsonl`, dropping
/// cross-segment duplicates and corrupt lines.
#[derive(Debug, Clone, Default)]
pub struct GcOptions {
    /// Prune entries whose `ts` is at least this old (entries without a
    /// `ts` — pre-lifecycle lines — count as arbitrarily old).
    pub older_than: Option<Duration>,
    /// Prune entries recorded under this manifest name.
    pub manifest: Option<String>,
    /// Size budget for the compacted cache: after the filters above,
    /// evict oldest-`ts` entries (ties broken by key, for determinism)
    /// until the surviving lines fit in this many bytes.
    pub max_bytes: Option<u64>,
    /// Report what would happen without touching any file.
    pub dry_run: bool,
}

/// What [`gc`] did (or, under `dry_run`, would do).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Parseable lines seen across all segments.
    pub scanned: usize,
    pub kept: usize,
    /// Entries dropped by the age / manifest filters.
    pub pruned: usize,
    /// Entries evicted (oldest first) to meet the `max_bytes` budget.
    pub evicted: usize,
    /// Cross-segment duplicate lines collapsed by compaction.
    pub deduped: usize,
    pub corrupt_dropped: usize,
    pub segments_before: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// Prune and compact a cache directory.
///
/// Takes every segment's writer lock first (erroring if any segment has
/// a live writer), merges all segments (last write per key wins),
/// applies the [`GcOptions`] filters, and — unless `dry_run` — rewrites
/// the survivors as a single key-sorted `runs.jsonl` (via a temp file +
/// rename) and deletes the shard segments.  An emptied cache ends up
/// with no segment files at all.
pub fn gc(dir: &Path, opts: &GcOptions) -> Result<GcReport> {
    let segments = list_segments(dir)?;
    let mut report = GcReport { segments_before: segments.len(), ..GcReport::default() };
    if segments.is_empty() {
        return Ok(report);
    }
    let compacted = dir.join("runs.jsonl");
    // lock every segment plus the compaction target so no live writer
    // (or competing gc) can race the rewrite
    let mut locks = Vec::new();
    for seg in segments.iter().chain(
        (!segments.contains(&compacted)).then_some(&compacted),
    ) {
        locks.push(
            SegmentLock::acquire(seg)
                .with_context(|| format!("gc: locking segment {}", seg.display()))?,
        );
    }

    // merge: insertion order = sorted segment order, so later segments
    // win for duplicated keys (mirrors the resume reader)
    let mut merged: BTreeMap<String, Entry> = BTreeMap::new();
    for seg in &segments {
        report.bytes_before += std::fs::metadata(seg).map(|m| m.len()).unwrap_or(0);
        let res = for_each_line(seg, |line| {
            if line.trim().is_empty() {
                return;
            }
            match parse_full_entry(line) {
                Ok(e) => {
                    report.scanned += 1;
                    if merged.insert(e.key.clone(), e).is_some() {
                        report.deduped += 1;
                    }
                }
                Err(_) => report.corrupt_dropped += 1,
            }
        });
        if let Err(e) = res {
            eprintln!("run-cache: gc could not read {}: {e:#}", seg.display());
        }
    }

    // filter
    let cutoff = opts.older_than.map(|d| now_ts().saturating_sub(d.as_secs()));
    let mut kept: Vec<&Entry> = merged
        .values()
        .filter(|e| {
            if let Some(m) = &opts.manifest {
                if &e.manifest == m {
                    return false;
                }
            }
            if let Some(cut) = cutoff {
                if e.ts <= cut {
                    return false;
                }
            }
            true
        })
        .collect();
    report.pruned = merged.len() - kept.len();

    // size budget: evict oldest-ts entries (key tiebreak, so repeated
    // gc over the same data is deterministic) until the projected
    // compacted file fits
    let mut projected: u64 = kept
        .iter()
        .map(|e| entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1)
        .sum();
    if let Some(budget) = opts.max_bytes {
        if projected > budget {
            let mut by_age: Vec<&Entry> = kept.clone();
            by_age.sort_by(|a, b| a.ts.cmp(&b.ts).then_with(|| a.key.cmp(&b.key)));
            let mut evict: std::collections::HashSet<&str> = std::collections::HashSet::new();
            for e in by_age {
                if projected <= budget {
                    break;
                }
                projected -= entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1;
                evict.insert(e.key.as_str());
            }
            report.evicted = evict.len();
            kept.retain(|e| !evict.contains(e.key.as_str()));
        }
    }
    report.kept = kept.len();

    if opts.dry_run {
        report.bytes_after = projected;
        return Ok(report);
    }

    // rewrite: survivors into runs.jsonl (atomically), then drop the
    // shard segments
    if kept.is_empty() {
        for seg in &segments {
            std::fs::remove_file(seg)
                .with_context(|| format!("gc: removing segment {}", seg.display()))?;
        }
    } else {
        let tmp = dir.join("runs.jsonl.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("gc: creating {}", tmp.display()))?;
            for e in &kept {
                writeln!(f, "{}", entry_line(&e.key, &e.manifest, e.ts, &e.record))
                    .context("gc: writing compacted entry")?;
            }
            f.flush().context("gc: flushing compacted cache")?;
        }
        std::fs::rename(&tmp, &compacted)
            .with_context(|| format!("gc: installing {}", compacted.display()))?;
        for seg in segments.iter().filter(|s| **s != compacted) {
            std::fs::remove_file(seg)
                .with_context(|| format!("gc: removing segment {}", seg.display()))?;
        }
        report.bytes_after = std::fs::metadata(&compacted).map(|m| m.len()).unwrap_or(0);
    }
    drop(locks);
    Ok(report)
}

/// Parse a human duration: bare seconds or `<number><s|m|h|d|w>`
/// (e.g. `0s`, `90`, `5m`, `12h`, `30d`).
pub fn parse_duration(s: &str) -> Result<Duration> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad duration {s:?} (expected e.g. 30d, 12h, 0s)"))?;
    let mult = match unit.trim() {
        "" | "s" => 1.0,
        "m" => 60.0,
        "h" => 3600.0,
        "d" => 86400.0,
        "w" => 604800.0,
        u => bail!("bad duration unit {u:?} in {s:?} (use s/m/h/d/w)"),
    };
    // try_from: an absurd `--older-than` must be an error, not a panic
    Duration::try_from_secs_f64(n * mult)
        .map_err(|e| anyhow::anyhow!("duration {s:?} out of range: {e}"))
}

/// Parse a human byte count: bare bytes or `<number><k|m|g>` (binary
/// multiples, case-insensitive — e.g. `65536`, `512k`, `10m`, `1g`).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad byte count {s:?} (expected e.g. 65536, 512k, 10m)"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" | "kib" => 1024.0,
        "m" | "mb" | "mib" => 1024.0 * 1024.0,
        "g" | "gb" | "gib" => 1024.0 * 1024.0 * 1024.0,
        u => bail!("bad byte unit {u:?} in {s:?} (use k/m/g)"),
    };
    let v = n * mult;
    if !v.is_finite() || v < 0.0 || v > u64::MAX as f64 {
        bail!("byte count {s:?} out of range");
    }
    Ok(v as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(label: &str, loss: f64) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            train_curve: vec![(1, loss)],
            valid_curve: vec![],
            final_valid_loss: loss,
            rms_curves: BTreeMap::new(),
            final_rms: vec![],
            diverged: false,
            wall_seconds: 0.0,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("umup-cache-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn key_depends_on_manifest_and_corpus() {
        let cfg = RunConfig::quick(
            "x",
            crate::parametrization::Parametrization::new(crate::parametrization::Scheme::Umup),
            crate::parametrization::HpSet::default(),
            8,
        );
        let corpus = |n_tokens: usize| Corpus {
            config: CorpusConfig { vocab: 64, n_tokens, ..Default::default() },
            tokens: vec![],
            n_train: 0,
        };
        let (small, big) = (corpus(1000), corpus(2000));
        assert_eq!(run_key("m1", &small, &cfg), run_key("m1", &small, &cfg));
        assert_ne!(run_key("m1", &small, &cfg), run_key("m2", &small, &cfg));
        // a quick-mode corpus must never satisfy a full-corpus run
        assert_ne!(run_key("m1", &small, &cfg), run_key("m1", &big, &cfg));
    }

    #[test]
    fn shard_parse_and_ownership_partition() {
        let s = Shard::parse("1/4").unwrap();
        assert_eq!((s.index, s.count), (1, 4));
        assert!(Shard::parse("4/4").is_err());
        assert!(Shard::parse("0/0").is_err());
        assert!(Shard::parse("x/4").is_err());
        assert!(Shard::parse("3").is_err());
        // every key is owned by exactly one shard, deterministically
        for key in ["00000000000000ff", "cbf29ce484222325", "not-hex-at-all"] {
            let owners: Vec<usize> = (0..4)
                .filter(|&i| Shard { index: i, count: 4 }.owns(key))
                .collect();
            assert_eq!(owners.len(), 1, "{key}: {owners:?}");
            assert_eq!(owners[0], Shard { index: 0, count: 4 }.index_of(key));
        }
        // count=1 owns everything
        assert!(Shard { index: 0, count: 1 }.owns("cbf29ce484222325"));
    }

    #[test]
    fn segment_names_are_recognized() {
        assert!(is_segment_name("runs.jsonl"));
        assert!(is_segment_name("runs.0.jsonl"));
        assert!(is_segment_name("runs.12.jsonl"));
        assert!(!is_segment_name("runs.jsonl.lock"));
        assert!(!is_segment_name("runs.0.jsonl.lock"));
        assert!(!is_segment_name("runs.x.jsonl"));
        assert!(!is_segment_name("runs..jsonl"));
        assert!(!is_segment_name("other.jsonl"));
        assert!(!is_segment_name("runs.jsonl.tmp"));
    }

    #[test]
    fn sharded_segments_merge_on_resume() {
        let dir = tmp_dir("merge");
        {
            let mut c0 =
                RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
            c0.put("aaaa", "m1", &rec("a", 1.0)).unwrap();
        }
        {
            let mut c1 =
                RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
            c1.put("bbbb", "m2", &rec("b", 2.0)).unwrap();
        }
        let merged = RunCache::open(&dir, true).unwrap();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged.get("aaaa").unwrap().final_valid_loss, 1.0);
        assert_eq!(merged.get("bbbb").unwrap().final_valid_loss, 2.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_lock_blocks_second_writer_and_stale_lock_is_reclaimed() {
        let dir = tmp_dir("lock");
        let cache = RunCache::open(&dir, true).unwrap();
        let err = RunCache::open(&dir, true).unwrap_err().to_string();
        assert!(err.contains("locked by live process"), "{err}");
        // a different segment is fine while the first is held
        let other =
            RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
        drop(other);
        drop(cache);
        // stale lock: dead pid -> reclaimed silently (warning only)
        std::fs::write(dir.join("runs.jsonl.lock"), "4294967294\n").unwrap();
        let cache = RunCache::open(&dir, true).unwrap();
        drop(cache);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_and_non_utf8_tails_are_skipped_on_resume() {
        let dir = tmp_dir("torn");
        {
            let mut c = RunCache::open(&dir, false).unwrap();
            c.put("aaaa", "m", &rec("a", 1.5)).unwrap();
        }
        // simulate a crash mid-append: truncated JSON then raw bytes
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join("runs.jsonl"))
                .unwrap();
            f.write_all(b"{\"key\":\"bbbb\",\"manifest\":\"m\",\"rec").unwrap();
            f.write_all(&[0xff, 0xfe, 0x80]).unwrap();
        }
        let mut c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 1, "torn tail must be skipped, not fatal");
        assert!(c.get("aaaa").is_some());
        // the torn tail is healed: a post-resume append must not be
        // concatenated onto (and lost with) the garbage line
        c.put("cccc", "m", &rec("c", 2.5)).unwrap();
        drop(c);
        let c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.get("cccc").is_some(), "append after torn tail must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_prunes_by_manifest_and_age_and_compacts() {
        let dir = tmp_dir("gc");
        // (timestamps are the real clock here: mutating the process-wide
        // UMUP_CACHE_TS env would race sibling unit tests' appends.  The
        // deterministic-ts path is covered per-child-process by
        // tests/engine_concurrency.rs.)
        {
            let mut c0 =
                RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
            c0.put("aaaa", "m1", &rec("a", 1.0)).unwrap();
            let mut c1 =
                RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
            c1.put("bbbb", "m2", &rec("b", 2.0)).unwrap();
            c1.put("cccc", "m2", &rec("c", 3.0)).unwrap();
        }

        let st = stats(&dir).unwrap();
        assert_eq!(st.segments.len(), 2);
        assert_eq!(st.unique_keys, 3);
        assert_eq!(st.duplicate_keys, 0);
        assert_eq!(st.per_manifest["m1"], 1);
        assert_eq!(st.per_manifest["m2"], 2);
        assert!(st.oldest_ts.is_some() && st.newest_ts >= st.oldest_ts);

        // dry-run changes nothing
        let dry = gc(
            &dir,
            &GcOptions { manifest: Some("m2".into()), dry_run: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!((dry.kept, dry.pruned), (1, 2));
        assert_eq!(stats(&dir).unwrap().unique_keys, 3);

        // prune one manifest; survivors land compacted in runs.jsonl
        let rep =
            gc(&dir, &GcOptions { manifest: Some("m2".into()), ..Default::default() }).unwrap();
        assert_eq!((rep.kept, rep.pruned), (1, 2));
        let st = stats(&dir).unwrap();
        assert_eq!(st.unique_keys, 1);
        assert_eq!(st.segments.len(), 1);
        assert_eq!(st.segments[0].name, "runs.jsonl");
        let merged = RunCache::open(&dir, true).unwrap();
        assert_eq!(merged.len(), 1);
        assert!(merged.get("aaaa").is_some());
        drop(merged);

        // age-based: every entry's ts <= now, so --older-than 0s prunes all
        let rep = gc(
            &dir,
            &GcOptions { older_than: Some(Duration::from_secs(0)), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.kept, 0);
        assert_eq!(rep.pruned, 1);
        let st = stats(&dir).unwrap();
        assert_eq!(st.unique_keys, 0);
        assert!(st.segments.is_empty(), "emptied cache has no segment files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_refuses_while_a_writer_is_live() {
        let dir = tmp_dir("gc-live");
        let mut c = RunCache::open(&dir, true).unwrap();
        c.put("aaaa", "m", &rec("a", 1.0)).unwrap();
        let err = gc(&dir, &GcOptions::default()).unwrap_err().to_string();
        assert!(err.contains("locked by live process"), "{err}");
        drop(c);
        assert_eq!(gc(&dir, &GcOptions::default()).unwrap().kept, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_max_bytes_evicts_oldest_first() {
        let dir = tmp_dir("gc-bytes");
        // three entries with strictly increasing ts (distinct keys);
        // UMUP_CACHE_TS can't be used here (process-wide env races
        // sibling tests), so write the lines directly
        std::fs::create_dir_all(&dir).unwrap();
        let mut lines = String::new();
        for (i, key) in ["aaaa", "bbbb", "cccc"].iter().enumerate() {
            lines.push_str(&entry_line(key, "m", 100 + i as u64, &rec(key, i as f64)));
            lines.push('\n');
        }
        std::fs::write(dir.join("runs.jsonl"), &lines).unwrap();

        // budget that fits exactly the two newest lines
        let line_len = |key: &str, i: u64| {
            entry_line(key, "m", 100 + i, &rec(key, i as f64)).len() as u64 + 1
        };
        let budget = line_len("bbbb", 1) + line_len("cccc", 2);
        // dry run reports the projection without touching the file
        let dry = gc(
            &dir,
            &GcOptions { max_bytes: Some(budget), dry_run: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!((dry.kept, dry.evicted, dry.pruned), (2, 1, 0));
        assert!(dry.bytes_after <= budget);
        assert_eq!(stats(&dir).unwrap().unique_keys, 3);

        let rep =
            gc(&dir, &GcOptions { max_bytes: Some(budget), ..Default::default() }).unwrap();
        assert_eq!((rep.kept, rep.evicted, rep.pruned), (2, 1, 0));
        assert!(rep.bytes_after <= budget, "{} > {budget}", rep.bytes_after);
        let merged = RunCache::open(&dir, true).unwrap();
        assert!(merged.get("aaaa").is_none(), "oldest entry must be evicted");
        assert!(merged.get("bbbb").is_some() && merged.get("cccc").is_some());
        drop(merged);

        // a generous budget evicts nothing
        let rep = gc(
            &dir,
            &GcOptions { max_bytes: Some(u64::MAX), ..Default::default() },
        )
        .unwrap();
        assert_eq!((rep.kept, rep.evicted), (2, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_open_auto_compacts_past_the_segment_threshold() {
        let dir = tmp_dir("auto-compact");
        let n = AUTO_COMPACT_SEGMENT_THRESHOLD + 2;
        for i in 0..n {
            // resume: false — auto-compaction is a resume-open behavior,
            // so seeding the segments here must not trigger it early
            let mut c =
                RunCache::open_sharded(&dir, Some(Shard { index: i, count: n }), false).unwrap();
            c.put(&format!("{i:016x}"), "m", &rec("r", i as f64)).unwrap();
        }
        assert_eq!(list_segments(&dir).unwrap().len(), n);
        // resume-open triggers compaction: all entries survive, but the
        // shard segments collapse into runs.jsonl (+ the opener's own)
        let c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), n, "auto-compaction must not lose entries");
        drop(c);
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1, "segments must be compacted: {segs:?}");
        assert!(segs[0].ends_with("runs.jsonl"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_count_parsing() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("65536").unwrap(), 65536);
        assert_eq!(parse_bytes("512k").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("10m").unwrap(), 10 * 1024 * 1024);
        assert_eq!(parse_bytes("1g").unwrap(), 1024 * 1024 * 1024);
        assert_eq!(parse_bytes("2KiB").unwrap(), 2048);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("5 parsecs").is_err());
    }

    #[test]
    fn duration_parsing() {
        assert_eq!(parse_duration("0s").unwrap(), Duration::from_secs(0));
        assert_eq!(parse_duration("90").unwrap(), Duration::from_secs(90));
        assert_eq!(parse_duration("5m").unwrap(), Duration::from_secs(300));
        assert_eq!(parse_duration("2h").unwrap(), Duration::from_secs(7200));
        assert_eq!(parse_duration("30d").unwrap(), Duration::from_secs(2_592_000));
        assert_eq!(parse_duration("1w").unwrap(), Duration::from_secs(604_800));
        assert_eq!(parse_duration("1.5h").unwrap(), Duration::from_secs(5400));
        assert!(parse_duration("abc").is_err());
        assert!(parse_duration("5 fortnights").is_err());
        // u64-overflow seconds must be an error, not a panic
        assert!(parse_duration("10000000000000000d").is_err());
    }
}
