//! S12 — the unified run engine: one subsystem owns run execution end to
//! end.
//!
//! Everything that trains (experiments, the CLI, examples, benches)
//! routes through [`Engine`] instead of hand-rolling
//! `Session::open`/`Runner::new` plumbing.  The engine provides:
//!
//! * **A multi-manifest job queue.**  One worker pool drains
//!   [`EngineJob`]s spanning different artifact shapes, so cross-width
//!   transfer sweeps (fig1b/fig5) are no longer serialized per shape.
//! * **Per-worker session pools with LRU eviction.**  PJRT sessions are
//!   `!Send`, so each persistent worker keeps its own
//!   `manifest name → Session` pool ([`LruPool`]).  Workers outlive
//!   individual [`Engine::run`] calls, which amortizes XLA compiles
//!   (seconds per module) across experiments, and eviction is
//!   per-entry LRU — a multi-shape sweep drops only its coldest
//!   session, never the whole pool.
//! * **A sharded, multi-process-safe run cache.**  A canonical,
//!   label-independent hash of (manifest name, corpus config,
//!   [`RunConfig`]) maps to [`RunRecord`] (see [`run_key`]),
//!   deduplicating repeated configs within a batch and — with
//!   [`EngineConfig::cache_dir`] — persisting results as lock-safe
//!   JSONL segments so interrupted sweeps resume across process
//!   restarts.  With [`EngineConfig::shard`] set to `i/n`, the engine
//!   executes only the jobs whose content address lands in its slice
//!   and writes them to its own `runs.<i>.jsonl` segment, so N
//!   processes drain one sweep into one shared directory with no
//!   write contention (see [`crate::engine::cache`] module docs for the
//!   on-disk layout and `repro cache gc`/`stats` for the lifecycle).
//! * **Per-job outcome reporting.**  [`EngineReport`] carries an
//!   `Ok`/`Err` per job plus progress counters; a failing job no longer
//!   kills the batch (the old scheduler's first-error-kills-all
//!   behavior, and its worker-abandons-queue bug, are both gone).
//!
//! The caller-facing surface is [`Engine::run`] (full per-job report),
//! [`Engine::run_sweep`] / [`Engine::run_single`] (strict, job-ordered)
//! and [`Engine::session`] / [`Engine::runner`] for caller-thread
//! stateful work (probe evaluation, init telemetry, `run_full`).

pub mod cache;
mod job;
mod lru;
mod pool;

pub use crate::util::hash::fnv1a64;
pub use cache::{
    gc, list_segments, parse_duration, run_key, stats, CacheStats, GcOptions, GcReport,
    RunCache, SegmentStats, Shard,
};
pub use job::{EngineJob, EngineReport, JobOutcome, SweepJob, SweepResult};
pub use lru::LruPool;
pub use pool::JobExec;

#[cfg(feature = "xla")]
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::Result;

use crate::data::Corpus;
use crate::runtime::Manifest;
#[cfg(feature = "xla")]
use crate::runtime::Session;
use crate::train::RunConfig;
#[cfg(feature = "xla")]
use crate::train::{RunRecord, Runner};

use pool::{Task, WorkerPool};

/// Marker embedded in every shard-skip outcome (and therefore in the
/// strict `run_sweep` error for a skipped job).  Callers running a
/// sharded drain match on this to distinguish "another shard owns this
/// run — retry once its result lands" from a real failure; see the
/// retry loop in `repro exp --shard`.
pub const SHARD_SKIP_MARKER: &str = "belongs to shard";

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads; each owns a session pool.  XLA already
    /// multithreads each step, so small counts suffice — more workers
    /// trade batch-level against op-level parallelism.
    pub workers: usize,
    /// Persist the run cache under this directory as lock-safe JSONL
    /// segments (see [`cache`] for the layout).  `None` keeps an
    /// in-memory cache (dedup only, no resume).
    pub cache_dir: Option<PathBuf>,
    /// Load pre-existing cache entries from **all** segments in
    /// `cache_dir` (resume an interrupted or sharded sweep).  Without
    /// this, this engine's own segment is truncated.
    pub resume: bool,
    /// Execute only jobs whose content address falls in this slice
    /// (`i/n`), recording them to the `runs.<i>.jsonl` segment;
    /// everything else is reported as skipped.  `None` owns every job.
    pub shard: Option<Shard>,
    /// Per-worker compiled-session cap; the least-recently-used session
    /// is evicted when a worker's pool exceeds it (compiles are seconds,
    /// so eviction only bounds memory — see [`LruPool`]).
    pub max_sessions_per_worker: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 4,
            cache_dir: None,
            resume: false,
            shard: None,
            max_sessions_per_worker: 8,
        }
    }
}

/// Aggregate counters over an engine's lifetime (see
/// [`EngineReport`] for the per-batch view).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub executed: usize,
    pub cache_hits: usize,
    pub deduped: usize,
    pub skipped: usize,
    pub failed: usize,
}

/// The unified run engine.  See the module docs for the architecture.
pub struct Engine {
    pool: WorkerPool,
    cache: Mutex<RunCache>,
    stats: Mutex<EngineStats>,
    shard: Option<Shard>,
    /// Caller-thread sessions for the stateful APIs ([`Engine::session`]
    /// / [`Engine::runner`]); separate from the worker pools because
    /// sessions cannot cross threads.
    #[cfg(feature = "xla")]
    local: RefCell<HashMap<String, Arc<Session>>>,
}

impl Engine {
    /// An engine whose workers run jobs on real XLA sessions, compiled
    /// on first use per (worker, manifest) and LRU-pooled thereafter.
    #[cfg(feature = "xla")]
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        let cap = cfg.max_sessions_per_worker.max(1);
        Self::with_factory(cfg, move |_worker| {
            let mut sessions: LruPool<Runner> = LruPool::new(cap);
            Box::new(move |job: &EngineJob| -> Result<RunRecord> {
                let runner = sessions.get_or_create(&job.manifest.name, || {
                    let session = Session::open(Arc::clone(&job.manifest)).with_context(
                        || format!("opening worker session for {}", job.manifest.name),
                    )?;
                    Ok(Runner::new(Arc::new(session)))
                })?;
                runner.run(&job.config, &job.corpus)
            })
        })
    }

    /// Build an engine with a custom per-worker executor factory.
    ///
    /// This is the seam the engine tests and benches use to exercise
    /// queueing, deduplication, caching, sharding and failure handling
    /// without XLA artifacts; embedders can use it to plug in remote
    /// execution.
    pub fn with_factory<F>(cfg: EngineConfig, factory: F) -> Result<Engine>
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        let cache = match &cfg.cache_dir {
            Some(dir) => RunCache::open_sharded(dir, cfg.shard, cfg.resume)?,
            None => RunCache::in_memory(),
        };
        Ok(Engine {
            pool: WorkerPool::new(cfg.workers, factory),
            cache: Mutex::new(cache),
            stats: Mutex::new(EngineStats::default()),
            shard: cfg.shard,
            #[cfg(feature = "xla")]
            local: RefCell::new(HashMap::new()),
        })
    }

    /// Does this engine's shard own the run with content address `key`?
    /// (Unsharded engines own everything.)
    fn owns(&self, key: &str) -> bool {
        match self.shard {
            Some(s) => s.owns(key),
            None => true,
        }
    }

    /// Run a batch of (possibly multi-manifest) jobs.  Never fails
    /// wholesale: each job gets its own `Ok`/`Err` in the report.
    ///
    /// Within the batch, jobs with the same content address are executed
    /// once; cache hits (including those loaded from a `--resume`d
    /// cache file) skip execution entirely.  On a sharded engine, jobs
    /// owned by other shards are reported as skipped (unless already in
    /// the cache — a merged cache satisfies any shard).
    pub fn run(&self, jobs: Vec<EngineJob>) -> EngineReport {
        let n = jobs.len();
        let keys: Vec<String> =
            jobs.iter().map(|j| run_key(&j.manifest.name, &j.corpus, &j.config)).collect();
        let mut outcomes: Vec<Option<JobOutcome>> = Vec::with_capacity(n);
        outcomes.resize_with(n, || None);

        // Partition: cache hit / other shard's / duplicate-of-earlier /
        // must run.
        let mut primary_of: HashMap<&str, usize> = HashMap::new();
        let mut followers: Vec<(usize, usize)> = Vec::new(); // (dup, primary)
        let mut to_run: Vec<usize> = Vec::new();
        let mut cache_hits = 0usize;
        let mut skipped = 0usize;
        {
            let cache = self.cache.lock().unwrap();
            for (i, job) in jobs.iter().enumerate() {
                if let Some(rec) = cache.get(&keys[i]) {
                    let mut rec = rec.clone();
                    rec.label = job.config.label.clone();
                    outcomes[i] = Some(JobOutcome {
                        job: job.clone(),
                        outcome: Ok(rec),
                        cached: true,
                        skipped: false,
                    });
                    cache_hits += 1;
                } else if !self.owns(&keys[i]) {
                    let shard = self.shard.expect("owns() is false only when sharded");
                    outcomes[i] = Some(JobOutcome {
                        job: job.clone(),
                        outcome: Err(format!(
                            "skipped: run {} {SHARD_SKIP_MARKER} {}/{} (this engine is \
                             shard {shard}; drain that shard into the same cache dir, \
                             then merge with --resume)",
                            keys[i],
                            shard.index_of(&keys[i]),
                            shard.count,
                        )),
                        cached: false,
                        skipped: true,
                    });
                    skipped += 1;
                } else if let Some(&p) = primary_of.get(keys[i].as_str()) {
                    followers.push((i, p));
                } else {
                    primary_of.insert(keys[i].as_str(), i);
                    to_run.push(i);
                }
            }
        }

        // Dispatch the misses to the worker pool.
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut submitted = 0usize;
        let mut failed = 0usize;
        for &i in &to_run {
            let task = Task { idx: i, job: jobs[i].clone(), reply: reply_tx.clone() };
            if self.pool.submit(task) {
                submitted += 1;
            } else {
                failed += 1;
                outcomes[i] = Some(JobOutcome {
                    job: jobs[i].clone(),
                    outcome: Err("engine worker pool is gone".to_string()),
                    cached: false,
                    skipped: false,
                });
            }
        }
        drop(reply_tx);

        let mut executed = 0usize;
        for _ in 0..submitted {
            let Ok((i, res)) = reply_rx.recv() else {
                break; // a worker died mid-job; stragglers handled below
            };
            executed += 1; // the job ran on a worker, whatever its outcome
            let outcome = match res {
                Ok(record) => {
                    let mut cache = self.cache.lock().unwrap();
                    if let Err(e) = cache.put(&keys[i], &jobs[i].manifest.name, &record) {
                        eprintln!(
                            "run-cache: failed to persist {}: {e:#}",
                            jobs[i].config.label
                        );
                    }
                    Ok(record)
                }
                Err(msg) => {
                    failed += 1;
                    Err(msg)
                }
            };
            outcomes[i] =
                Some(JobOutcome { job: jobs[i].clone(), outcome, cached: false, skipped: false });
        }
        for &i in &to_run {
            if outcomes[i].is_none() {
                failed += 1;
                outcomes[i] = Some(JobOutcome {
                    job: jobs[i].clone(),
                    outcome: Err("engine worker died before finishing this job".to_string()),
                    cached: false,
                    skipped: false,
                });
            }
        }

        // Resolve in-batch duplicates from their primary's outcome.
        let mut deduped = 0usize;
        for &(d, p) in &followers {
            let outcome = match &outcomes[p].as_ref().expect("primary resolved").outcome {
                Ok(rec) => {
                    deduped += 1;
                    let mut rec = rec.clone();
                    rec.label = jobs[d].config.label.clone();
                    Ok(rec)
                }
                Err(e) => {
                    failed += 1;
                    Err(e.clone())
                }
            };
            outcomes[d] =
                Some(JobOutcome { job: jobs[d].clone(), outcome, cached: true, skipped: false });
        }

        let outcomes: Vec<JobOutcome> =
            outcomes.into_iter().map(|o| o.expect("all jobs resolved")).collect();
        let completed = outcomes.iter().filter(|o| o.outcome.is_ok()).count();
        {
            let mut s = self.stats.lock().unwrap();
            s.executed += executed;
            s.cache_hits += cache_hits;
            s.deduped += deduped;
            s.skipped += skipped;
            s.failed += failed;
        }
        EngineReport { outcomes, completed, failed, cache_hits, deduped, skipped, executed }
    }

    /// Run a single-manifest batch strictly: job-ordered results or the
    /// first per-job error (all jobs are still attempted either way).
    pub fn run_sweep(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        jobs: &[SweepJob],
    ) -> Result<Vec<SweepResult>> {
        let engine_jobs = jobs
            .iter()
            .map(|j| EngineJob {
                manifest: Arc::clone(manifest),
                corpus: Arc::clone(corpus),
                config: j.config.clone(),
                tag: j.tag.clone(),
            })
            .collect();
        self.run(engine_jobs).into_sweep_results()
    }

    /// Run one config (cache-aware like any other job).
    pub fn run_single(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        config: RunConfig,
    ) -> Result<SweepResult> {
        let mut v = self.run_sweep(manifest, corpus, &[SweepJob { config, tag: vec![] }])?;
        Ok(v.pop().expect("one job in, one result out"))
    }

    /// A caller-thread session for `manifest`, compiled once and pooled
    /// for the engine's lifetime (this is where the old
    /// `Registry::session` cache moved).
    #[cfg(feature = "xla")]
    pub fn session(&self, manifest: &Arc<Manifest>) -> Result<Arc<Session>> {
        if let Some(s) = self.local.borrow().get(&manifest.name) {
            return Ok(Arc::clone(s));
        }
        let s = Arc::new(Session::open(Arc::clone(manifest))?);
        self.local.borrow_mut().insert(manifest.name.clone(), Arc::clone(&s));
        Ok(s)
    }

    /// A [`Runner`] over the pooled caller-thread session — for stateful
    /// work the job queue cannot express (`run_full`, `eval_at_init`,
    /// probe evaluation).
    #[cfg(feature = "xla")]
    pub fn runner(&self, manifest: &Arc<Manifest>) -> Result<Runner> {
        Ok(Runner::new(self.session(manifest)?))
    }

    /// Lifetime counters (executed / cache hits / deduped / skipped /
    /// failed).
    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }

    /// Number of records currently addressable in the run cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Merge in records that sibling shard processes have appended to
    /// the shared cache directory since this engine opened it (no-op
    /// for in-memory caches).  Returns the number of newly visible
    /// records — the sharded drain's progress signal.
    pub fn refresh_cache(&self) -> usize {
        self.cache.lock().unwrap().refresh_from_disk()
    }
}
