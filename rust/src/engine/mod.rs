//! S12 — the unified run engine: one subsystem owns run execution end to
//! end, behind a **handle-based, non-blocking submission API**.
//!
//! Everything that trains (experiments, the CLI, examples, benches)
//! routes through [`Engine`] instead of hand-rolling
//! `Session::open`/`Runner::new` plumbing.
//!
//! # Submission lifecycle
//!
//! [`Engine::submit`] (and [`Engine::submit_one`]) is the entry point:
//! it resolves immediately what needs no worker — run-cache hits,
//! foreign-shard skips, in-batch duplicates — queues the rest on the
//! shared worker pool, and returns a [`SweepHandle`] without blocking.
//! The handle streams [`JobOutcome`]s in *completion* order
//! ([`SweepHandle::recv`] / [`try_recv`](SweepHandle::try_recv) /
//! iteration), so callers plot, early-stop, or schedule follow-up work
//! while the tail of a sweep is still training; [`SweepHandle::wait`]
//! collapses the stream into the classic submission-ordered
//! [`EngineReport`], and [`SweepHandle::cancel`] unqueues the
//! submission's pending jobs (in-flight jobs finish and are cached — a
//! cancelled sweep never leaves the cache inconsistent).  Handles are
//! independent: many callers may hold live handles against one engine
//! concurrently, each submission carrying its own
//! [`SubmitOptions::priority`].  [`Engine::run`] survives only as
//! `submit(jobs).wait()` for call sites that genuinely want the
//! blocking batch; [`Engine::run_sweep`] / [`Engine::run_single`] are
//! strict conveniences over it.
//!
//! # Priority / affinity scheduling
//!
//! Workers pull from a scheduler rather than a FIFO.  Dispatch order is
//! priority first (higher [`SubmitOptions::priority`] always wins),
//! then **manifest affinity**: within a priority level a worker prefers
//! jobs whose manifest is warm in its session pool ([`LruPool`]), and
//! crosses manifests — a *steal* — only when its warm shapes have no
//! pending work.  That keeps each worker's compiled sessions hot across
//! interleaved multi-shape batches (an XLA compile costs seconds; a
//! pool hit costs nothing) while still guaranteeing no worker idles
//! while any job is queued.  [`EngineStats::pool_hits`] /
//! [`EngineStats::pool_steals`] expose the split; healthy sweeps are
//! hit-dominated with `steals ≤ workers × distinct manifests`.
//!
//! # Sharding and the drive topology
//!
//! With [`EngineConfig::shard`] set to `i/n`, an engine executes only
//! jobs whose content address lands in its slice and records them to
//! its own `runs.<i>.jsonl` segment, so N *processes* drain one sweep
//! into one shared [`EngineConfig::cache_dir`] with no write contention
//! (foreign jobs resolve as explicit [`SHARD_SKIP_MARKER`] skips; a
//! merged cache satisfies any shard — see [`crate::engine::cache`] for
//! the on-disk layout).  The [`driver`] module closes the loop:
//! [`driver::drive`] (CLI: `repro drive --shards n`) spawns the N shard
//! processes itself, monitors them, restarts crashed ones against the
//! same cache dir (stale segment locks are reclaimed on restart), and
//! streams merged progress — one command instead of N terminals.
//!
//! # Execution backends
//!
//! *Where* jobs run is a first-class seam: the [`backend`] module's
//! [`Backend`] trait.  An engine is constructed over a backend
//! ([`Engine::with_backend`]); each worker thread asks it for a
//! private [`Executor`] (created on the worker's own thread, so it may
//! own `!Send` state), and everything above — submission, dedup,
//! sharding, priorities, the run cache — is backend-agnostic.  Three
//! implementations ship:
//!
//! * `XlaBackend` (the default behind `Engine::new`; needs the `xla`
//!   feature): in-process execution on per-worker [`LruPool`]s of
//!   compiled XLA sessions.
//! * [`MockBackend`]: closure-driven executors for tests and benches
//!   (CLI: `--backend mock`, which uses the canonical deterministic
//!   mock).
//! * [`ProcessBackend`] (CLI: `--backend process`): each worker slot
//!   owns a spawned `repro worker` child speaking a length-prefixed
//!   JSONL protocol over stdin/stdout, where the success reply *is*
//!   the run-cache line codec — wire format == cache format.  Child
//!   crashes are supervised per worker slot: bounded restart budget,
//!   the in-flight job re-dispatched once, then reported as a normal
//!   per-job `Err` outcome.  Child stderr is teed into the parent's
//!   log with a `[worker k]` prefix.
//! * [`NetworkBackend`] (CLI: `--backend network --workers
//!   host:port,...`): the same wire frames over sockets.  Each worker
//!   slot dials a long-lived `repro worker --listen` endpoint (TCP or
//!   `unix:/path`) from a round-robin list; connection loss is
//!   supervised exactly like a child crash — bounded reconnect budget,
//!   one re-dispatch of the in-flight job, failover to the next
//!   endpoint on redial.
//!
//! # Network topology
//!
//! The socket layer has two distinct planes, both framed by
//! [`backend::wire`]:
//!
//! * the **data plane** — engine ⇄ worker job traffic: `repro worker
//!   --listen <ep>` accepts any number of engines, serving each
//!   connection on its own thread; [`NetworkBackend`] is the dialing
//!   side.  The worker hello (`umup-worker`) authenticates it.
//! * the **control plane** — client ⇄ coordinator RPC: `repro serve`
//!   (the [`serve`] module) owns an engine and exposes
//!   `submit`/`status`/`cancel`/`cache-stats`/`shutdown` verbs over
//!   id-tagged RPC frames; `repro ctl <verb>` is the thin client.  The
//!   serve hello (`umup-serve`) is deliberately distinct, so
//!   cross-wiring the two socket kinds fails the handshake with an
//!   error that names the fix.
//!
//! Contract points that hold for *every* backend: outcomes are
//! persisted to the run cache by the engine worker **before** they are
//! reported (so a dropped handle never loses completed work, and a
//! consumer that sees an outcome may rely on the cache); executor
//! errors and panics are per-job, never fatal to the engine; and the
//! scheduler queries [`Backend::capabilities`] once — a backend
//! without per-manifest warm state opts out of affinity tracking and
//! gets plain priority+FIFO dispatch.
//!
//! # Observability
//!
//! Everything the engine used to *print* is modelled in the [`events`]
//! module as a typed, versioned [`Event`] stream — the human-readable
//! progress lines are now just one consumer among several:
//!
//! * **Taxonomy.**  Sweep lifecycle (`sweep_started` /
//!   `sweep_finished` with the full counter partition), per-job
//!   terminal outcomes (`job_queued` / `job_done` with key, manifest,
//!   duration, and a `status` of `executed`/`hit`/`dup`/`skip`/
//!   `cancelled` — exactly one per job, so the counts partition the
//!   sweep total), worker lifecycle (`worker_spawned` /
//!   `worker_restarted` / `worker_budget_exhausted` with teed stderr
//!   excerpts), cache activity (`cache_refresh` / `cache_compaction`),
//!   shard-driver lifecycle (`shard_spawned` / `shard_exit` /
//!   `shard_restarted`), and periodic throughput/ETA `snapshot`s.
//! * **Non-blocking bus.**  Publishers go through an [`EventBus`]
//!   handle ([`EngineConfig::events`]); with no subscriber a publish is
//!   one relaxed atomic load, and with subscribers it is `try_send`
//!   onto bounded channels — a slow consumer loses events into the
//!   counted [`EventBus::dropped`] metric, and never stalls a worker.
//! * **Versioning.**  Envelopes carry `v` ([`events::EVENTS_VERSION`])
//!   and evolve additively: new fields and event types appear without
//!   a bump, and [`Envelope::parse`] ignores unknown fields / maps
//!   unknown types to [`Event::Unknown`], so old readers tail new
//!   streams.  Breaking changes (rename/retype/remove) require a `v`
//!   bump; the golden test in `tests/events.rs` pins every variant's
//!   serialized form.
//!
//! Consumers: `--progress jsonl[:PATH]` on `train`/`exp`/`drive`
//! mirrors the stream to stdout or a file; `repro drive --tui`
//! (feature `tui`, `events::tui`) renders a live dashboard; and the
//! [`serve`] control plane re-serves the bus over the wire via the
//! `events` RPC verb (`repro ctl watch`).
//!
//! # Everything underneath (unchanged contracts)
//!
//! * **Per-worker session pools with LRU eviction** ([`LruPool`]):
//!   PJRT sessions are `!Send`, so each persistent worker owns its
//!   sessions; workers outlive submissions, amortizing compiles across
//!   experiments.
//! * **A sharded, multi-process-safe run cache** keyed by [`run_key`]
//!   (a canonical, label-independent hash of manifest/corpus/config),
//!   persisted as lock-safe JSONL segments with GC/compaction
//!   (`repro cache gc`, now also size-targeted via `--max-bytes`, plus
//!   automatic compaction when a directory accretes too many segments).
//! * **Per-job outcome reporting**: a failing job never kills a batch;
//!   workers persist results before reporting them, so dropping a
//!   handle abandons notifications, never completed work.
//!
//! # Performance notes
//!
//! The engine is sized for sweeps of 10⁵–10⁶ cached runs (u-µP's whole
//! economic argument is *many cheap proxy runs*), so the cache paths
//! scale with **new** work, not total history:
//!
//! * **Lazy record index.**  Opening a cache scans segments for *keys
//!   only*, building `key → (segment, byte offset, length, ts,
//!   manifest)`; no [`crate::train::RunRecord`] (full train/valid/RMS
//!   curves) is materialized until a submission actually hits that key,
//!   and then exactly once (memoized).  Resident memory is O(keys +
//!   records touched).  The tradeoff versus the old eager reader: the
//!   first hit on a key pays one seek + one line parse, and a
//!   structurally-valid line whose record body is malformed is
//!   discovered at hit time (degrading to a miss) rather than at open.
//! * **Incremental refresh.**  [`Engine::refresh_cache`] — the sharded
//!   `exp` converge loop's poll — remembers a per-segment tail offset
//!   and reads only bytes appended since the last call: O(new bytes),
//!   not O(total cache).  The shard driver's progress monitor
//!   ([`CacheWatcher`]) polls the same way, lock-free.
//! * **Compaction generation.**  Remembered offsets are only valid
//!   while segments are append-only; `repro cache gc` (and
//!   auto-compaction) bumps a generation marker under the segment
//!   locks, and an incremental reader that observes a changed
//!   generation — or a vanished/shrunken segment — falls back to one
//!   full rescan, then resumes tailing.  See [`cache`] for the full
//!   contract.
//! * **Bounded-memory compaction.**  The gc rewrite streams: line
//!   metadata spills to sorted temp runs and k-way merges back, each
//!   surviving line serialized exactly once, so compacting a 10⁶-entry
//!   cache holds O(spill chunk) entries resident, never O(cache).
//! * **Background tiered merges & key-presence filters.**  Between
//!   full gc passes, a [`Compactor`] (stepped from the drive loop's
//!   idle path when enabled, or `repro cache compact`) folds
//!   similar-sized adjacent segments with non-blocking locks — live
//!   writers are never stalled.  Compacted segments carry a
//!   bloom + fence-pointer sidecar (`<segment>.idx`), so a cold open
//!   adopts the segment without scanning it and miss-heavy lookups
//!   stop at the filter; [`FilterStats`] counts the saved work.
//! * **Memoized job identity.**  An [`EngineJob`]'s canonical config
//!   JSON and content address are computed once per job (shared across
//!   clones), so submission hashing and the process-backend wire frame
//!   don't re-serialize the same config.
//! * **Pipelined wire dispatch.**  Out-of-process executors keep a
//!   configurable window of encoded jobs in flight per connection
//!   (`--pipeline-depth N`; [`ProcessBackend::with_pipeline_depth`] /
//!   [`NetworkBackend::with_pipeline_depth`]).  The worker loop pulls
//!   up to `depth` jobs per scheduler claim — the first pull may steal
//!   across manifests, top-ups are warm-affine only, so a window never
//!   drags cold-manifest work onto a warm worker — encodes them into
//!   one write+flush, and matches replies to requests by content key
//!   in whatever order the peer finishes them.  Each completion is
//!   persisted and reported as its reply lands (streaming, not
//!   end-of-batch).  The remote `repro worker` overlaps too: frames
//!   are read ahead into a bounded queue ([`backend::wire`]'s
//!   `WORKER_READAHEAD`) so the next job parses while the current one
//!   executes.  The codec hot path is zero-realloc: `encode_job_into`
//!   / `read_frame_into` / `ok_reply_line_into` reuse caller scratch
//!   buffers, so steady-state dispatch allocates nothing per frame.
//!
//!   *Recovery contract*: when a connection dies with a non-empty
//!   window, **every unacknowledged job** in it is re-dispatched once
//!   (together, to the restarted child / next endpoint) under the same
//!   bounded `--max-restarts` budget as lockstep mode; a job that
//!   fails again is reported `Err` per job, never retried a third
//!   time.  Replies for keys outside the window are a protocol error
//!   (the connection is torn down), so a duplicate or stray reply can
//!   never mis-file a record into the cache.
//!
//!   *Determinism*: cache **contents** are depth-independent — the
//!   record for a key is byte-identical whatever the window, because
//!   the reply line *is* the cache line.  Per-connection dispatch
//!   *order* (and hence segment line order and live event order) only
//!   matches the classic lockstep path at depth 1; pin
//!   `--pipeline-depth 1` when a workflow diffs raw segment files
//!   instead of comparing keyed contents.
//!
//! # Failure semantics
//!
//! How each failure class is detected, what recovery runs, and which
//! events it publishes.  The invariant behind every row: a result is
//! persisted to the cache *before* it is reported, a job is recorded at
//! most once, and no recovery path may change result **bytes** — only
//! timing (the chaos suite, `tests/chaos.rs`, pins this by driving real
//! sweeps through the `repro chaos` fault proxy and byte-comparing the
//! drained cache against a clean run).
//!
//! | Failure | Detected by | Recovery | Events |
//! |---|---|---|---|
//! | Worker crash / connection death | read or write error, or EOF mid-exchange | respawn child / redial next endpoint under the bounded `--max-restarts` budget; the unacknowledged window is re-dispatched **exactly once**; a second loss (or exhausted budget) reports each lost job as a per-job `Err` | `worker_restarted`, then `worker_budget_exhausted` if the budget runs dry |
//! | Hung-but-alive peer | `--job-timeout SECS` only (off by default — unarmed runs are bit-for-bit identical): socket read/write deadlines on the network path, a SIGKILL watchdog over the child pid on the process path | the stalled connection is *treated as* a connection death; the crash row above takes over | `worker_stalled`, then the crash row's events |
//! | Protocol desync | reply keyed outside the in-flight window, duplicate reply, garbage or torn frame | connection torn down, crash row takes over; the stray record is **never** filed into the cache | crash row's events |
//! | Job failure (peer healthy) | error reply frame | no restart, no budget spent; reported as that job's `Err` outcome, worker keeps serving | `job_failed` |
//! | Graceful drain (SIGTERM/SIGINT) | [`crate::util::signal`] flag, polled by the serve/worker/drive loops | stop accepting new work, cancel pending jobs, let in-flight jobs finish and persist, unlink unix sockets, exit [`crate::util::signal::EXIT_DRAINED`] | normal completion events for whatever finished |
//! | Auth mismatch | listener's hello advertises auth; the token frame is checked before any job is served | the handshake fails with a hint naming `--token` / `UMUP_TOKEN`; no token configured on the listener = open, as before | none (the connection never serves) |

pub mod backend;
pub mod cache;
pub mod driver;
pub mod events;
mod handle;
mod job;
mod lru;
mod pool;
mod sched;
pub mod serve;

pub use crate::util::hash::fnv1a64;
#[cfg(feature = "xla")]
pub use backend::XlaBackend;
pub use backend::{
    det_record, Backend, Capabilities, Endpoint, Executor, FaultPlan, Listener, MockBackend,
    NetworkBackend, ProcessBackend,
};
pub use cache::{
    gc, list_segments, parse_bytes, parse_duration, run_key, stats, CacheStats, CacheWatcher,
    Compactor, CompactorConfig, FilterStats, GcOptions, GcReport, RunCache, SegmentStats, Shard,
    TierMergeReport,
};
pub use events::{Envelope, Event, EventBus, EventStream, JobStatus, SweepCounters};
pub use handle::{JobHandle, SubmitOptions, SweepHandle};
pub use job::{EngineJob, EngineReport, JobOutcome, SweepJob, SweepResult};
pub use lru::LruPool;
pub use pool::JobExec;

#[cfg(feature = "xla")]
use std::cell::RefCell;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{Context, Result};

use crate::data::Corpus;
use crate::runtime::Manifest;
#[cfg(feature = "xla")]
use crate::runtime::Session;
use crate::train::RunConfig;
#[cfg(feature = "xla")]
use crate::train::Runner;

use pool::WorkerPool;
use sched::Scheduler;

/// Marker embedded in every shard-skip outcome (and therefore in the
/// strict `run_sweep` error for a skipped job).  Callers running a
/// sharded drain match on this to distinguish "another shard owns this
/// run — retry once its result lands" from a real failure; see the
/// retry loop in `repro exp --shard`.
pub const SHARD_SKIP_MARKER: &str = "belongs to shard";

/// Poison-tolerant lock: engine-internal mutexes guard state that stays
/// consistent between operations (cache map, counters), so a panicking
/// thread elsewhere must not wedge the rest of the engine.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads; each owns a session pool.  XLA already
    /// multithreads each step, so small counts suffice — more workers
    /// trade batch-level against op-level parallelism.
    pub workers: usize,
    /// Persist the run cache under this directory as lock-safe JSONL
    /// segments (see [`cache`] for the layout).  `None` keeps an
    /// in-memory cache (dedup only, no resume).
    pub cache_dir: Option<PathBuf>,
    /// Load pre-existing cache entries from **all** segments in
    /// `cache_dir` (resume an interrupted or sharded sweep).  Without
    /// this, this engine's own segment is truncated.
    pub resume: bool,
    /// Execute only jobs whose content address falls in this slice
    /// (`i/n`), recording them to the `runs.<i>.jsonl` segment;
    /// everything else is reported as skipped.  `None` owns every job.
    pub shard: Option<Shard>,
    /// Per-worker compiled-session cap; the least-recently-used session
    /// is evicted when a worker's pool exceeds it (compiles are seconds,
    /// so eviction only bounds memory — see [`LruPool`]).  The affinity
    /// scheduler mirrors the same capacity when deciding which
    /// manifests are warm for a worker.
    pub max_sessions_per_worker: usize,
    /// Publish telemetry onto this [`EventBus`] (see [`events`] and the
    /// module-level *Observability* section).  `None` gives the engine
    /// a private bus with no subscribers — publishes cost one atomic
    /// load, so telemetry is free until someone listens.
    pub events: Option<EventBus>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 4,
            cache_dir: None,
            resume: false,
            shard: None,
            max_sessions_per_worker: 8,
            events: None,
        }
    }
}

/// Aggregate counters over an engine's lifetime (see
/// [`EngineReport`] for the per-submission view).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Jobs that actually ran on a worker (including failures).
    pub executed: usize,
    /// Jobs satisfied by the run cache at submit time.
    pub cache_hits: usize,
    /// Jobs resolved from an identical job earlier in their submission.
    pub deduped: usize,
    /// Jobs declined because their key belongs to another shard.
    pub skipped: usize,
    /// Jobs that errored on a worker (plus duplicates of those).
    pub failed: usize,
    /// Jobs cancelled while still queued (never executed).
    pub cancelled: usize,
    /// Scheduler dispatches whose manifest was warm for the worker.
    pub pool_hits: usize,
    /// Scheduler dispatches that crossed manifests (cold session).
    pub pool_steals: usize,
}

/// State shared between the engine facade, its workers, and any live
/// submission handles (which may outlive a dropped [`Engine`]).
pub(crate) struct Shared {
    pub(crate) cache: Mutex<RunCache>,
    pub(crate) stats: Mutex<EngineStats>,
    pub(crate) shard: Option<Shard>,
    /// Telemetry fan-out (never blocks; see [`events`]).
    pub(crate) events: EventBus,
    /// Sweep-id allocator for this engine's event stream.
    pub(crate) sweeps: std::sync::atomic::AtomicU64,
}

/// The unified run engine.  See the module docs for the architecture.
pub struct Engine {
    shared: Arc<Shared>,
    sched: Arc<Scheduler>,
    /// Held only for its Drop, which shuts the scheduler down and joins
    /// the workers (they drain the queue first, so every live handle
    /// still gets its replies).
    _pool: WorkerPool,
    /// Caller-thread sessions for the stateful APIs ([`Engine::session`]
    /// / [`Engine::runner`]); separate from the worker pools because
    /// sessions cannot cross threads.
    #[cfg(feature = "xla")]
    local: RefCell<HashMap<String, Arc<Session>>>,
}

impl Engine {
    /// An engine over the default in-process [`XlaBackend`]: jobs run
    /// on real XLA sessions, compiled on first use per (worker,
    /// manifest) and LRU-pooled thereafter.
    #[cfg(feature = "xla")]
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        let backend = Arc::new(XlaBackend::new(cfg.max_sessions_per_worker));
        Self::with_backend(cfg, backend)
    }

    /// Build an engine over an explicit execution [`Backend`] — the
    /// seam behind `Engine::new` (`XlaBackend`), the test/bench
    /// harnesses ([`MockBackend`]), and out-of-process fleets
    /// ([`ProcessBackend`]); embedders implement the trait to plug in
    /// remote execution.
    ///
    /// The backend's [`Backend::health`] probe runs here, once, so a
    /// broken backend (missing worker binary, bad artifact path) fails
    /// construction instead of every job; its
    /// [`Backend::capabilities`] are queried once to configure the
    /// scheduler.
    pub fn with_backend(cfg: EngineConfig, backend: Arc<dyn Backend>) -> Result<Engine> {
        backend
            .health()
            .with_context(|| format!("{} backend failed its health probe", backend.name()))?;
        let caps = backend.capabilities();
        let cache = match &cfg.cache_dir {
            Some(dir) => RunCache::open_sharded(dir, cfg.shard, cfg.resume)?,
            None => RunCache::in_memory(),
        };
        let events = cfg.events.clone().unwrap_or_default();
        // hand the backend a publisher so out-of-process supervisors
        // (restart / budget-exhaustion) report onto the same stream
        backend.attach_events(&events);
        let shared = Arc::new(Shared {
            cache: Mutex::new(cache),
            stats: Mutex::new(EngineStats::default()),
            shard: cfg.shard,
            events,
            sweeps: std::sync::atomic::AtomicU64::new(0),
        });
        let sched = Arc::new(Scheduler::new(
            cfg.workers,
            cfg.max_sessions_per_worker.max(1),
            caps.session_affinity,
        ));
        let pool =
            WorkerPool::new(cfg.workers, backend, Arc::clone(&sched), Arc::clone(&shared));
        Ok(Engine {
            shared,
            sched,
            _pool: pool,
            #[cfg(feature = "xla")]
            local: RefCell::new(HashMap::new()),
        })
    }

    /// Build an engine with a bare per-worker executor-closure factory.
    #[deprecated(
        since = "0.2.0",
        note = "wrap the factory in `MockBackend::new` (or implement `Backend`) and use \
                `Engine::with_backend`"
    )]
    pub fn with_factory<F>(cfg: EngineConfig, factory: F) -> Result<Engine>
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        Self::with_backend(cfg, Arc::new(MockBackend::new(factory)))
    }

    /// Does this engine's shard own the run with content address `key`?
    /// (Unsharded engines own everything.)
    fn owns(&self, key: &str) -> bool {
        match self.shared.shard {
            Some(s) => s.owns(key),
            None => true,
        }
    }

    /// Is this engine draining only one shard of its sweeps?
    pub fn is_sharded(&self) -> bool {
        self.shared.shard.is_some()
    }

    /// Submit a batch non-blockingly at default priority; outcomes
    /// stream through the returned handle as they complete.
    pub fn submit(&self, jobs: Vec<EngineJob>) -> SweepHandle {
        self.submit_with(jobs, SubmitOptions::default())
    }

    /// [`Engine::submit`] with explicit [`SubmitOptions`] (priority).
    ///
    /// Cache hits, foreign-shard skips and in-batch duplicates are
    /// resolved immediately (they stream out first); the rest is queued
    /// on the shared worker pool.  Jobs with identical content
    /// addresses execute once per submission — concurrent *handles*
    /// racing the same address may both execute it (the cache `put` is
    /// idempotent, so correctness is unaffected; only the duplicate
    /// work is paid).
    pub fn submit_with(&self, jobs: Vec<EngineJob>, opts: SubmitOptions) -> SweepHandle {
        let n = jobs.len();
        let keys: Vec<String> = jobs.iter().map(|j| j.key()).collect();
        let (tx, rx) = mpsc::channel();
        let ctl = self.sched.new_submission();
        let sweep = self.shared.sweeps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let bus = &self.shared.events;
        bus.publish(Event::SweepStarted { sweep, total: n });
        if bus.is_active() {
            for (i, job) in jobs.iter().enumerate() {
                bus.publish(Event::JobQueued {
                    sweep,
                    idx: i,
                    key: keys[i].clone(),
                    manifest: job.manifest.name.clone(),
                    label: job.config.label.clone(),
                });
            }
        }

        let mut outcomes: Vec<Option<JobOutcome>> = Vec::with_capacity(n);
        outcomes.resize_with(n, || None);
        let mut followers_of: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut ready = VecDeque::new();
        let mut to_run: Vec<usize> = Vec::new();
        let mut cache_hits = 0usize;
        let mut skipped = 0usize;
        {
            // mut: a cache hit may lazily parse (and memoize) the
            // record from its indexed byte span — see `cache`
            let mut cache = lock(&self.shared.cache);
            let mut primary_of: HashMap<&str, usize> = HashMap::new();
            for (i, job) in jobs.iter().enumerate() {
                if let Some(rec) = cache.get(&keys[i]) {
                    let mut rec = rec.clone();
                    rec.label = job.config.label.clone();
                    outcomes[i] = Some(JobOutcome {
                        idx: i,
                        job: job.clone(),
                        outcome: Ok(rec),
                        cached: true,
                        skipped: false,
                        cancelled: false,
                    });
                    ready.push_back(i);
                    cache_hits += 1;
                    if bus.is_active() {
                        bus.publish(Event::JobDone {
                            sweep,
                            idx: i,
                            key: keys[i].clone(),
                            manifest: job.manifest.name.clone(),
                            label: job.config.label.clone(),
                            status: JobStatus::Hit,
                            ok: true,
                            error: None,
                            duration_ms: None,
                            worker: None,
                        });
                    }
                } else if !self.owns(&keys[i]) {
                    let shard = self.shared.shard.expect("owns() is false only when sharded");
                    outcomes[i] = Some(JobOutcome {
                        idx: i,
                        job: job.clone(),
                        outcome: Err(format!(
                            "skipped: run {} {SHARD_SKIP_MARKER} {}/{} (this engine is \
                             shard {shard}; drain that shard into the same cache dir, \
                             then merge with --resume)",
                            keys[i],
                            shard.index_of(&keys[i]),
                            shard.count,
                        )),
                        cached: false,
                        skipped: true,
                        cancelled: false,
                    });
                    ready.push_back(i);
                    skipped += 1;
                    if bus.is_active() {
                        let err = outcomes[i]
                            .as_ref()
                            .and_then(|o| o.outcome.as_ref().err().cloned());
                        bus.publish(Event::JobDone {
                            sweep,
                            idx: i,
                            key: keys[i].clone(),
                            manifest: job.manifest.name.clone(),
                            label: job.config.label.clone(),
                            status: JobStatus::Skip,
                            ok: false,
                            error: err,
                            duration_ms: None,
                            worker: None,
                        });
                    }
                } else if let Some(&p) = primary_of.get(keys[i].as_str()) {
                    followers_of[p].push(i);
                } else {
                    primary_of.insert(keys[i].as_str(), i);
                    to_run.push(i);
                }
            }
        }
        {
            let mut s = lock(&self.shared.stats);
            s.cache_hits += cache_hits;
            s.skipped += skipped;
        }

        let tasks: Vec<sched::Task> = to_run
            .iter()
            .map(|&i| {
                sched::Task::new(
                    opts.priority,
                    sweep,
                    i,
                    keys[i].clone(),
                    jobs[i].clone(),
                    tx.clone(),
                    Arc::clone(&ctl),
                )
            })
            .collect();
        let outstanding = tasks.len();
        self.sched.enqueue(tasks);

        let resolved = cache_hits + skipped;
        let mut handle = SweepHandle {
            shared: Arc::clone(&self.shared),
            sched: Arc::clone(&self.sched),
            ctl,
            rx,
            sweep,
            t0: std::time::Instant::now(),
            jobs,
            outcomes,
            ready,
            followers_of,
            dispatched: to_run,
            outstanding,
            resolved,
            finished: false,
            emitted: 0,
            cache_hits,
            deduped: 0,
            skipped,
            executed: 0,
            failed: 0,
            cancelled: 0,
        };
        // a sweep satisfied entirely at submit time finishes here
        handle.maybe_finish();
        handle
    }

    /// Submit one job non-blockingly (cache-aware like any other).
    pub fn submit_one(&self, job: EngineJob) -> JobHandle {
        JobHandle(self.submit(vec![job]))
    }

    /// Run a batch of (possibly multi-manifest) jobs and block for the
    /// full report — a thin `submit(jobs).wait()`.  Never fails
    /// wholesale: each job gets its own `Ok`/`Err` in the report.
    pub fn run(&self, jobs: Vec<EngineJob>) -> EngineReport {
        self.submit(jobs).wait()
    }

    /// Run a single-manifest batch strictly: job-ordered results or the
    /// first per-job error (all jobs are still attempted either way).
    pub fn run_sweep(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        jobs: &[SweepJob],
    ) -> Result<Vec<SweepResult>> {
        let engine_jobs = jobs
            .iter()
            .map(|j| {
                EngineJob::new(
                    Arc::clone(manifest),
                    Arc::clone(corpus),
                    j.config.clone(),
                    j.tag.clone(),
                )
            })
            .collect();
        self.run(engine_jobs).into_sweep_results()
    }

    /// Run one config (cache-aware like any other job), blocking.
    pub fn run_single(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        config: RunConfig,
    ) -> Result<SweepResult> {
        self.submit_one(EngineJob::new(
            Arc::clone(manifest),
            Arc::clone(corpus),
            config,
            vec![],
        ))
        .result()
    }

    /// A caller-thread session for `manifest`, compiled once and pooled
    /// for the engine's lifetime (this is where the old
    /// `Registry::session` cache moved).
    #[cfg(feature = "xla")]
    pub fn session(&self, manifest: &Arc<Manifest>) -> Result<Arc<Session>> {
        if let Some(s) = self.local.borrow().get(&manifest.name) {
            return Ok(Arc::clone(s));
        }
        let s = Arc::new(Session::open(Arc::clone(manifest))?);
        self.local.borrow_mut().insert(manifest.name.clone(), Arc::clone(&s));
        Ok(s)
    }

    /// A [`Runner`] over the pooled caller-thread session — for stateful
    /// work the job queue cannot express (`run_full`, `eval_at_init`,
    /// probe evaluation).
    #[cfg(feature = "xla")]
    pub fn runner(&self, manifest: &Arc<Manifest>) -> Result<Runner> {
        Ok(Runner::new(self.session(manifest)?))
    }

    /// Lifetime counters (executed / cache hits / deduped / skipped /
    /// failed / cancelled, plus scheduler affinity hits and steals).
    ///
    /// Dedup and follower-failure counters are recorded as handles
    /// *drain*; a handle dropped without draining undercounts them (the
    /// work itself — execution and caching — is unaffected).
    pub fn stats(&self) -> EngineStats {
        let mut s = *lock(&self.shared.stats);
        let (hits, steals, cancelled) = self.sched.counters();
        s.pool_hits = hits as usize;
        s.pool_steals = steals as usize;
        // queued-task cancels live in the scheduler; cancelled
        // *followers* (duplicates of a cancelled primary) are recorded
        // by their handle into the shared counter — sum both
        s.cancelled += cancelled as usize;
        s
    }

    /// The engine's telemetry bus — subscribe for the typed event
    /// stream ([`events`]); clones publish onto the same bus.  This is
    /// the bus passed as [`EngineConfig::events`], or a private one.
    pub fn events(&self) -> &EventBus {
        &self.shared.events
    }

    /// Number of records currently addressable in the run cache.
    pub fn cache_len(&self) -> usize {
        lock(&self.shared.cache).len()
    }

    /// Merge in records that sibling shard processes have appended to
    /// the shared cache directory since this engine opened it (no-op
    /// for in-memory caches).  Returns the number of newly visible
    /// records — the sharded drain's progress signal.
    pub fn refresh_cache(&self) -> usize {
        let mut cache = lock(&self.shared.cache);
        let new_keys = cache.refresh_from_disk();
        let total_keys = cache.len();
        drop(cache);
        if new_keys > 0 {
            self.shared.events.publish(Event::CacheRefresh { new_keys, total_keys });
        }
        new_keys
    }

    /// Run at most one background tier-merge step against this engine's
    /// cache directory (`Ok(None)` for in-memory caches and when no
    /// group is mergeable).  The cache mutex is held only long enough
    /// to read the directory path — the merge itself runs beside the
    /// workers, and this engine's own segment is protected by its
    /// writer lock (the compactor skips any group containing it).  The
    /// next [`Engine::refresh_cache`] picks a rewrite up through the
    /// generation contract.
    pub fn compact_step(&self) -> Result<Option<TierMergeReport>> {
        let dir = lock(&self.shared.cache).dir().map(|d| d.to_path_buf());
        let report = match dir {
            Some(dir) => Compactor::new(&dir).step()?,
            None => None,
        };
        if let Some(rep) = &report {
            self.shared.events.publish(Event::CacheCompaction {
                inputs: rep.inputs.len(),
                output: rep.output.clone(),
                entries: rep.entries,
                deduped: rep.deduped,
            });
        }
        Ok(report)
    }
}
