//! S12 — the unified run engine: one subsystem owns run execution end to
//! end.
//!
//! Everything that trains (experiments, the CLI, examples, benches)
//! routes through [`Engine`] instead of hand-rolling
//! `Session::open`/`Runner::new` plumbing.  The engine provides:
//!
//! * **A multi-manifest job queue.**  One worker pool drains
//!   [`EngineJob`]s spanning different artifact shapes, so cross-width
//!   transfer sweeps (fig1b/fig5) are no longer serialized per shape.
//! * **Per-worker session pools.**  PJRT sessions are `!Send`, so each
//!   persistent worker keeps its own `manifest name → Session` map.
//!   Workers outlive individual [`Engine::run`] calls, which amortizes
//!   XLA compiles (seconds per module) across experiments.
//! * **A content-addressed run cache.**  A canonical, label-independent
//!   hash of (manifest name, corpus config, [`RunConfig`]) maps to
//!   [`RunRecord`] (see [`run_key`]), deduplicating repeated configs
//!   within a batch and — with [`EngineConfig::cache_dir`] — persisting
//!   results as JSONL so interrupted sweeps resume across process
//!   restarts.
//! * **Per-job outcome reporting.**  [`EngineReport`] carries an
//!   `Ok`/`Err` per job plus progress counters; a failing job no longer
//!   kills the batch (the old scheduler's first-error-kills-all
//!   behavior, and its worker-abandons-queue bug, are both gone).
//!
//! The caller-facing surface is [`Engine::run`] (full per-job report),
//! [`Engine::run_sweep`] / [`Engine::run_single`] (strict, job-ordered)
//! and [`Engine::session`] / [`Engine::runner`] for caller-thread
//! stateful work (probe evaluation, init telemetry, `run_full`).

mod cache;
mod job;
mod pool;

pub use cache::{run_key, RunCache};
pub use crate::util::hash::fnv1a64;
pub use job::{EngineJob, EngineReport, JobOutcome, SweepJob, SweepResult};
pub use pool::JobExec;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::data::Corpus;
use crate::runtime::{Manifest, Session};
use crate::train::{RunConfig, RunRecord, Runner};

use pool::{Task, WorkerPool};

/// Engine construction options.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads; each owns a session pool.  XLA already
    /// multithreads each step, so small counts suffice — more workers
    /// trade batch-level against op-level parallelism.
    pub workers: usize,
    /// Persist the run cache under this directory (as `runs.jsonl`).
    /// `None` keeps an in-memory cache (dedup only, no resume).
    pub cache_dir: Option<PathBuf>,
    /// Load pre-existing cache entries (resume an interrupted sweep).
    /// Without this an existing cache file is truncated.
    pub resume: bool,
    /// Per-worker compiled-session cap; a worker's pool is cleared
    /// wholesale when exceeded (compiles are seconds, so the crude
    /// eviction is fine — the cap only bounds memory).
    pub max_sessions_per_worker: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 4,
            cache_dir: None,
            resume: false,
            max_sessions_per_worker: 8,
        }
    }
}

/// Aggregate counters over an engine's lifetime (see
/// [`EngineReport`] for the per-batch view).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub executed: usize,
    pub cache_hits: usize,
    pub deduped: usize,
    pub failed: usize,
}

/// The unified run engine.  See the module docs for the architecture.
pub struct Engine {
    pool: WorkerPool,
    cache: Mutex<RunCache>,
    stats: Mutex<EngineStats>,
    /// Caller-thread sessions for the stateful APIs ([`Engine::session`]
    /// / [`Engine::runner`]); separate from the worker pools because
    /// sessions cannot cross threads.
    local: RefCell<HashMap<String, Arc<Session>>>,
}

impl Engine {
    /// An engine whose workers run jobs on real XLA sessions, compiled
    /// on first use per (worker, manifest) and pooled thereafter.
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        let cap = cfg.max_sessions_per_worker.max(1);
        Self::with_factory(cfg, move |_worker| {
            let mut sessions: HashMap<String, Runner> = HashMap::new();
            Box::new(move |job: &EngineJob| -> Result<RunRecord> {
                if !sessions.contains_key(&job.manifest.name) {
                    if sessions.len() >= cap {
                        sessions.clear();
                    }
                    let session = Session::open(Arc::clone(&job.manifest)).with_context(
                        || format!("opening worker session for {}", job.manifest.name),
                    )?;
                    sessions
                        .insert(job.manifest.name.clone(), Runner::new(Arc::new(session)));
                }
                sessions[&job.manifest.name].run(&job.config, &job.corpus)
            })
        })
    }

    /// Build an engine with a custom per-worker executor factory.
    ///
    /// This is the seam the engine tests and benches use to exercise
    /// queueing, deduplication, caching and failure handling without
    /// XLA artifacts; embedders can use it to plug in remote execution.
    pub fn with_factory<F>(cfg: EngineConfig, factory: F) -> Result<Engine>
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        let cache = match &cfg.cache_dir {
            Some(dir) => RunCache::open(dir, cfg.resume)?,
            None => RunCache::in_memory(),
        };
        Ok(Engine {
            pool: WorkerPool::new(cfg.workers, factory),
            cache: Mutex::new(cache),
            stats: Mutex::new(EngineStats::default()),
            local: RefCell::new(HashMap::new()),
        })
    }

    /// Run a batch of (possibly multi-manifest) jobs.  Never fails
    /// wholesale: each job gets its own `Ok`/`Err` in the report.
    ///
    /// Within the batch, jobs with the same content address are executed
    /// once; cache hits (including those loaded from a `--resume`d
    /// cache file) skip execution entirely.
    pub fn run(&self, jobs: Vec<EngineJob>) -> EngineReport {
        let n = jobs.len();
        let keys: Vec<String> =
            jobs.iter().map(|j| run_key(&j.manifest.name, &j.corpus, &j.config)).collect();
        let mut outcomes: Vec<Option<JobOutcome>> = Vec::with_capacity(n);
        outcomes.resize_with(n, || None);

        // Partition: cache hit / duplicate-of-earlier / must run.
        let mut primary_of: HashMap<&str, usize> = HashMap::new();
        let mut followers: Vec<(usize, usize)> = Vec::new(); // (dup, primary)
        let mut to_run: Vec<usize> = Vec::new();
        let mut cache_hits = 0usize;
        {
            let cache = self.cache.lock().unwrap();
            for (i, job) in jobs.iter().enumerate() {
                if let Some(rec) = cache.get(&keys[i]) {
                    let mut rec = rec.clone();
                    rec.label = job.config.label.clone();
                    outcomes[i] = Some(JobOutcome {
                        job: job.clone(),
                        outcome: Ok(rec),
                        cached: true,
                    });
                    cache_hits += 1;
                } else if let Some(&p) = primary_of.get(keys[i].as_str()) {
                    followers.push((i, p));
                } else {
                    primary_of.insert(keys[i].as_str(), i);
                    to_run.push(i);
                }
            }
        }

        // Dispatch the misses to the worker pool.
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut submitted = 0usize;
        let mut failed = 0usize;
        for &i in &to_run {
            let task = Task { idx: i, job: jobs[i].clone(), reply: reply_tx.clone() };
            if self.pool.submit(task) {
                submitted += 1;
            } else {
                failed += 1;
                outcomes[i] = Some(JobOutcome {
                    job: jobs[i].clone(),
                    outcome: Err("engine worker pool is gone".to_string()),
                    cached: false,
                });
            }
        }
        drop(reply_tx);

        let mut executed = 0usize;
        for _ in 0..submitted {
            let Ok((i, res)) = reply_rx.recv() else {
                break; // a worker died mid-job; stragglers handled below
            };
            executed += 1; // the job ran on a worker, whatever its outcome
            let outcome = match res {
                Ok(record) => {
                    let mut cache = self.cache.lock().unwrap();
                    if let Err(e) = cache.put(&keys[i], &jobs[i].manifest.name, &record) {
                        eprintln!(
                            "run-cache: failed to persist {}: {e:#}",
                            jobs[i].config.label
                        );
                    }
                    Ok(record)
                }
                Err(msg) => {
                    failed += 1;
                    Err(msg)
                }
            };
            outcomes[i] = Some(JobOutcome { job: jobs[i].clone(), outcome, cached: false });
        }
        for &i in &to_run {
            if outcomes[i].is_none() {
                failed += 1;
                outcomes[i] = Some(JobOutcome {
                    job: jobs[i].clone(),
                    outcome: Err("engine worker died before finishing this job".to_string()),
                    cached: false,
                });
            }
        }

        // Resolve in-batch duplicates from their primary's outcome.
        let mut deduped = 0usize;
        for &(d, p) in &followers {
            let outcome = match &outcomes[p].as_ref().expect("primary resolved").outcome {
                Ok(rec) => {
                    deduped += 1;
                    let mut rec = rec.clone();
                    rec.label = jobs[d].config.label.clone();
                    Ok(rec)
                }
                Err(e) => {
                    failed += 1;
                    Err(e.clone())
                }
            };
            outcomes[d] = Some(JobOutcome { job: jobs[d].clone(), outcome, cached: true });
        }

        let outcomes: Vec<JobOutcome> =
            outcomes.into_iter().map(|o| o.expect("all jobs resolved")).collect();
        let completed = outcomes.iter().filter(|o| o.outcome.is_ok()).count();
        {
            let mut s = self.stats.lock().unwrap();
            s.executed += executed;
            s.cache_hits += cache_hits;
            s.deduped += deduped;
            s.failed += failed;
        }
        EngineReport { outcomes, completed, failed, cache_hits, deduped, executed }
    }

    /// Run a single-manifest batch strictly: job-ordered results or the
    /// first per-job error (all jobs are still attempted either way).
    pub fn run_sweep(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        jobs: &[SweepJob],
    ) -> Result<Vec<SweepResult>> {
        let engine_jobs = jobs
            .iter()
            .map(|j| EngineJob {
                manifest: Arc::clone(manifest),
                corpus: Arc::clone(corpus),
                config: j.config.clone(),
                tag: j.tag.clone(),
            })
            .collect();
        self.run(engine_jobs).into_sweep_results()
    }

    /// Run one config (cache-aware like any other job).
    pub fn run_single(
        &self,
        manifest: &Arc<Manifest>,
        corpus: &Arc<Corpus>,
        config: RunConfig,
    ) -> Result<SweepResult> {
        let mut v = self.run_sweep(manifest, corpus, &[SweepJob { config, tag: vec![] }])?;
        Ok(v.pop().expect("one job in, one result out"))
    }

    /// A caller-thread session for `manifest`, compiled once and pooled
    /// for the engine's lifetime (this is where the old
    /// `Registry::session` cache moved).
    pub fn session(&self, manifest: &Arc<Manifest>) -> Result<Arc<Session>> {
        if let Some(s) = self.local.borrow().get(&manifest.name) {
            return Ok(Arc::clone(s));
        }
        let s = Arc::new(Session::open(Arc::clone(manifest))?);
        self.local.borrow_mut().insert(manifest.name.clone(), Arc::clone(&s));
        Ok(s)
    }

    /// A [`Runner`] over the pooled caller-thread session — for stateful
    /// work the job queue cannot express (`run_full`, `eval_at_init`,
    /// probe evaluation).
    pub fn runner(&self, manifest: &Arc<Manifest>) -> Result<Runner> {
        Ok(Runner::new(self.session(manifest)?))
    }

    /// Lifetime counters (executed / cache hits / deduped / failed).
    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }

    /// Number of records currently addressable in the run cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
