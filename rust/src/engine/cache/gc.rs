//! Run-cache lifecycle: pruning, size-targeted eviction, and
//! compaction — the only code here that *rewrites* every segment.
//!
//! Compaction is a bounded-memory streaming pipeline, not an eager
//! merge: at 10⁶ entries the cache outgrows RAM long before it outgrows
//! disk, so no phase may hold more than O(chunk) entries resident.
//!
//! 1. **Scan** — every segment is read strictly
//!    ([`super::segment::scan_lines_strict`]): each line is validated by
//!    the non-materializing key scanner ([`super::index::scan_line`])
//!    and spilled as a [`KeyedLine`] — key, scan sequence number, and
//!    the (segment, offset, length) needed to re-read it — in sorted
//!    fixed-size runs ([`super::spill`]).  A segment that cannot be
//!    read **aborts the whole gc** before any file is touched: a lossy
//!    scan followed by a rewrite would silently destroy the entries it
//!    never saw.
//! 2. **Plan** — a k-way merge replays the runs in (key, seq) order;
//!    the last item of each key group is the newest write and wins.
//!    The `older_than` / `manifest` filters apply to winners here, and
//!    when `max_bytes` is set the surviving (ts, key, len) triples are
//!    spilled again and age-merged to find the eviction cutoff — all
//!    without serializing a single record.  `dry_run` stops here, so
//!    its projection is exact and costs zero writes.
//! 3. **Write** — the key runs are replayed once more; each surviving
//!    winner is re-read from its segment, parsed through the reference
//!    codec, and serialized exactly once into `runs.jsonl.tmp`
//!    (key-sorted, so the output feeds a [`super::filter::SidecarWriter`]
//!    as it streams).  Rename + delete the merged segments + bump the
//!    generation marker, all under every segment's writer lock.
//!
//! What compaction owes the lazy readers ([`super::index`]) is the
//! **generation contract**: any non-dry-run rewrite bumps the
//! directory's generation marker (under every segment's writer lock),
//! so incremental readers discover that their remembered byte offsets
//! died with the old files and fall back to one full rescan.
//!
//! One deliberate divergence from the old eager path: a line whose
//! `record` is valid JSON of the wrong *shape* passes the plan (the key
//! scanner doesn't build records) and is dropped at write time with
//! `corrupt_dropped`, so a `dry_run` projection can overcount such
//! lines.  They only exist in hand-edited caches.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::hash::fnv1a64;

use super::filter::{remove_sidecar, SidecarWriter, PREFIX_HASH_SPAN};
use super::index::scan_line;
use super::segment::{
    bump_generation, entry_line, list_segments, now_ts, parse_full_entry, read_generation,
    scan_lines_strict, SegmentLock,
};
use super::spill::{AgeKey, KeyedLine, SpillWriter, DEFAULT_SPILL_CHUNK};

/// Opening a cache dir with `resume` auto-compacts it first when it
/// holds more than this many segments (see [`super::RunCache::open_sharded`]).
pub const AUTO_COMPACT_SEGMENT_THRESHOLD: usize = 8;

/// What [`gc`] should prune.  With no filters set, GC is a pure
/// compaction: segments merge into one key-sorted `runs.jsonl`, dropping
/// cross-segment duplicates and corrupt lines.
#[derive(Debug, Clone, Default)]
pub struct GcOptions {
    /// Prune entries whose `ts` is at least this old (entries without a
    /// `ts` — pre-lifecycle lines — count as arbitrarily old).
    pub older_than: Option<Duration>,
    /// Prune entries recorded under this manifest name.
    pub manifest: Option<String>,
    /// Size budget for the compacted cache: after the filters above,
    /// evict oldest-`ts` entries (ties broken by key, for determinism)
    /// until the surviving lines fit in this many bytes.
    pub max_bytes: Option<u64>,
    /// Report what would happen without touching any file.
    pub dry_run: bool,
    /// Entries held in memory per spill run — the bounded-memory knob.
    /// Peak resident usage is O(this), independent of cache size.
    /// `None` uses [`super::spill::DEFAULT_SPILL_CHUNK`]; tiny values
    /// are only useful to tests.
    pub chunk_entries: Option<usize>,
}

/// What [`gc`] did (or, under `dry_run`, would do).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Structurally valid lines seen across all segments.
    pub scanned: usize,
    pub kept: usize,
    /// Entries dropped by the age / manifest filters.
    pub pruned: usize,
    /// Entries evicted (oldest first) to meet the `max_bytes` budget.
    pub evicted: usize,
    /// Cross-segment duplicate lines collapsed by compaction.
    pub deduped: usize,
    pub corrupt_dropped: usize,
    pub segments_before: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

fn read_span(path: &Path, offset: u64, len: usize) -> Result<Vec<u8>> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    f.seek(SeekFrom::Start(offset))
        .with_context(|| format!("seeking {} in {}", offset, path.display()))?;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)
        .with_context(|| format!("reading {len} bytes at {offset} of {}", path.display()))?;
    Ok(buf)
}

/// Prune and compact a cache directory with O(chunk) resident memory.
///
/// Takes every segment's writer lock first (erroring if any segment has
/// a live writer), streams all segments through the spill/merge pipeline
/// (last write per key wins), applies the [`GcOptions`] filters, and —
/// unless `dry_run` — rewrites the survivors as a single key-sorted
/// `runs.jsonl` (via a temp file + rename) with a fresh key-presence
/// sidecar, deletes the shard segments and their stale sidecars, and
/// bumps the directory's compaction generation so incremental readers
/// rescan.  An emptied cache ends up with no segment files at all.
///
/// All reads happen before any mutation: an unreadable segment aborts
/// the gc with every file intact.
pub fn gc(dir: &Path, opts: &GcOptions) -> Result<GcReport> {
    let segments = list_segments(dir)?;
    let mut report = GcReport { segments_before: segments.len(), ..GcReport::default() };
    if segments.is_empty() {
        return Ok(report);
    }
    let compacted = dir.join("runs.jsonl");
    // lock every segment plus the compaction target so no live writer
    // (or competing gc) can race the rewrite
    let mut locks = Vec::new();
    for seg in segments.iter().chain(
        (!segments.contains(&compacted)).then_some(&compacted),
    ) {
        locks.push(
            SegmentLock::acquire(seg)
                .with_context(|| format!("gc: locking segment {}", seg.display()))?,
        );
    }
    let chunk = opts.chunk_entries.unwrap_or(DEFAULT_SPILL_CHUNK);

    // ---- phase 1: strict scan, spill (key, seq) sorted runs
    let mut manifests: Vec<String> = Vec::new();
    let mut manifest_ids: std::collections::HashMap<String, u32> = std::collections::HashMap::new();
    let mut spill: SpillWriter<KeyedLine> = SpillWriter::new(dir, "keys", chunk)?;
    let mut seq = 0u64;
    for (seg_idx, seg) in segments.iter().enumerate() {
        report.bytes_before += std::fs::metadata(seg).map(|m| m.len()).unwrap_or(0);
        scan_lines_strict(seg, |offset, raw| {
            let Ok(text) = std::str::from_utf8(raw) else {
                report.corrupt_dropped += 1;
                return Ok(());
            };
            if text.trim().is_empty() {
                return Ok(());
            }
            match scan_line(text.trim_end_matches('\r')) {
                Ok(meta) => {
                    report.scanned += 1;
                    let manifest = match manifest_ids.get(&meta.manifest) {
                        Some(&id) => id,
                        None => {
                            let id = manifests.len() as u32;
                            manifests.push(meta.manifest.clone());
                            manifest_ids.insert(meta.manifest, id);
                            id
                        }
                    };
                    spill.push(KeyedLine {
                        key: meta.key,
                        seq,
                        seg: seg_idx as u32,
                        offset,
                        len: raw.len() as u32,
                        ts: meta.ts,
                        manifest,
                    })?;
                    seq += 1;
                }
                Err(_) => report.corrupt_dropped += 1,
            }
            Ok(())
        })
        .with_context(|| {
            format!("gc: reading segment {} (aborted; no file was modified)", seg.display())
        })?;
    }
    let runs = spill.finish()?;

    // ---- phase 2: merge winners, filter, plan the size budget
    let cutoff = opts.older_than.map(|d| now_ts().saturating_sub(d.as_secs()));
    // a filter naming a manifest no line uses prunes nothing
    let manifest_filter: Option<u32> =
        opts.manifest.as_ref().and_then(|m| manifest_ids.get(m).copied());
    let survives = |item: &KeyedLine| {
        if manifest_filter.is_some_and(|mid| item.manifest == mid) {
            return false;
        }
        !cutoff.is_some_and(|cut| item.ts <= cut)
    };

    let mut age: Option<SpillWriter<AgeKey>> = match opts.max_bytes {
        Some(_) => Some(SpillWriter::new(dir, "age", chunk)?),
        None => None,
    };
    let mut survivors = 0u64;
    let mut projected = 0u64;
    {
        let mut merge = runs.merge()?;
        let mut cur = merge.next()?;
        while let Some(first) = cur.take() {
            let mut winner = first;
            loop {
                match merge.next()? {
                    Some(next) if next.key == winner.key => {
                        report.deduped += 1;
                        winner = next;
                    }
                    other => {
                        cur = other;
                        break;
                    }
                }
            }
            if !survives(&winner) {
                report.pruned += 1;
                continue;
            }
            survivors += 1;
            projected += winner.len as u64 + 1;
            if let Some(w) = &mut age {
                w.push(AgeKey { ts: winner.ts, key: winner.key, len: winner.len })?;
            }
        }
    }

    let mut evicted = 0u64;
    let mut evict_cutoff: Option<(u64, String)> = None;
    let age_runs = match age {
        Some(w) => Some(w.finish()?),
        None => None,
    };
    if let (Some(budget), Some(age_runs)) = (opts.max_bytes, &age_runs) {
        if projected > budget {
            let mut m = age_runs.merge()?;
            while projected > budget {
                let Some(a) = m.next()? else { break };
                projected -= a.len as u64 + 1;
                evicted += 1;
                evict_cutoff = Some((a.ts, a.key));
            }
        }
    }
    report.evicted = evicted as usize;
    report.kept = (survivors - evicted) as usize;

    if opts.dry_run {
        report.bytes_after = projected;
        return Ok(report);
    }

    // ---- phase 3: replay the merge, serialize each survivor once
    let mut written = 0usize;
    if report.kept > 0 {
        let tmp = dir.join("runs.jsonl.tmp");
        let next_generation = read_generation(dir).wrapping_add(1);
        let mut out = BufWriter::new(
            File::create(&tmp).with_context(|| format!("gc: creating {}", tmp.display()))?,
        );
        let mut sidecar = match SidecarWriter::create(&compacted, &manifests, report.kept) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("run-cache: gc could not start the sidecar: {e:#}");
                None
            }
        };
        let mut out_off = 0u64;
        let mut prefix: Vec<u8> = Vec::with_capacity(PREFIX_HASH_SPAN as usize);
        let mut merge = runs.merge()?;
        let mut cur = merge.next()?;
        while let Some(first) = cur.take() {
            let mut winner = first;
            loop {
                match merge.next()? {
                    Some(next) if next.key == winner.key => winner = next,
                    other => {
                        cur = other;
                        break;
                    }
                }
            }
            if !survives(&winner) {
                continue;
            }
            if let Some((cts, ckey)) = &evict_cutoff {
                if (winner.ts, winner.key.as_str()) <= (*cts, ckey.as_str()) {
                    continue;
                }
            }
            let raw =
                read_span(&segments[winner.seg as usize], winner.offset, winner.len as usize)
                    .context("gc: re-reading a planned winner")?;
            // the scan validated this span under the same locks, so
            // utf-8 trouble here means the disk changed under us
            let text = std::str::from_utf8(&raw).context("gc: winner line is no longer utf-8")?;
            match parse_full_entry(text.trim_end_matches('\r')) {
                Ok(e) => {
                    let line = entry_line(&e.key, &e.manifest, e.ts, &e.record);
                    out.write_all(line.as_bytes()).context("gc: writing compacted entry")?;
                    out.write_all(b"\n").context("gc: writing compacted entry")?;
                    if prefix.len() < PREFIX_HASH_SPAN as usize {
                        let room = PREFIX_HASH_SPAN as usize - prefix.len();
                        let n = room.min(line.len());
                        prefix.extend_from_slice(&line.as_bytes()[..n]);
                        if prefix.len() < PREFIX_HASH_SPAN as usize {
                            prefix.push(b'\n');
                        }
                    }
                    if let Some(mut sw) = sidecar.take() {
                        match sw.push(&e.key, out_off, line.len() as u32, e.ts, winner.manifest) {
                            Ok(()) => sidecar = Some(sw),
                            Err(err) => {
                                // dropping the unfinished writer removes
                                // its temp file; the cache stays correct,
                                // just unfiltered
                                eprintln!("run-cache: gc abandoning the sidecar: {err:#}");
                            }
                        }
                    }
                    out_off += line.len() as u64 + 1;
                    written += 1;
                }
                Err(err) => {
                    report.corrupt_dropped += 1;
                    eprintln!(
                        "run-cache: gc dropping key {} (its record does not parse: {err:#})",
                        winner.key
                    );
                }
            }
        }
        out.flush().context("gc: flushing compacted cache")?;
        let _ = out.get_ref().sync_all();
        drop(out);
        report.kept = written;
        if written == 0 {
            let _ = std::fs::remove_file(&tmp);
        } else {
            std::fs::rename(&tmp, &compacted)
                .with_context(|| format!("gc: installing {}", compacted.display()))?;
            for seg in segments.iter().filter(|s| **s != compacted) {
                remove_sidecar(seg);
                std::fs::remove_file(seg)
                    .with_context(|| format!("gc: removing segment {}", seg.display()))?;
            }
            match sidecar {
                Some(sw) => {
                    if let Err(e) = sw.finish(out_off, next_generation, fnv1a64(&prefix)) {
                        eprintln!("run-cache: gc could not install the sidecar: {e:#}");
                        remove_sidecar(&compacted);
                    }
                }
                // never leave a stale sidecar describing the old bytes
                None => remove_sidecar(&compacted),
            }
            report.bytes_after = std::fs::metadata(&compacted).map(|m| m.len()).unwrap_or(0);
        }
    }
    if report.kept == 0 && written == 0 {
        for seg in &segments {
            remove_sidecar(seg);
            std::fs::remove_file(seg)
                .with_context(|| format!("gc: removing segment {}", seg.display()))?;
        }
    }
    // the old byte offsets died with the old files: tell incremental
    // readers before the locks drop (best-effort — a reader that misses
    // the bump still catches the shrunken/vanished segments)
    if let Err(e) = bump_generation(dir) {
        eprintln!("run-cache: gc could not bump the generation marker: {e:#}");
    }
    drop(locks);
    Ok(report)
}

/// Parse a human duration: bare seconds or `<number><s|m|h|d|w>`
/// (e.g. `0s`, `90`, `5m`, `12h`, `30d`).
pub fn parse_duration(s: &str) -> Result<Duration> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad duration {s:?} (expected e.g. 30d, 12h, 0s)"))?;
    let mult = match unit.trim() {
        "" | "s" => 1.0,
        "m" => 60.0,
        "h" => 3600.0,
        "d" => 86400.0,
        "w" => 604800.0,
        u => bail!("bad duration unit {u:?} in {s:?} (use s/m/h/d/w)"),
    };
    // try_from: an absurd `--older-than` must be an error, not a panic
    Duration::try_from_secs_f64(n * mult)
        .map_err(|e| anyhow::anyhow!("duration {s:?} out of range: {e}"))
}

/// Parse a human byte count: bare bytes or `<number><k|m|g>` (binary
/// multiples, case-insensitive — e.g. `65536`, `512k`, `10m`, `1g`).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad byte count {s:?} (expected e.g. 65536, 512k, 10m)"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" | "kib" => 1024.0,
        "m" | "mb" | "mib" => 1024.0 * 1024.0,
        "g" | "gb" | "gib" => 1024.0 * 1024.0 * 1024.0,
        u => bail!("bad byte unit {u:?} in {s:?} (use k/m/g)"),
    };
    let v = n * mult;
    if !v.is_finite() || v < 0.0 || v > u64::MAX as f64 {
        bail!("byte count {s:?} out of range");
    }
    Ok(v as u64)
}

#[cfg(test)]
mod tests {
    use super::super::filter::Sidecar;
    use super::super::segment::{for_each_line, Entry};
    use super::*;
    use crate::train::RunRecord;
    use crate::util::prop::{check, Config};
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("umup-gc-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn rec(label: &str, loss: f64) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            train_curve: vec![(1, loss + 0.5), (2, loss)],
            valid_curve: vec![(2, loss)],
            final_valid_loss: loss,
            rms_curves: BTreeMap::new(),
            final_rms: vec![("w.out".to_string(), 1.0)],
            diverged: false,
            wall_seconds: 0.25,
        }
    }

    /// Everything the old (pre-streaming) gc would have produced: the
    /// exact `runs.jsonl` bytes plus every report counter.  Replicated
    /// here so the streaming pipeline is pinned byte-for-byte against
    /// the eager algorithm it replaced.
    struct EagerOutcome {
        bytes: String,
        scanned: usize,
        kept: usize,
        pruned: usize,
        evicted: usize,
        deduped: usize,
        corrupt_dropped: usize,
        bytes_before: u64,
        projected: u64,
    }

    fn eager_reference(dir: &Path, opts: &GcOptions) -> EagerOutcome {
        let segments = list_segments(dir).unwrap();
        let (mut scanned, mut deduped, mut corrupt) = (0usize, 0usize, 0usize);
        let mut bytes_before = 0u64;
        let mut merged: BTreeMap<String, Entry> = BTreeMap::new();
        for seg in &segments {
            bytes_before += std::fs::metadata(seg).map(|m| m.len()).unwrap_or(0);
            for_each_line(seg, |line| {
                if line.trim().is_empty() {
                    return;
                }
                match parse_full_entry(line) {
                    Ok(e) => {
                        scanned += 1;
                        if merged.insert(e.key.clone(), e).is_some() {
                            deduped += 1;
                        }
                    }
                    Err(_) => corrupt += 1,
                }
            })
            .unwrap();
        }
        let cutoff = opts.older_than.map(|d| now_ts().saturating_sub(d.as_secs()));
        let mut kept: Vec<&Entry> = merged
            .values()
            .filter(|e| {
                if let Some(m) = &opts.manifest {
                    if &e.manifest == m {
                        return false;
                    }
                }
                if let Some(cut) = cutoff {
                    if e.ts <= cut {
                        return false;
                    }
                }
                true
            })
            .collect();
        let pruned = merged.len() - kept.len();
        let mut projected: u64 = kept
            .iter()
            .map(|e| entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1)
            .sum();
        let mut evicted = 0usize;
        if let Some(budget) = opts.max_bytes {
            if projected > budget {
                let mut by_age: Vec<&Entry> = kept.clone();
                by_age.sort_by(|a, b| a.ts.cmp(&b.ts).then_with(|| a.key.cmp(&b.key)));
                let mut evict: std::collections::HashSet<&str> = std::collections::HashSet::new();
                for e in by_age {
                    if projected <= budget {
                        break;
                    }
                    projected -= entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1;
                    evict.insert(e.key.as_str());
                }
                evicted = evict.len();
                kept.retain(|e| !evict.contains(e.key.as_str()));
            }
        }
        let mut bytes = String::new();
        for e in &kept {
            bytes.push_str(&entry_line(&e.key, &e.manifest, e.ts, &e.record));
            bytes.push('\n');
        }
        EagerOutcome {
            bytes,
            scanned,
            kept: kept.len(),
            pruned,
            evicted,
            deduped,
            corrupt_dropped: corrupt,
            bytes_before,
            projected,
        }
    }

    #[test]
    fn streaming_gc_matches_the_eager_reference() {
        check("gc byte equivalence", Config { cases: 20, seed: 0x6c_5eed }, |g| {
            let dir = tmp_dir(&format!("equiv-{}", g.case));
            let seg_names = ["runs.jsonl", "runs.0.jsonl", "runs.1.jsonl", "runs.2.jsonl"];
            let n_segs = g.usize_in(1, 4);
            for name in seg_names.iter().take(n_segs) {
                let mut content = String::new();
                for _ in 0..g.usize_in(0, 12) {
                    match g.usize_in(0, 9) {
                        0 => content.push('\n'),
                        1 => content.push_str("{ not json\n"),
                        _ => {
                            let key = format!("{:016x}", 0xabc0 + g.usize_in(0, 7));
                            let m = if g.usize_in(0, 1) == 0 { "m0" } else { "m1" };
                            let ts = 100 + g.usize_in(0, 20) as u64;
                            let r = rec(&format!("case{}", g.case), 2.0 + ts as f64 / 64.0);
                            content.push_str(&entry_line(&key, m, ts, &r));
                            content.push('\n');
                        }
                    }
                }
                if g.usize_in(0, 4) == 0 {
                    // torn tail: a killed writer's fragment, no newline
                    content.push_str("{\"key\":\"torn");
                }
                std::fs::write(dir.join(name), &content).unwrap();
            }
            let total: u64 = list_segments(&dir)
                .unwrap()
                .iter()
                .map(|s| std::fs::metadata(s).map(|m| m.len()).unwrap_or(0))
                .sum();
            let mut opts =
                GcOptions { chunk_entries: Some(g.usize_in(1, 5)), ..GcOptions::default() };
            if g.usize_in(0, 3) == 0 {
                opts.manifest = Some("m0".to_string());
            }
            if g.usize_in(0, 4) == 0 {
                // ZERO is the only deterministic age filter (prune-all:
                // every test ts is far below "now" regardless of clock)
                opts.older_than = Some(Duration::ZERO);
            }
            if g.usize_in(0, 2) == 0 {
                opts.max_bytes = Some(g.usize_in(0, total as usize) as u64);
            }
            let expected = eager_reference(&dir, &opts);

            let before: Vec<(PathBuf, u64)> = list_segments(&dir)
                .unwrap()
                .into_iter()
                .map(|s| {
                    let len = std::fs::metadata(&s).map(|m| m.len()).unwrap_or(0);
                    (s, len)
                })
                .collect();
            let dry = gc(&dir, &GcOptions { dry_run: true, ..opts.clone() }).unwrap();
            assert_eq!(
                (dry.scanned, dry.kept, dry.pruned, dry.evicted, dry.deduped, dry.corrupt_dropped),
                (
                    expected.scanned,
                    expected.kept,
                    expected.pruned,
                    expected.evicted,
                    expected.deduped,
                    expected.corrupt_dropped
                ),
                "dry-run report diverged (case {})",
                g.case
            );
            assert_eq!(dry.bytes_after, expected.projected);
            let after: Vec<(PathBuf, u64)> = list_segments(&dir)
                .unwrap()
                .into_iter()
                .map(|s| {
                    let len = std::fs::metadata(&s).map(|m| m.len()).unwrap_or(0);
                    (s, len)
                })
                .collect();
            assert_eq!(before, after, "dry run must not touch any file");

            let real = gc(&dir, &opts).unwrap();
            assert_eq!(
                (
                    real.scanned,
                    real.kept,
                    real.pruned,
                    real.evicted,
                    real.deduped,
                    real.corrupt_dropped
                ),
                (
                    expected.scanned,
                    expected.kept,
                    expected.pruned,
                    expected.evicted,
                    expected.deduped,
                    expected.corrupt_dropped
                ),
                "real-run report diverged (case {})",
                g.case
            );
            assert_eq!(real.bytes_before, expected.bytes_before);
            if expected.kept == 0 {
                assert!(list_segments(&dir).unwrap().is_empty());
            } else {
                let compacted = dir.join("runs.jsonl");
                let got = std::fs::read_to_string(&compacted).unwrap();
                assert_eq!(got, expected.bytes, "compacted bytes diverged (case {})", g.case);
                assert_eq!(list_segments(&dir).unwrap(), vec![compacted.clone()]);
                assert_eq!(real.bytes_after, expected.bytes.len() as u64);
                let sc = Sidecar::open(&compacted).unwrap().expect("gc must leave a sidecar");
                assert!(sc.validate(&compacted));
                assert_eq!(sc.n_entries() as usize, expected.kept);
            }
            let _ = std::fs::remove_dir_all(&dir);
        });
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn unreadable_segment_aborts_gc_without_touching_files() {
        let dir = tmp_dir("abort");
        let line = entry_line("00000000000000aa", "m", 100, &rec("keep", 2.0));
        std::fs::write(dir.join("runs.jsonl"), format!("{line}\n")).unwrap();
        // stat says regular file; reading it returns EIO (offset 0 of
        // our own address space is unmapped) — a portable-enough stand-in
        // for a segment on failing media
        std::os::unix::fs::symlink("/proc/self/mem", dir.join("runs.0.jsonl")).unwrap();
        assert!(gc(&dir, &GcOptions::default()).is_err(), "gc must abort, not drop entries");
        assert_eq!(
            std::fs::read_to_string(dir.join("runs.jsonl")).unwrap(),
            format!("{line}\n"),
            "the readable segment must be untouched"
        );
        assert!(dir.join("runs.0.jsonl").exists(), "the unreadable segment must survive");
        assert!(!dir.join("runs.jsonl.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wrong_shape_winner_is_dropped_at_write_time() {
        let dir = tmp_dir("shape");
        let key = "00000000000000ab";
        let good = entry_line(key, "m", 100, &rec("good", 2.0));
        std::fs::write(dir.join("runs.0.jsonl"), format!("{good}\n")).unwrap();
        // runs.jsonl sorts after runs.0.jsonl, so this structurally
        // valid (but not-a-RunRecord) line wins the merge
        std::fs::write(
            dir.join("runs.jsonl"),
            format!("{{\"key\":\"{key}\",\"manifest\":\"m\",\"record\":{{\"not\":\"a record\"}}}}\n"),
        )
        .unwrap();
        let dry = gc(&dir, &GcOptions { dry_run: true, ..GcOptions::default() }).unwrap();
        // the plan (key scanner) counts it as a keeper...
        assert_eq!((dry.scanned, dry.deduped, dry.kept, dry.corrupt_dropped), (2, 1, 1, 0));
        let real = gc(&dir, &GcOptions::default()).unwrap();
        // ...the write pass pushes it through the full parser and drops it
        assert_eq!((real.scanned, real.deduped, real.kept, real.corrupt_dropped), (2, 1, 0, 1));
        assert!(list_segments(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
