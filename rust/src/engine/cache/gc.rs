//! Run-cache lifecycle: pruning, size-targeted eviction, and
//! compaction — the only code that *rewrites* segments.
//!
//! GC is deliberately the eager, O(total-bytes) path: it must
//! re-serialize every surviving line anyway, so it materializes records
//! through the reference codec.  What it owes the lazy readers
//! ([`super::index`]) is the **generation contract**: any non-dry-run
//! rewrite bumps the directory's generation marker (under every
//! segment's writer lock), so incremental readers discover that their
//! remembered byte offsets died with the old files and fall back to one
//! full rescan.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::segment::{
    bump_generation, entry_line, for_each_line, list_segments, now_ts, parse_full_entry, Entry,
    SegmentLock,
};

/// Opening a cache dir with `resume` auto-compacts it first when it
/// holds more than this many segments (see [`super::RunCache::open_sharded`]).
pub const AUTO_COMPACT_SEGMENT_THRESHOLD: usize = 8;

/// What [`gc`] should prune.  With no filters set, GC is a pure
/// compaction: segments merge into one key-sorted `runs.jsonl`, dropping
/// cross-segment duplicates and corrupt lines.
#[derive(Debug, Clone, Default)]
pub struct GcOptions {
    /// Prune entries whose `ts` is at least this old (entries without a
    /// `ts` — pre-lifecycle lines — count as arbitrarily old).
    pub older_than: Option<Duration>,
    /// Prune entries recorded under this manifest name.
    pub manifest: Option<String>,
    /// Size budget for the compacted cache: after the filters above,
    /// evict oldest-`ts` entries (ties broken by key, for determinism)
    /// until the surviving lines fit in this many bytes.
    pub max_bytes: Option<u64>,
    /// Report what would happen without touching any file.
    pub dry_run: bool,
}

/// What [`gc`] did (or, under `dry_run`, would do).
#[derive(Debug, Clone, Default)]
pub struct GcReport {
    /// Parseable lines seen across all segments.
    pub scanned: usize,
    pub kept: usize,
    /// Entries dropped by the age / manifest filters.
    pub pruned: usize,
    /// Entries evicted (oldest first) to meet the `max_bytes` budget.
    pub evicted: usize,
    /// Cross-segment duplicate lines collapsed by compaction.
    pub deduped: usize,
    pub corrupt_dropped: usize,
    pub segments_before: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// Prune and compact a cache directory.
///
/// Takes every segment's writer lock first (erroring if any segment has
/// a live writer), merges all segments (last write per key wins),
/// applies the [`GcOptions`] filters, and — unless `dry_run` — rewrites
/// the survivors as a single key-sorted `runs.jsonl` (via a temp file +
/// rename), deletes the shard segments, and bumps the directory's
/// compaction generation so incremental readers rescan.  An emptied
/// cache ends up with no segment files at all.
pub fn gc(dir: &Path, opts: &GcOptions) -> Result<GcReport> {
    let segments = list_segments(dir)?;
    let mut report = GcReport { segments_before: segments.len(), ..GcReport::default() };
    if segments.is_empty() {
        return Ok(report);
    }
    let compacted = dir.join("runs.jsonl");
    // lock every segment plus the compaction target so no live writer
    // (or competing gc) can race the rewrite
    let mut locks = Vec::new();
    for seg in segments.iter().chain(
        (!segments.contains(&compacted)).then_some(&compacted),
    ) {
        locks.push(
            SegmentLock::acquire(seg)
                .with_context(|| format!("gc: locking segment {}", seg.display()))?,
        );
    }

    // merge: insertion order = sorted segment order, so later segments
    // win for duplicated keys (mirrors the resume reader)
    let mut merged: BTreeMap<String, Entry> = BTreeMap::new();
    for seg in &segments {
        report.bytes_before += std::fs::metadata(seg).map(|m| m.len()).unwrap_or(0);
        let res = for_each_line(seg, |line| {
            if line.trim().is_empty() {
                return;
            }
            match parse_full_entry(line) {
                Ok(e) => {
                    report.scanned += 1;
                    if merged.insert(e.key.clone(), e).is_some() {
                        report.deduped += 1;
                    }
                }
                Err(_) => report.corrupt_dropped += 1,
            }
        });
        if let Err(e) = res {
            eprintln!("run-cache: gc could not read {}: {e:#}", seg.display());
        }
    }

    // filter
    let cutoff = opts.older_than.map(|d| now_ts().saturating_sub(d.as_secs()));
    let mut kept: Vec<&Entry> = merged
        .values()
        .filter(|e| {
            if let Some(m) = &opts.manifest {
                if &e.manifest == m {
                    return false;
                }
            }
            if let Some(cut) = cutoff {
                if e.ts <= cut {
                    return false;
                }
            }
            true
        })
        .collect();
    report.pruned = merged.len() - kept.len();

    // size budget: evict oldest-ts entries (key tiebreak, so repeated
    // gc over the same data is deterministic) until the projected
    // compacted file fits
    let mut projected: u64 = kept
        .iter()
        .map(|e| entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1)
        .sum();
    if let Some(budget) = opts.max_bytes {
        if projected > budget {
            let mut by_age: Vec<&Entry> = kept.clone();
            by_age.sort_by(|a, b| a.ts.cmp(&b.ts).then_with(|| a.key.cmp(&b.key)));
            let mut evict: std::collections::HashSet<&str> = std::collections::HashSet::new();
            for e in by_age {
                if projected <= budget {
                    break;
                }
                projected -= entry_line(&e.key, &e.manifest, e.ts, &e.record).len() as u64 + 1;
                evict.insert(e.key.as_str());
            }
            report.evicted = evict.len();
            kept.retain(|e| !evict.contains(e.key.as_str()));
        }
    }
    report.kept = kept.len();

    if opts.dry_run {
        report.bytes_after = projected;
        return Ok(report);
    }

    // rewrite: survivors into runs.jsonl (atomically), then drop the
    // shard segments
    if kept.is_empty() {
        for seg in &segments {
            std::fs::remove_file(seg)
                .with_context(|| format!("gc: removing segment {}", seg.display()))?;
        }
    } else {
        let tmp = dir.join("runs.jsonl.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("gc: creating {}", tmp.display()))?;
            for e in &kept {
                writeln!(f, "{}", entry_line(&e.key, &e.manifest, e.ts, &e.record))
                    .context("gc: writing compacted entry")?;
            }
            f.flush().context("gc: flushing compacted cache")?;
        }
        std::fs::rename(&tmp, &compacted)
            .with_context(|| format!("gc: installing {}", compacted.display()))?;
        for seg in segments.iter().filter(|s| **s != compacted) {
            std::fs::remove_file(seg)
                .with_context(|| format!("gc: removing segment {}", seg.display()))?;
        }
        report.bytes_after = std::fs::metadata(&compacted).map(|m| m.len()).unwrap_or(0);
    }
    // the old byte offsets died with the old files: tell incremental
    // readers before the locks drop (best-effort — a reader that misses
    // the bump still catches the shrunken/vanished segments)
    if let Err(e) = bump_generation(dir) {
        eprintln!("run-cache: gc could not bump the generation marker: {e:#}");
    }
    drop(locks);
    Ok(report)
}

/// Parse a human duration: bare seconds or `<number><s|m|h|d|w>`
/// (e.g. `0s`, `90`, `5m`, `12h`, `30d`).
pub fn parse_duration(s: &str) -> Result<Duration> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad duration {s:?} (expected e.g. 30d, 12h, 0s)"))?;
    let mult = match unit.trim() {
        "" | "s" => 1.0,
        "m" => 60.0,
        "h" => 3600.0,
        "d" => 86400.0,
        "w" => 604800.0,
        u => bail!("bad duration unit {u:?} in {s:?} (use s/m/h/d/w)"),
    };
    // try_from: an absurd `--older-than` must be an error, not a panic
    Duration::try_from_secs_f64(n * mult)
        .map_err(|e| anyhow::anyhow!("duration {s:?} out of range: {e}"))
}

/// Parse a human byte count: bare bytes or `<number><k|m|g>` (binary
/// multiples, case-insensitive — e.g. `65536`, `512k`, `10m`, `1g`).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit() && c != '.')
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: f64 = num
        .parse()
        .with_context(|| format!("bad byte count {s:?} (expected e.g. 65536, 512k, 10m)"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" | "kib" => 1024.0,
        "m" | "mb" | "mib" => 1024.0 * 1024.0,
        "g" | "gb" | "gib" => 1024.0 * 1024.0 * 1024.0,
        u => bail!("bad byte unit {u:?} in {s:?} (use k/m/g)"),
    };
    let v = n * mult;
    if !v.is_finite() || v < 0.0 || v > u64::MAX as f64 {
        bail!("byte count {s:?} out of range");
    }
    Ok(v as u64)
}
