//! Per-segment key-presence sidecars (`<segment>.idx`): a bloom filter
//! plus sorted fence pointers over a segment's per-key winners, written
//! by compaction so miss-heavy opens and [`super::CacheWatcher`] polls
//! can answer "not in this segment" without scanning it.
//!
//! # On-disk format (all integers little-endian)
//!
//! ```text
//! magic            8B   "UMUPSCX1"
//! manifest table   u32 count, then per name: u32 len + utf-8 bytes
//! entries          per entry (key-sorted):
//!                    u16 key len + key bytes
//!                    u64 offset   (line start within the segment)
//!                    u32 len      (line length, no trailing newline)
//!                    u64 ts
//!                    u32 manifest (index into the manifest table)
//! fences           every 64th entry: u16 key len + key bytes +
//!                    u64 rel      (entry's byte offset within `entries`)
//! bloom            u64 × bloom_words
//! trailer (88B)    u64 n_entries, entries_off, entries_len, n_fences,
//!                    fences_off, bloom_off, bloom_words, covered_bytes,
//!                    generation, prefix_hash; magic 8B "UMUPSCXT"
//! ```
//!
//! [`Sidecar::open`] reads the trailer, manifest table, fences, and
//! bloom — never the entries section, whose size is O(keys).  A point
//! [`Sidecar::lookup`] re-opens the file and scans at most one fence gap
//! (≤ 64 entries, ~one page).
//!
//! # Validity
//!
//! A sidecar describes the first `covered_bytes` of its segment at
//! write time.  [`Sidecar::validate`] checks *structurally* — the
//! segment must still be at least `covered_bytes` long and the first
//! `min(4096, covered_bytes)` bytes must hash to `prefix_hash` — so
//! appends after the covered prefix keep the sidecar valid (newer
//! same-key appends are resolved by the reader: in-map entries outrank
//! the sidecar at equal segment rank).  The stored `generation` is
//! diagnostic only: tiered merges bump the *directory* generation
//! without touching other segments, so generation equality must not
//! gate validity.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::hash::fnv1a64;

use super::segment::sidecar_path;

const MAGIC_HEAD: &[u8; 8] = b"UMUPSCX1";
const MAGIC_TAIL: &[u8; 8] = b"UMUPSCXT";
const TRAILER_LEN: u64 = 88;
/// One fence pointer per this many entries: a point lookup scans at
/// most one gap (64 entries ≈ 4 KiB of entry records — about a page).
const FENCE_EVERY: u64 = 64;
/// Bytes of segment prefix folded into `prefix_hash` by the validity
/// check.
pub(crate) const PREFIX_HASH_SPAN: u64 = 4096;
const BLOOM_HASHES: u64 = 6;
const BLOOM_BITS_PER_KEY: u64 = 10;

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn bloom_probes(key: &str) -> (u64, u64) {
    let h1 = fnv1a64(key.as_bytes());
    (h1, splitmix64(h1) | 1)
}

/// Hash of the first `min(PREFIX_HASH_SPAN, covered)` bytes of a
/// segment — the anchor [`Sidecar::validate`] compares against.
pub(crate) fn segment_prefix_hash(path: &Path, covered: u64) -> Result<u64> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut buf = vec![0u8; PREFIX_HASH_SPAN.min(covered) as usize];
    f.read_exact(&mut buf)
        .with_context(|| format!("reading prefix of {}", path.display()))?;
    Ok(fnv1a64(&buf))
}

/// Delete a segment's sidecar (idempotent) — called when the segment is
/// removed, truncated, or rewritten outside compaction.
pub(crate) fn remove_sidecar(segment: &Path) {
    let _ = std::fs::remove_file(sidecar_path(segment));
}

// --------------------------------------------------------------- writer

/// Streams a sidecar to `<segment>.idx.tmp`, renamed into place by
/// [`SidecarWriter::finish`]; dropping an unfinished writer removes the
/// temp file.  Keys must be pushed in sorted order (compaction output
/// order) — enforced, since fences and lookups depend on it.
pub(crate) struct SidecarWriter {
    tmp: PathBuf,
    dst: PathBuf,
    w: BufWriter<File>,
    bloom: Vec<u64>,
    fences: Vec<(String, u64)>,
    manifest_table_len: u64,
    n_entries: u64,
    entries_written: u64,
    last_key: String,
    finished: bool,
}

impl SidecarWriter {
    /// `expected_keys` sizes the bloom filter (~10 bits/key, k=6 —
    /// ≈1% false positives at the design point); overshooting is
    /// harmless, undershooting just raises the FP rate.
    pub(crate) fn create(
        segment: &Path,
        manifests: &[String],
        expected_keys: usize,
    ) -> Result<SidecarWriter> {
        let dst = sidecar_path(segment);
        let mut tmp_name = dst.file_name().unwrap_or_default().to_os_string();
        tmp_name.push(".tmp");
        let tmp = dst.with_file_name(tmp_name);
        let mut w = BufWriter::new(
            File::create(&tmp).with_context(|| format!("creating sidecar {}", tmp.display()))?,
        );
        w.write_all(MAGIC_HEAD).context("writing sidecar magic")?;
        let mut table_len = 4u64;
        w.write_all(&(manifests.len() as u32).to_le_bytes())?;
        for m in manifests {
            w.write_all(&(m.len() as u32).to_le_bytes())?;
            w.write_all(m.as_bytes())?;
            table_len += 4 + m.len() as u64;
        }
        let bits = (expected_keys as u64 * BLOOM_BITS_PER_KEY).max(64).div_ceil(64) * 64;
        Ok(SidecarWriter {
            tmp,
            dst,
            w,
            bloom: vec![0u64; (bits / 64) as usize],
            fences: Vec::new(),
            manifest_table_len: table_len,
            n_entries: 0,
            entries_written: 0,
            last_key: String::new(),
            finished: false,
        })
    }

    pub(crate) fn push(
        &mut self,
        key: &str,
        offset: u64,
        len: u32,
        ts: u64,
        manifest: u32,
    ) -> Result<()> {
        if key.len() > u16::MAX as usize {
            bail!("sidecar key too long ({} bytes)", key.len());
        }
        if self.n_entries > 0 && key <= self.last_key.as_str() {
            bail!("sidecar keys pushed out of order ({key:?} after {:?})", self.last_key);
        }
        if self.n_entries % FENCE_EVERY == 0 {
            self.fences.push((key.to_string(), self.entries_written));
        }
        self.w.write_all(&(key.len() as u16).to_le_bytes())?;
        self.w.write_all(key.as_bytes())?;
        self.w.write_all(&offset.to_le_bytes())?;
        self.w.write_all(&len.to_le_bytes())?;
        self.w.write_all(&ts.to_le_bytes())?;
        self.w.write_all(&manifest.to_le_bytes())?;
        let (h1, h2) = bloom_probes(key);
        let bits = self.bloom.len() as u64 * 64;
        for i in 0..BLOOM_HASHES {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) % bits;
            self.bloom[(bit / 64) as usize] |= 1u64 << (bit % 64);
        }
        self.n_entries += 1;
        self.entries_written += 2 + key.len() as u64 + 8 + 4 + 8 + 4;
        self.last_key.clear();
        self.last_key.push_str(key);
        Ok(())
    }

    /// Seal: write fences, bloom, and the trailer, then rename the
    /// sidecar into place.  `covered_bytes` is the segment length the
    /// entries describe; `prefix_hash` anchors [`Sidecar::validate`].
    pub(crate) fn finish(
        mut self,
        covered_bytes: u64,
        generation: u64,
        prefix_hash: u64,
    ) -> Result<()> {
        let entries_off = 8 + self.manifest_table_len;
        let fences_off = entries_off + self.entries_written;
        let mut fences_len = 0u64;
        for (key, rel) in &self.fences {
            self.w.write_all(&(key.len() as u16).to_le_bytes())?;
            self.w.write_all(key.as_bytes())?;
            self.w.write_all(&rel.to_le_bytes())?;
            fences_len += 2 + key.len() as u64 + 8;
        }
        let bloom_off = fences_off + fences_len;
        for word in &self.bloom {
            self.w.write_all(&word.to_le_bytes())?;
        }
        for v in [
            self.n_entries,
            entries_off,
            self.entries_written,
            self.fences.len() as u64,
            fences_off,
            bloom_off,
            self.bloom.len() as u64,
            covered_bytes,
            generation,
            prefix_hash,
        ] {
            self.w.write_all(&v.to_le_bytes())?;
        }
        self.w.write_all(MAGIC_TAIL)?;
        self.w.flush().context("flushing sidecar")?;
        self.w.get_ref().sync_all().context("syncing sidecar")?;
        std::fs::rename(&self.tmp, &self.dst)
            .with_context(|| format!("installing sidecar {}", self.dst.display()))?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for SidecarWriter {
    fn drop(&mut self) {
        if !self.finished {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

// --------------------------------------------------------------- reader

fn get_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b).context("truncated sidecar")?;
    Ok(u16::from_le_bytes(b))
}

fn get_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated sidecar")?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("truncated sidecar")?;
    Ok(u64::from_le_bytes(b))
}

fn get_str(r: &mut impl Read, len: usize) -> Result<String> {
    let mut b = vec![0u8; len];
    r.read_exact(&mut b).context("truncated sidecar")?;
    String::from_utf8(b).context("non-utf8 sidecar string")
}

/// An opened sidecar: trailer + manifest table + fences + bloom resident
/// (O(keys / 64)), entries left on disk.
pub(crate) struct Sidecar {
    path: PathBuf,
    n_entries: u64,
    entries_off: u64,
    entries_len: u64,
    covered_bytes: u64,
    generation: u64,
    prefix_hash: u64,
    manifests: Vec<String>,
    fences: Vec<(String, u64)>,
    bloom: Vec<u64>,
}

impl Sidecar {
    /// Open `<segment>.idx`.  `Ok(None)` when no sidecar exists; a
    /// malformed one is an error (callers treat it as absent and
    /// usually delete it).
    pub(crate) fn open(segment: &Path) -> Result<Option<Sidecar>> {
        let path = sidecar_path(segment);
        let mut f = match File::open(&path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).with_context(|| format!("opening {}", path.display())),
        };
        let file_len = f.metadata().context("sidecar metadata")?.len();
        if file_len < 8 + TRAILER_LEN {
            bail!("sidecar {} too short ({file_len} bytes)", path.display());
        }
        f.seek(SeekFrom::End(-(TRAILER_LEN as i64))).context("seeking sidecar trailer")?;
        let mut trailer = [0u8; TRAILER_LEN as usize];
        f.read_exact(&mut trailer).context("reading sidecar trailer")?;
        if &trailer[80..88] != MAGIC_TAIL {
            bail!("sidecar {} has a bad trailer magic", path.display());
        }
        let word = |i: usize| u64::from_le_bytes(trailer[i * 8..i * 8 + 8].try_into().unwrap());
        let (n_entries, entries_off, entries_len) = (word(0), word(1), word(2));
        let (n_fences, fences_off, bloom_off, bloom_words) = (word(3), word(4), word(5), word(6));
        let (covered_bytes, generation, prefix_hash) = (word(7), word(8), word(9));
        let trailer_off = file_len - TRAILER_LEN;
        if entries_off + entries_len != fences_off
            || fences_off > bloom_off
            || bloom_off + bloom_words * 8 != trailer_off
            || bloom_words == 0
        {
            bail!("sidecar {} has inconsistent section offsets", path.display());
        }
        f.seek(SeekFrom::Start(0)).context("seeking sidecar head")?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("reading sidecar magic")?;
        if &magic != MAGIC_HEAD {
            bail!("sidecar {} has a bad header magic", path.display());
        }
        let n_manifests = get_u32(&mut r)?;
        if n_manifests as u64 > entries_off {
            bail!("sidecar {} manifest table overruns", path.display());
        }
        let mut manifests = Vec::with_capacity(n_manifests as usize);
        for _ in 0..n_manifests {
            let len = get_u32(&mut r)? as usize;
            manifests.push(get_str(&mut r, len)?);
        }
        let mut r = r.into_inner();
        r.seek(SeekFrom::Start(fences_off)).context("seeking sidecar fences")?;
        let mut r = BufReader::new(r);
        if n_fences > n_entries {
            bail!("sidecar {} has more fences than entries", path.display());
        }
        let mut fences = Vec::with_capacity(n_fences as usize);
        for _ in 0..n_fences {
            let klen = get_u16(&mut r)? as usize;
            let key = get_str(&mut r, klen)?;
            fences.push((key, get_u64(&mut r)?));
        }
        let mut r = r.into_inner();
        r.seek(SeekFrom::Start(bloom_off)).context("seeking sidecar bloom")?;
        let mut r = BufReader::new(r);
        let mut bloom = Vec::with_capacity(bloom_words as usize);
        for _ in 0..bloom_words {
            bloom.push(get_u64(&mut r)?);
        }
        Ok(Some(Sidecar {
            path,
            n_entries,
            entries_off,
            entries_len,
            covered_bytes,
            generation,
            prefix_hash,
            manifests,
            fences,
            bloom,
        }))
    }

    pub(crate) fn n_entries(&self) -> u64 {
        self.n_entries
    }

    pub(crate) fn covered_bytes(&self) -> u64 {
        self.covered_bytes
    }

    #[allow(dead_code)] // diagnostic field, surfaced by `cache stats`-style tooling
    pub(crate) fn generation(&self) -> u64 {
        self.generation
    }

    pub(crate) fn manifest(&self, id: u32) -> Option<&str> {
        self.manifests.get(id as usize).map(String::as_str)
    }

    /// Structural validity against the segment as it is *now*: the
    /// covered prefix must still exist and hash to what it hashed at
    /// write time.  Appends beyond the prefix keep a sidecar valid;
    /// truncation or rewrite-in-place invalidates it.
    pub(crate) fn validate(&self, segment: &Path) -> bool {
        let Ok(meta) = std::fs::metadata(segment) else { return false };
        if meta.len() < self.covered_bytes {
            return false;
        }
        matches!(segment_prefix_hash(segment, self.covered_bytes), Ok(h) if h == self.prefix_hash)
    }

    /// Bloom membership: `false` means definitely absent from the
    /// covered prefix; `true` means "probably present" (~1% FP at the
    /// design load).
    pub(crate) fn might_contain(&self, key: &str) -> bool {
        let (h1, h2) = bloom_probes(key);
        let bits = self.bloom.len() as u64 * 64;
        (0..BLOOM_HASHES).all(|i| {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) % bits;
            self.bloom[(bit / 64) as usize] & (1u64 << (bit % 64)) != 0
        })
    }

    /// Exact point lookup: bloom, then one fence gap of entries
    /// (≤ [`FENCE_EVERY`]) read straight off disk.  Returns
    /// `(offset, len, ts, manifest-id)` for the key's winner within the
    /// covered prefix.  I/O or format trouble degrades to a miss with a
    /// warning — the caller falls back to scanning the segment.
    pub(crate) fn lookup(&self, key: &str) -> Option<(u64, u32, u64, u32)> {
        if !self.might_contain(key) {
            return None;
        }
        match self.lookup_inner(key) {
            Ok(hit) => hit,
            Err(e) => {
                eprintln!("run-cache: sidecar probe failed on {}: {e:#}", self.path.display());
                None
            }
        }
    }

    fn lookup_inner(&self, key: &str) -> Result<Option<(u64, u32, u64, u32)>> {
        let idx = self.fences.partition_point(|(k, _)| k.as_str() <= key);
        if idx == 0 {
            return Ok(None); // key sorts before the first entry
        }
        let start = self.fences[idx - 1].1;
        let end = self.fences.get(idx).map_or(self.entries_len, |(_, rel)| *rel);
        let mut f =
            File::open(&self.path).with_context(|| format!("opening {}", self.path.display()))?;
        f.seek(SeekFrom::Start(self.entries_off + start)).context("seeking sidecar entries")?;
        let mut r = BufReader::new(f.take(end - start));
        let mut consumed = 0;
        while consumed < end - start {
            let klen = get_u16(&mut r)? as usize;
            let ekey = get_str(&mut r, klen)?;
            let offset = get_u64(&mut r)?;
            let len = get_u32(&mut r)?;
            let ts = get_u64(&mut r)?;
            let manifest = get_u32(&mut r)?;
            match ekey.as_str().cmp(key) {
                std::cmp::Ordering::Equal => return Ok(Some((offset, len, ts, manifest))),
                std::cmp::Ordering::Greater => return Ok(None), // sorted: passed it
                std::cmp::Ordering::Less => {}
            }
            consumed += 2 + klen as u64 + 8 + 4 + 8 + 4;
        }
        Ok(None)
    }

    /// Stream every entry (sorted order) — used when the index adopts a
    /// sidecar and needs to reconcile its key set against entries it
    /// already holds.
    pub(crate) fn for_each_entry(
        &self,
        mut f: impl FnMut(&str, u64, u32, u64, u32),
    ) -> Result<()> {
        let file =
            File::open(&self.path).with_context(|| format!("opening {}", self.path.display()))?;
        let mut file = file;
        file.seek(SeekFrom::Start(self.entries_off)).context("seeking sidecar entries")?;
        let mut r = BufReader::new(file.take(self.entries_len));
        for _ in 0..self.n_entries {
            let klen = get_u16(&mut r)? as usize;
            let key = get_str(&mut r, klen)?;
            let offset = get_u64(&mut r)?;
            let len = get_u32(&mut r)?;
            let ts = get_u64(&mut r)?;
            let manifest = get_u32(&mut r)?;
            f(&key, offset, len, ts, manifest);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("umup-sidecar-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn key(i: u64) -> String {
        format!("{i:016x}")
    }

    #[test]
    fn roundtrip_across_multiple_fence_gaps() {
        let dir = tmp_dir("roundtrip");
        let seg = dir.join("runs.jsonl");
        std::fs::write(&seg, b"line one\nline two\n").unwrap();
        let manifests = vec!["m.json".to_string(), "other.json".to_string()];
        let mut w = SidecarWriter::create(&seg, &manifests, 300).unwrap();
        for i in 0..300u64 {
            // only even keys present, so odd keys probe real absences
            w.push(&key(2 * i), i * 10, 100 + i as u32, 5000 + i, (i % 2) as u32).unwrap();
        }
        let hash = segment_prefix_hash(&seg, 18).unwrap();
        w.finish(18, 7, hash).unwrap();

        let sc = Sidecar::open(&seg).unwrap().expect("sidecar should exist");
        assert_eq!(sc.n_entries(), 300);
        assert_eq!(sc.covered_bytes(), 18);
        assert_eq!(sc.generation(), 7);
        assert_eq!(sc.manifest(1), Some("other.json"));
        assert!(sc.validate(&seg));
        for i in [0u64, 1, 63, 64, 65, 150, 298, 299] {
            let (off, len, ts, m) = sc.lookup(&key(2 * i)).expect("present key");
            assert_eq!((off, len, ts, m), (i * 10, 100 + i as u32, 5000 + i, (i % 2) as u32));
        }
        for i in [0u64, 64, 150, 299] {
            assert!(sc.lookup(&key(2 * i + 1)).is_none(), "odd key {i} must miss");
        }
        // below the first entry and above the last
        assert!(sc.lookup("0000000000000000").is_none() || key(0) == "0000000000000000");
        assert!(sc.lookup("ffffffffffffffff").is_none());
        let mut streamed = 0;
        sc.for_each_entry(|k, off, _, _, _| {
            assert_eq!(k, key(streamed * 2));
            assert_eq!(off, streamed * 10);
            streamed += 1;
        })
        .unwrap();
        assert_eq!(streamed, 300);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bloom_rejects_most_absent_keys() {
        let dir = tmp_dir("fpr");
        let seg = dir.join("runs.jsonl");
        std::fs::write(&seg, b"x\n").unwrap();
        let mut w = SidecarWriter::create(&seg, &[], 10_000).unwrap();
        for i in 0..10_000u64 {
            w.push(&key(i), 0, 1, 0, 0).unwrap();
        }
        w.finish(2, 0, segment_prefix_hash(&seg, 2).unwrap()).unwrap();
        let sc = Sidecar::open(&seg).unwrap().unwrap();
        // all present keys pass
        assert!((0..10_000u64).all(|i| sc.might_contain(&key(i))));
        // absent keys: ~1% FP design point; require ≥90% rejected
        let rejected = (10_000..20_000u64).filter(|i| !sc.might_contain(&key(*i))).count();
        assert!(rejected >= 9_000, "bloom rejected only {rejected}/10000 absent keys");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validation_tracks_the_segment_prefix() {
        let dir = tmp_dir("validate");
        let seg = dir.join("runs.jsonl");
        let body = b"abcdefghij\n".to_vec();
        std::fs::write(&seg, &body).unwrap();
        let mut w = SidecarWriter::create(&seg, &[], 4).unwrap();
        w.push("k1", 0, 10, 1, 0).unwrap();
        let covered = body.len() as u64;
        w.finish(covered, 1, segment_prefix_hash(&seg, covered).unwrap()).unwrap();
        let sc = Sidecar::open(&seg).unwrap().unwrap();
        assert!(sc.validate(&seg));

        // appending keeps it valid (prefix untouched)
        let mut appended = body.clone();
        appended.extend_from_slice(b"more\n");
        std::fs::write(&seg, &appended).unwrap();
        assert!(sc.validate(&seg));

        // rewriting the prefix invalidates
        std::fs::write(&seg, b"XXcdefghij\nmore\n").unwrap();
        assert!(!sc.validate(&seg));

        // truncation below covered_bytes invalidates
        std::fs::write(&seg, b"abc").unwrap();
        assert!(!sc.validate(&seg));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn out_of_order_push_is_rejected_and_tmp_cleaned_up() {
        let dir = tmp_dir("order");
        let seg = dir.join("runs.jsonl");
        std::fs::write(&seg, b"x\n").unwrap();
        {
            let mut w = SidecarWriter::create(&seg, &[], 4).unwrap();
            w.push("bb", 0, 1, 0, 0).unwrap();
            assert!(w.push("aa", 0, 1, 0, 0).is_err());
            // dropped unfinished
        }
        assert!(!sidecar_path(&seg).exists());
        assert!(!dir.join("runs.jsonl.idx.tmp").exists());
        assert!(Sidecar::open(&seg).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
