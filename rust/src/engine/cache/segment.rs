//! Segment-level plumbing for the run cache: file naming, advisory
//! writer locks, the JSONL entry codec, byte-oriented (lossy) line
//! reading, and the compaction *generation* marker.
//!
//! A cache directory holds one or more JSONL segments (`runs.jsonl`,
//! `runs.<k>.jsonl`) plus two kinds of sidecar files that are *not*
//! segments: `<segment>.lock` (advisory writer locks, holder pid) and
//! [`GENERATION_FILE`] (a counter that [`super::gc`] bumps after every
//! compacting rewrite, so incremental readers know their remembered
//! byte offsets are stale — see [`super::index`]).

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, ErrorKind, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::train::RunRecord;
use crate::util::Json;

use super::Shard;

// ------------------------------------------------------------- segments

/// The segment file this opener appends to.
pub(crate) fn segment_name(shard: Option<Shard>) -> String {
    match shard {
        Some(s) => format!("runs.{}.jsonl", s.index),
        None => "runs.jsonl".to_string(),
    }
}

/// Is `name` a cache segment file (`runs.jsonl` or `runs.<k>.jsonl`)?
pub(crate) fn is_segment_name(name: &str) -> bool {
    if name == "runs.jsonl" {
        return true;
    }
    name.strip_prefix("runs.")
        .and_then(|rest| rest.strip_suffix(".jsonl"))
        .is_some_and(|mid| !mid.is_empty() && mid.bytes().all(|b| b.is_ascii_digit()))
}

/// Every segment in `dir`, sorted by file name (a missing directory is
/// an empty cache).
pub fn list_segments(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(out),
        Err(e) => {
            return Err(e).with_context(|| format!("reading cache dir {}", dir.display()))
        }
    };
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if path.is_file() && is_segment_name(name) {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

// ----------------------------------------------------------- generation

/// The compaction-generation marker file.  Not a segment
/// ([`is_segment_name`] rejects it), so it never participates in merges.
pub(crate) const GENERATION_FILE: &str = ".generation";

/// Current compaction generation of `dir` (0 for a never-compacted or
/// missing directory; unreadable markers count as 0 too, which at worst
/// costs a reader one spurious full rescan).
pub(crate) fn read_generation(dir: &Path) -> u64 {
    std::fs::read_to_string(dir.join(GENERATION_FILE))
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Bump the compaction generation (atomically, via temp file + rename).
/// Called by [`super::gc`] after any rewrite that invalidates readers'
/// remembered byte offsets; incremental readers that observe a changed
/// generation fall back to one full rescan.
pub(crate) fn bump_generation(dir: &Path) -> Result<()> {
    let next = read_generation(dir).wrapping_add(1);
    let tmp = dir.join(format!("{GENERATION_FILE}.tmp"));
    std::fs::write(&tmp, format!("{next}\n"))
        .with_context(|| format!("writing generation marker {}", tmp.display()))?;
    std::fs::rename(&tmp, dir.join(GENERATION_FILE))
        .context("installing generation marker")?;
    Ok(())
}

// ------------------------------------------------------------ sidecars

/// The key-presence sidecar (`<segment>.idx`) for a segment — see
/// [`super::filter`] for the on-disk format.  Not a segment
/// ([`is_segment_name`] rejects it), so it never participates in merges.
pub(crate) fn sidecar_path(segment: &Path) -> PathBuf {
    let mut name = segment.file_name().unwrap_or_default().to_os_string();
    name.push(".idx");
    segment.with_file_name(name)
}

// ---------------------------------------------------------- lock files

fn lock_path(segment: &Path) -> PathBuf {
    let mut name = segment.file_name().unwrap_or_default().to_os_string();
    name.push(".lock");
    segment.with_file_name(name)
}

fn pid_is_alive(pid: u32) -> bool {
    if pid == std::process::id() {
        return true;
    }
    if cfg!(target_os = "linux") {
        Path::new(&format!("/proc/{pid}")).exists()
    } else {
        // no portable liveness probe without libc: assume alive and make
        // the operator remove the lock file by hand
        true
    }
}

/// An advisory per-segment writer lock: a `<segment>.lock` file created
/// atomically (`create_new`) and holding the owner pid.  Stale locks
/// (dead pid) are reclaimed with a warning; live holders are an error.
pub(crate) struct SegmentLock {
    path: PathBuf,
}

impl SegmentLock {
    pub(crate) fn acquire(segment: &Path) -> Result<SegmentLock> {
        let path = lock_path(segment);
        for _ in 0..4 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Ok(SegmentLock { path });
                }
                Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    match holder {
                        Some(pid) if pid_is_alive(pid) => bail!(
                            "cache segment {} is locked by live process {pid} \
                             (another writer is draining this shard; pick a \
                             different --shard index or wait, then retry)",
                            segment.display()
                        ),
                        Some(pid) => {
                            // positively dead: reclaim and retry; if a
                            // racing process re-creates the lock first,
                            // the next round sees its live pid and errors
                            eprintln!(
                                "run-cache: reclaiming stale lock {} (holder {pid} is gone)",
                                path.display()
                            );
                            let _ = std::fs::remove_file(&path);
                        }
                        None => {
                            // a racing writer may have created the file
                            // but not flushed its pid line yet — never
                            // steal on an unreadable holder, just give
                            // it a beat and look again
                            std::thread::sleep(std::time::Duration::from_millis(20));
                        }
                    }
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating lock file {}", path.display()));
                }
            }
        }
        bail!(
            "could not acquire lock for segment {} after retries (if its writer is \
             gone, delete {} by hand)",
            segment.display(),
            lock_path(segment).display()
        )
    }

    /// Non-blocking acquire for opportunistic work (background tiered
    /// merges): a live holder is `Ok(None)`, not an error, and an
    /// unreadable holder pid is treated as live rather than waited on.
    /// Stale (dead-pid) locks are still reclaimed.
    pub(crate) fn try_acquire(segment: &Path) -> Result<Option<SegmentLock>> {
        let path = lock_path(segment);
        for _ in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Ok(Some(SegmentLock { path }));
                }
                Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    match holder {
                        Some(pid) if !pid_is_alive(pid) => {
                            eprintln!(
                                "run-cache: reclaiming stale lock {} (holder {pid} is gone)",
                                path.display()
                            );
                            let _ = std::fs::remove_file(&path);
                            // retry the create_new round
                        }
                        _ => return Ok(None),
                    }
                }
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("creating lock file {}", path.display()));
                }
            }
        }
        Ok(None)
    }
}

impl Drop for SegmentLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ------------------------------------------------------------- entries

/// Completion timestamp for new cache lines: unix seconds, overridable
/// via `UMUP_CACHE_TS` (the deterministic test harness pins it so whole
/// segments become byte-for-byte reproducible).
pub(crate) fn now_ts() -> u64 {
    if let Ok(v) = std::env::var("UMUP_CACHE_TS") {
        if let Ok(ts) = v.trim().parse::<u64>() {
            return ts;
        }
    }
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Serialize one cache line (the canonical, sorted-key form; also the
/// compaction output, so merged caches round-trip byte-identically —
/// and the worker wire protocol's success-reply codec, so the wire
/// format is the cache format).
pub(crate) fn entry_line(key: &str, manifest: &str, ts: u64, record: &RunRecord) -> String {
    let mut line = String::new();
    entry_line_into(key, manifest, ts, record, &mut line);
    line
}

/// [`entry_line`] into a caller-owned buffer (appended, not cleared):
/// the zero-realloc codec path the pipelined worker reply loop reuses
/// per frame.  Hand-writes the same sorted-key object byte-for-byte
/// (`key`, `manifest`, `record`, `ts` — already alphabetical), with
/// the record body via [`RunRecord::json_into`].
pub(crate) fn entry_line_into(
    key: &str,
    manifest: &str,
    ts: u64,
    record: &RunRecord,
    out: &mut String,
) {
    out.push_str("{\"key\":");
    crate::util::write_json_str(key, out);
    out.push_str(",\"manifest\":");
    crate::util::write_json_str(manifest, out);
    out.push_str(",\"record\":");
    record.json_into(out);
    out.push_str(",\"ts\":");
    crate::util::write_json_num(ts as f64, out);
    out.push('}');
}

/// One fully parsed cache line.  `ts` is 0 for pre-lifecycle lines
/// (treated as arbitrarily old by age-based GC).
pub(crate) struct Entry {
    pub(crate) key: String,
    pub(crate) manifest: String,
    pub(crate) ts: u64,
    pub(crate) record: RunRecord,
}

/// The eager (record-materializing) line parse — the reference codec
/// that hit-time loads, GC, and the wire protocol share.  The hot scan
/// path uses [`super::index::scan_line`] instead, which extracts the
/// same `key`/`manifest`/`ts` without building the record tree; the
/// two must agree on what constitutes a well-formed line (pinned by the
/// lazy-vs-eager property test in the module tests).
pub(crate) fn parse_full_entry(line: &str) -> Result<Entry> {
    let j = Json::parse(line)?;
    let key = j.get("key")?.as_str()?.to_string();
    let manifest = j.get("manifest")?.as_str()?.to_string();
    let ts = match j.get("ts") {
        Ok(v) => v.as_f64()? as u64,
        Err(_) => 0,
    };
    let record = RunRecord::from_json(j.get("record")?)?;
    Ok(Entry { key, manifest, ts, record })
}

/// Does `path` end mid-line (non-empty, no trailing newline)?  The
/// signature a writer was killed mid-append.
pub(crate) fn tail_is_torn(path: &Path) -> bool {
    let Ok(mut f) = File::open(path) else { return false };
    let Ok(len) = f.metadata().map(|m| m.len()) else { return false };
    if len == 0 || f.seek(SeekFrom::End(-1)).is_err() {
        return false;
    }
    let mut last = [0u8; 1];
    f.read_exact(&mut last).is_ok() && last[0] != b'\n'
}

/// Strict byte-oriented line iteration for *rewriters*: yields every
/// line (including a final unterminated one) as raw bytes with its
/// starting byte offset, and — unlike [`for_each_line`] — propagates
/// every I/O error.  Compaction must see either the whole segment or a
/// hard error; a silently truncated scan would let the rewrite destroy
/// the entries it never saw.  The callback's own error aborts the scan
/// too.  Line bytes include no trailing `\n`; a trailing `\r` (if any)
/// is *kept* so offset + len arithmetic stays exact.
pub(crate) fn scan_lines_strict(
    path: &Path,
    mut f: impl FnMut(u64, &[u8]) -> Result<()>,
) -> Result<()> {
    let file = match File::open(path) {
        Ok(file) => file,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("opening {}", path.display())),
    };
    let mut reader = BufReader::new(file);
    let mut buf = Vec::new();
    let mut offset: u64 = 0;
    loop {
        buf.clear();
        let n = reader
            .read_until(b'\n', &mut buf)
            .with_context(|| format!("reading {}", path.display()))?;
        if n == 0 {
            return Ok(());
        }
        let line = if buf.last() == Some(&b'\n') { &buf[..buf.len() - 1] } else { &buf[..] };
        f(offset, line)?;
        offset += n as u64;
    }
}

/// Byte-oriented, lossy line iteration: a torn final line from a killed
/// writer (possibly invalid UTF-8) must never abort a resume.  I/O
/// errors mid-file stop the scan with a warning instead of propagating.
pub(crate) fn for_each_line(path: &Path, mut f: impl FnMut(&str)) -> Result<()> {
    let file = match File::open(path) {
        Ok(file) => file,
        Err(e) if e.kind() == ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("opening {}", path.display())),
    };
    let mut reader = BufReader::new(file);
    let mut buf = Vec::new();
    loop {
        buf.clear();
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => return Ok(()),
            Ok(_) => {
                let line = String::from_utf8_lossy(&buf);
                f(line.trim_end_matches(['\n', '\r']));
            }
            Err(e) => {
                eprintln!("run-cache: stopping scan of {}: {e}", path.display());
                return Ok(());
            }
        }
    }
}
