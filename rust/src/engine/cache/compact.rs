//! Size-tiered background merges: opportunistically fold *similar-sized*
//! adjacent segments into one, without ever blocking a live writer.
//!
//! Where [`super::gc`] is the heavyweight whole-directory rewrite (runs
//! under every segment lock, applies retention filters, re-serializes
//! canonically), a tier merge is the cheap incremental sibling:
//!
//! - it only touches one *contiguous* group of segments whose sizes sit
//!   in the same tier (every member ≤ `tier_ratio` × the group's
//!   smallest, floored at `min_bytes` so tiny shard files always
//!   coalesce);
//! - it takes locks with [`SegmentLock::try_acquire`] — a group with a
//!   live writer in it is simply skipped this round, so background
//!   compaction never stalls an appending shard;
//! - winning lines are copied *verbatim* (raw bytes, no record parse or
//!   re-serialization) — last write per key wins, where "last" is the
//!   segment-sorted scan order that every reader already merges by.
//!   A line whose `record` is structurally wrong but scannable is
//!   therefore carried along unchanged (gc, which re-serializes, is the
//!   pass that sheds those);
//! - the merged output replaces the group's *highest-sorting* member, so
//!   its precedence slot relative to segments outside the group is
//!   unchanged, and the other members are deleted.
//!
//! Memory is bounded exactly like gc's: line metadata spills through
//! [`super::spill`] in fixed-size sorted runs and merges back in
//! streaming order, so a merge of arbitrarily large segments holds
//! O(chunk) entries in memory.  Each successful merge writes a fresh
//! key-presence sidecar (see [`super::filter`]) for the output segment
//! and bumps the directory generation so incremental readers rescan.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::hash::fnv1a64;

use super::filter::{remove_sidecar, SidecarWriter, PREFIX_HASH_SPAN};
use super::index::scan_line;
use super::segment::{bump_generation, list_segments, read_generation, scan_lines_strict, SegmentLock};
use super::spill::{KeyedLine, SpillWriter, DEFAULT_SPILL_CHUNK};

/// Tuning knobs for [`Compactor`].  The defaults keep merges strictly
/// "like with like": a 100 MiB compacted base is never rewritten just
/// because a 2 KiB shard segment appeared next to it.
#[derive(Debug, Clone)]
pub struct CompactorConfig {
    /// A group is mergeable when every member's size is at most this
    /// multiple of the group's smallest member (after the `min_bytes`
    /// floor).
    pub tier_ratio: f64,
    /// Never merge fewer segments than this (a 1-segment "merge" is a
    /// pointless rewrite).
    pub min_group: usize,
    /// Cap on group width, bounding single-step I/O.
    pub max_group: usize,
    /// Sizes below this count as `min_bytes` for the ratio test, so
    /// many tiny segments (the common post-sweep shard litter) always
    /// share a tier.
    pub min_bytes: u64,
}

impl Default for CompactorConfig {
    fn default() -> CompactorConfig {
        CompactorConfig {
            tier_ratio: 4.0,
            min_group: 2,
            max_group: 8,
            min_bytes: 1 << 20,
        }
    }
}

/// What one successful [`Compactor::step`] did.
#[derive(Debug, Clone)]
pub struct TierMergeReport {
    /// File names of the merged segments, in precedence order.
    pub inputs: Vec<String>,
    /// File name the merged output was installed over (the group's
    /// highest-sorting member).
    pub output: String,
    /// Unique keys in the output.
    pub entries: usize,
    /// Cross-segment duplicate lines dropped (older writes of a key).
    pub deduped: usize,
    /// Unparseable lines dropped.
    pub corrupt_dropped: usize,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// Pure planning: which contiguous index ranges of the (sorted) segment
/// listing form mergeable tier groups, cheapest total first.  `sizes`
/// is in listing order; candidates may overlap — the caller takes the
/// first one it can lock and re-plans next step.
fn plan_groups(sizes: &[u64], cfg: &CompactorConfig) -> Vec<Range<usize>> {
    let mut out: Vec<(u64, Range<usize>)> = Vec::new();
    let widest = cfg.max_group.max(cfg.min_group);
    for start in 0..sizes.len() {
        let (mut lo, mut hi, mut total) = (u64::MAX, 0u64, 0u64);
        for end in start + 1..=sizes.len().min(start + widest) {
            let s = sizes[end - 1];
            lo = lo.min(s);
            hi = hi.max(s);
            total += s;
            if end - start < cfg.min_group {
                continue;
            }
            if hi as f64 <= cfg.tier_ratio * lo.max(cfg.min_bytes) as f64 {
                out.push((total, start..end));
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.start.cmp(&b.1.start)));
    out.into_iter().map(|(_, r)| r).collect()
}

/// The background tier-merge driver for one cache directory.  `step`
/// does at most one group merge; `run` steps until no group is
/// mergeable.  Safe to run beside live writers (their segments are
/// lock-protected and simply skipped) and beside readers (the
/// generation bump triggers their rescan).
pub struct Compactor {
    dir: PathBuf,
    cfg: CompactorConfig,
}

impl Compactor {
    pub fn new(dir: &Path) -> Compactor {
        Compactor::with_config(dir, CompactorConfig::default())
    }

    pub fn with_config(dir: &Path, cfg: CompactorConfig) -> Compactor {
        Compactor { dir: dir.to_path_buf(), cfg }
    }

    /// Merge the cheapest lockable tier group, if any.  `Ok(None)` means
    /// there was nothing to do *right now* (no group, or every candidate
    /// has a live writer) — the idle-loop caller just tries again later.
    pub fn step(&self) -> Result<Option<TierMergeReport>> {
        let segments = list_segments(&self.dir)?;
        if segments.len() < self.cfg.min_group {
            return Ok(None);
        }
        let sizes: Vec<u64> = segments
            .iter()
            .map(|p| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
            .collect();
        'candidates: for range in plan_groups(&sizes, &self.cfg) {
            let group = &segments[range.clone()];
            let mut locks = Vec::with_capacity(group.len());
            for path in group {
                match SegmentLock::try_acquire(path)? {
                    Some(lock) => locks.push(lock),
                    // a live writer owns this member: drop whatever we
                    // grabbed and try the next candidate group
                    None => continue 'candidates,
                }
            }
            let report = self.merge_group(group)?;
            drop(locks);
            return Ok(Some(report));
        }
        Ok(None)
    }

    /// Step until no mergeable group remains, returning every report.
    /// Converges because each merge strictly reduces the segment count.
    pub fn run(&self) -> Result<Vec<TierMergeReport>> {
        let mut reports = Vec::new();
        while let Some(report) = self.step()? {
            reports.push(report);
        }
        Ok(reports)
    }

    /// Merge one locked group.  All reads happen (and must succeed)
    /// before any file is modified — an unreadable member aborts the
    /// merge with every segment intact, mirroring gc's no-data-loss
    /// contract.
    fn merge_group(&self, group: &[PathBuf]) -> Result<TierMergeReport> {
        let mut report = TierMergeReport {
            inputs: group.iter().map(|p| name_of(p)).collect(),
            output: name_of(group.last().expect("plan_groups yields non-empty groups")),
            entries: 0,
            deduped: 0,
            corrupt_dropped: 0,
            bytes_in: group
                .iter()
                .map(|p| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
                .sum(),
            bytes_out: 0,
        };

        // ---- scan: spill (key, seq) line metadata, bounded memory
        let mut manifests: Vec<String> = Vec::new();
        let mut manifest_ids: HashMap<String, u32> = HashMap::new();
        let mut spill: SpillWriter<KeyedLine> =
            SpillWriter::new(&self.dir, "tier", DEFAULT_SPILL_CHUNK)?;
        let mut seq = 0u64;
        for (seg_idx, path) in group.iter().enumerate() {
            scan_lines_strict(path, |offset, raw| {
                let Ok(text) = std::str::from_utf8(raw) else {
                    report.corrupt_dropped += 1;
                    return Ok(());
                };
                let line = text.trim_end_matches('\r');
                if line.trim().is_empty() {
                    return Ok(());
                }
                match scan_line(line) {
                    Ok(meta) => {
                        let manifest = match manifest_ids.get(&meta.manifest) {
                            Some(&id) => id,
                            None => {
                                let id = manifests.len() as u32;
                                manifests.push(meta.manifest.clone());
                                manifest_ids.insert(meta.manifest, id);
                                id
                            }
                        };
                        spill.push(KeyedLine {
                            key: meta.key,
                            seq,
                            seg: seg_idx as u32,
                            offset,
                            len: raw.len() as u32,
                            ts: meta.ts,
                            manifest,
                        })?;
                        seq += 1;
                    }
                    Err(_) => report.corrupt_dropped += 1,
                }
                Ok(())
            })
            .with_context(|| {
                format!(
                    "tier merge: reading segment {} (aborted; no file was modified)",
                    path.display()
                )
            })?;
        }
        let runs = spill.finish()?;

        // ---- count winners (sizes the sidecar's bloom filter)
        let mut merge = runs.merge()?;
        let mut winners = 0usize;
        let mut cur = merge.next()?;
        while let Some(first) = cur.take() {
            let mut winner = first;
            loop {
                match merge.next()? {
                    Some(next) if next.key == winner.key => {
                        report.deduped += 1;
                        winner = next;
                    }
                    other => {
                        cur = other;
                        break;
                    }
                }
            }
            winners += 1;
        }

        // ---- write: raw-copy each winning line once, sidecar alongside
        let output = group.last().expect("non-empty group");
        let mut written = 0usize;
        let mut out_off = 0u64;
        let tmp = {
            let mut name = output.file_name().unwrap_or_default().to_os_string();
            name.push(".tier.tmp");
            output.with_file_name(name)
        };
        if winners > 0 {
            let mut out = BufWriter::new(
                File::create(&tmp)
                    .with_context(|| format!("tier merge: creating {}", tmp.display()))?,
            );
            let mut sidecar = match SidecarWriter::create(output, &manifests, winners) {
                Ok(sw) => Some(sw),
                Err(e) => {
                    eprintln!("run-cache: tier merge proceeding without a sidecar: {e:#}");
                    None
                }
            };
            let mut prefix: Vec<u8> = Vec::new();
            let mut merge = runs.merge()?;
            let mut cur = merge.next()?;
            while let Some(first) = cur.take() {
                let mut winner = first;
                loop {
                    match merge.next()? {
                        Some(next) if next.key == winner.key => winner = next,
                        other => {
                            cur = other;
                            break;
                        }
                    }
                }
                let raw =
                    read_span(&group[winner.seg as usize], winner.offset, winner.len as usize)
                        .with_context(|| {
                            format!(
                                "tier merge: re-reading a planned winner from {} \
                                 (aborted; no segment was modified)",
                                group[winner.seg as usize].display()
                            )
                        })?;
                out.write_all(&raw).context("tier merge: writing merged segment")?;
                out.write_all(b"\n").context("tier merge: writing merged segment")?;
                if (prefix.len() as u64) < PREFIX_HASH_SPAN {
                    let take = (PREFIX_HASH_SPAN as usize - prefix.len()).min(raw.len());
                    prefix.extend_from_slice(&raw[..take]);
                    if (prefix.len() as u64) < PREFIX_HASH_SPAN {
                        prefix.push(b'\n');
                    }
                }
                if let Some(mut sw) = sidecar.take() {
                    match sw.push(&winner.key, out_off, winner.len, winner.ts, winner.manifest) {
                        Ok(()) => sidecar = Some(sw),
                        Err(e) => {
                            eprintln!("run-cache: tier merge abandoning the sidecar: {e:#}")
                        }
                    }
                }
                out_off += winner.len as u64 + 1;
                written += 1;
            }
            out.flush().context("tier merge: flushing merged segment")?;
            let _ = out.get_ref().sync_all();
            drop(out);

            // ---- commit: install output, drop merged-away members
            let next_generation = read_generation(&self.dir).wrapping_add(1);
            std::fs::rename(&tmp, output)
                .with_context(|| format!("tier merge: installing {}", output.display()))?;
            for member in &group[..group.len() - 1] {
                remove_sidecar(member);
                if let Err(e) = std::fs::remove_file(member) {
                    // harmless leftover: the installed output outranks it,
                    // so its (duplicate) keys stay shadowed; next step
                    // retries the delete via another merge
                    eprintln!(
                        "run-cache: tier merge could not remove {}: {e}",
                        member.display()
                    );
                }
            }
            match sidecar {
                Some(sw) => {
                    if let Err(e) = sw.finish(out_off, next_generation, fnv1a64(&prefix)) {
                        eprintln!("run-cache: tier merge sidecar write failed: {e:#}");
                        remove_sidecar(output);
                    }
                }
                None => remove_sidecar(output),
            }
            report.bytes_out = std::fs::metadata(output).map(|m| m.len()).unwrap_or(0);
        } else {
            // every line in the group was blank or corrupt: drop the
            // group entirely rather than install an empty segment
            for member in group {
                remove_sidecar(member);
                if let Err(e) = std::fs::remove_file(member) {
                    eprintln!(
                        "run-cache: tier merge could not remove {}: {e}",
                        member.display()
                    );
                }
            }
        }
        report.entries = written;
        if let Err(e) = bump_generation(&self.dir) {
            eprintln!("run-cache: tier merge could not bump the generation marker: {e:#}");
        }
        Ok(report)
    }
}

fn name_of(p: &Path) -> String {
    p.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default()
}

fn read_span(path: &Path, offset: u64, len: usize) -> Result<Vec<u8>> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    f.seek(SeekFrom::Start(offset)).context("seeking winner")?;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf).with_context(|| format!("reading {}", path.display()))?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::super::filter::Sidecar;
    use super::super::segment::entry_line;
    use super::super::CacheWatcher;
    use super::*;
    use crate::train::RunRecord;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("umup-tier-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn rec(label: &str) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            train_curve: vec![(1, 2.0)],
            valid_curve: vec![(1, 2.5)],
            final_valid_loss: 2.5,
            rms_curves: std::collections::BTreeMap::new(),
            final_rms: vec![("embedding".to_string(), 1.0)],
            diverged: false,
            wall_seconds: 0.5,
        }
    }

    fn key(i: u64) -> String {
        format!("{i:016x}")
    }

    fn write_seg(dir: &Path, name: &str, entries: &[(u64, &str, u64)]) -> Vec<String> {
        let mut lines = Vec::new();
        for &(k, manifest, ts) in entries {
            lines.push(entry_line(&key(k), manifest, ts, &rec(&format!("run-{k}"))));
        }
        let body: String = lines.iter().map(|l| format!("{l}\n")).collect();
        std::fs::write(dir.join(name), body).unwrap();
        lines
    }

    #[test]
    fn plan_groups_keeps_tiers_apart_and_prefers_cheap_merges() {
        let cfg = CompactorConfig {
            tier_ratio: 4.0,
            min_group: 2,
            max_group: 3,
            min_bytes: 1,
        };
        let groups = plan_groups(&[100, 120, 4000, 100_000], &cfg);
        // the two small segments are the only tier-compatible window:
        // 4000 > 4×120 and 100_000 > 4×4000 exclude everything else
        assert_eq!(groups, vec![0..2]);

        // the min_bytes floor puts tiny segments in one shared tier
        let floored = CompactorConfig { min_bytes: 10_000, ..cfg.clone() };
        let groups = plan_groups(&[100, 120, 4000], &floored);
        assert_eq!(groups.first(), Some(&(0..2)), "cheapest merge first");
        assert!(groups.contains(&(0..3)), "the full window shares the floored tier");

        // too few segments: nothing to plan
        assert!(plan_groups(&[500], &cfg).is_empty());
    }

    #[test]
    fn tiered_merge_converges_preserving_raw_bytes_and_precedence() {
        let dir = tmp_dir("converge");
        let s0 = write_seg(&dir, "runs.0.jsonl", &[(0xa, "m0", 100), (0xb, "m0", 101)]);
        let s1 = write_seg(&dir, "runs.1.jsonl", &[(0xb, "m1", 200), (0xc, "m0", 102)]);
        let s2 = write_seg(&dir, "runs.2.jsonl", &[(0xd, "m1", 103)]);

        let compactor = Compactor::new(&dir);
        let reports = compactor.run().unwrap();
        // cheapest group first: the (runs.1, runs.2) pair is the
        // smallest total, then the result folds up with runs.0
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].inputs, vec!["runs.1.jsonl", "runs.2.jsonl"]);
        assert_eq!(reports[0].output, "runs.2.jsonl");
        assert_eq!((reports[0].entries, reports[0].deduped), (3, 0));
        assert_eq!(reports[1].inputs, vec!["runs.0.jsonl", "runs.2.jsonl"]);
        assert_eq!(reports[1].output, "runs.2.jsonl");
        // runs.0's older write of key 0xb loses to the runs.1 version
        assert_eq!((reports[1].entries, reports[1].deduped), (4, 1));
        assert_eq!(reports.iter().map(|r| r.corrupt_dropped).sum::<usize>(), 0);
        assert!(reports[1].bytes_out < reports[1].bytes_in);

        // only the output segment remains, holding each key's raw
        // winning line verbatim, key-sorted — 0xb's runs.1 version wins
        let out = dir.join("runs.2.jsonl");
        assert_eq!(list_segments(&dir).unwrap(), vec![out.clone()]);
        let expected: String =
            [&s0[0], &s1[0], &s1[1], &s2[0]].iter().map(|l| format!("{l}\n")).collect();
        assert_eq!(std::fs::read_to_string(&out).unwrap(), expected);

        // the generation moved and the sidecar is adoptable: a fresh
        // watcher counts keys without scanning the segment
        assert!(read_generation(&dir) > 0);
        let sc = Sidecar::open(&out).unwrap().expect("merge must leave a sidecar");
        assert!(sc.validate(&out));
        assert_eq!(sc.n_entries(), 4);
        let mut w = CacheWatcher::new(&dir);
        assert_eq!(w.poll(), 4);
        assert_eq!(w.filter_stats().segments_skipped, 1);

        // idempotent: a single segment is never re-merged
        assert!(compactor.step().unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_writer_lock_skips_the_group_without_blocking() {
        let dir = tmp_dir("locked");
        write_seg(&dir, "runs.0.jsonl", &[(1, "m0", 100)]);
        write_seg(&dir, "runs.1.jsonl", &[(2, "m0", 101)]);
        let held = SegmentLock::acquire(&dir.join("runs.1.jsonl")).unwrap();

        let compactor = Compactor::new(&dir);
        assert!(compactor.step().unwrap().is_none(), "locked member excludes its group");
        assert_eq!(list_segments(&dir).unwrap().len(), 2, "nothing was touched");

        drop(held);
        let report = compactor.step().unwrap().expect("unlocked group now merges");
        assert_eq!(report.entries, 2);
        assert_eq!(list_segments(&dir).unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_corrupt_group_is_dropped_not_installed_empty() {
        let dir = tmp_dir("corrupt");
        std::fs::write(dir.join("runs.0.jsonl"), "{ not json\n").unwrap();
        std::fs::write(dir.join("runs.1.jsonl"), "also not json\n").unwrap();
        let report = Compactor::new(&dir).step().unwrap().expect("the group was planned");
        assert_eq!((report.entries, report.corrupt_dropped), (0, 2));
        assert!(list_segments(&dir).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
