//! Content-addressed run cache with sharded, lock-safe segments, a lazy
//! byte-offset index, and a lifecycle (GC / compaction / stats).
//!
//! # Addressing
//!
//! A run is addressed by a stable 64-bit FNV-1a hash of
//! `(manifest name, corpus config, canonical RunConfig)` — see
//! [`crate::train::RunConfig::canonical_json`] for what is (and is not)
//! part of the address; notably the presentation-only `label` is
//! excluded, so the same baseline config reached from different figures
//! deduplicates.  The corpus participates through its generator config
//! ([`CorpusConfig`]): corpora are deterministic functions of it, and
//! without it a quick-mode (200k-token) record would silently satisfy a
//! full-corpus run of the same config.  The canonical form serializes
//! through the in-tree JSON writer with sorted keys and
//! shortest-round-trip floats, and FNV-1a is a fixed function, so keys
//! are stable across field-construction order *and* across process runs
//! — which is what makes the on-disk cache a resume mechanism.
//!
//! # Cache layout & lifecycle
//!
//! A cache directory holds one or more JSONL *segments*:
//!
//! * `runs.jsonl` — the unsharded (single-process) segment, also the
//!   output of compaction;
//! * `runs.<k>.jsonl` — the segment written by shard `k` of a sharded
//!   sweep (`--shard k/n`).
//!
//! Each line is one completed run:
//! `{"key":…,"manifest":…,"record":…,"ts":…}` — appended and flushed as
//! results arrive, so a killed sweep loses at most the in-flight runs.
//! `ts` is the unix-seconds completion time (overridable via the
//! `UMUP_CACHE_TS` env var, which the deterministic concurrency harness
//! uses to make whole segments byte-for-byte reproducible).
//!
//! *Reads* are **lazy**: opening a cache with `resume` scans every
//! segment for *keys only* (sorted by file name, last write per key
//! wins), building a `key → (segment, byte offset, length, ts,
//! manifest)` index without materializing a single [`RunRecord`];
//! records are parsed on demand at hit time and memoized per key, so
//! resident memory is O(keys + records touched), not O(total curve
//! points).  [`RunCache::refresh_from_disk`] is **incremental**: it
//! tails only the bytes siblings appended since the last call (the
//! `index` submodule holds the offset/tailing/generation machinery;
//! [`CacheWatcher`] is its lock-free, read-only public face), so the
//! sharded converge loop polls at O(new bytes).
//!
//! *Writes* are single-writer per segment: each opener appends only to
//! its own segment, guarded by an advisory lock file
//! (`<segment>.lock`, containing the holder pid).  A stale lock — its
//! pid no longer alive — is reclaimed with a warning; a live holder is a
//! hard error, so two processes can never interleave writes within one
//! segment.  Distinct shards write distinct segments, which is what
//! makes a sharded sweep safe without any cross-process byte-level
//! locking.
//!
//! *Lifecycle*: [`stats`] summarizes a cache directory (per-segment
//! entry/corruption/byte counts, duplicate keys across segments,
//! per-manifest totals) by streaming the key scanner — no record is
//! materialized; [`gc()`] prunes by age (`ts`) and/or manifest, evicts
//! oldest-first down to a byte budget (`--max-bytes`), and compacts all
//! segments into a single key-sorted `runs.jsonl`, taking every segment
//! lock first so it never races a live writer, and bumping the
//! directory's compaction *generation* so incremental readers rescan.
//! The whole rewrite is *streaming*: line metadata spills to sorted
//! temp runs and k-way merges back, so gc of a 10⁶-entry cache holds
//! O(chunk) entries in memory, never O(cache).  An *unsharded* open
//! with `resume` auto-compacts (best-effort) once a directory accretes
//! more than [`AUTO_COMPACT_SEGMENT_THRESHOLD`] segments, so long-lived
//! sharded caches don't degrade every open into an N-file merge (shard
//! children never compact — they open one directory concurrently and
//! must not steal each other's locks).
//!
//! # Tiered merges, key-presence filters, and the generation contract
//!
//! Between full gc passes, a [`Compactor`] (driven from the engine's
//! idle path, or `repro cache compact`) opportunistically folds
//! *similar-sized adjacent* segments into one with raw byte copies —
//! size-tiered compaction.  It locks only the group it merges, via
//! non-blocking `try_acquire`, so a live shard writer is never stalled:
//! its segment's group is simply skipped this round.
//!
//! Every compacted segment (gc output or tier-merge output) gets a
//! `<segment>.idx` *sidecar*: a bloom filter + fence-pointed, key-sorted
//! entry table over the segment's per-key winners (format in the
//! `filter` submodule docs).  Readers use sidecars two ways: a fresh
//! index **adopts** a valid sidecar instead of scanning the segment
//! (cold opens after compaction cost O(sidecar trailer), not O(bytes)),
//! and point lookups for absent keys stop at the bloom filter — the
//! miss-heavy sweep-resume path never touches the segment.
//! [`FilterStats`] (via [`CacheWatcher::filter_stats`] /
//! [`RunCache::filter_stats`]) counts the work saved.
//!
//! The coherence rules:
//!
//! * a sidecar covers a *byte prefix* of its segment and stays valid
//!   under appends (validity = the covered prefix still exists and its
//!   first 4 KiB hash unchanged); truncation or in-place rewrite
//!   invalidates it structurally — the stored generation is diagnostic
//!   only, since a tier merge bumps the directory generation without
//!   touching *other* segments' sidecars;
//! * precedence is by segment sort order (rank), exactly the merge
//!   order scans use; at equal rank an in-map (scanned/appended) entry
//!   outranks the sidecar, because appends land beyond the covered
//!   prefix and are therefore newer;
//! * any rewrite bumps the directory `.generation`, and incremental
//!   readers that observe a changed generation fall back to one full
//!   rescan (re-adopting sidecars where valid).
//!
//! # Crash safety
//!
//! A process killed mid-append leaves a truncated (possibly non-UTF-8)
//! final line.  Scanning is byte-oriented and lossy: corrupt lines are
//! *skipped with a warning*, never propagated, so a `--resume` after a
//! crash re-runs at most the torn job.  A torn line that has not yet
//! been newline-terminated is never consumed by the incremental tailer
//! — a sibling caught mid-`write` is simply picked up one refresh
//! later, once its newline lands.  Compaction (gc and tier merges) is
//! temp-file + rename, aborts wholesale on any read error before
//! touching a file, and cleans its spill runs on drop.

mod compact;
mod filter;
mod gc;
mod index;
mod segment;
mod spill;

pub use self::compact::{Compactor, CompactorConfig, TierMergeReport};
pub use self::gc::{
    gc, parse_bytes, parse_duration, GcOptions, GcReport, AUTO_COMPACT_SEGMENT_THRESHOLD,
};
pub use self::index::{stats, CacheStats, CacheWatcher, FilterStats, SegmentStats};
pub use self::segment::list_segments;

pub(crate) use self::segment::{entry_line, entry_line_into, now_ts, parse_full_entry};

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::{Corpus, CorpusConfig};
use crate::train::{RunConfig, RunRecord};
use crate::util::hash::fnv1a64;
use crate::util::Json;

use self::index::CacheIndex;
use self::segment::{segment_name, tail_is_torn, SegmentLock};

/// Canonical form of the corpus generator config (sorted keys).  Also
/// the `corpus` field of a worker wire-protocol job frame (see
/// `crate::engine::backend::wire`), so key hashing and the wire agree
/// on what a corpus *is*.
pub(crate) fn corpus_json(c: &CorpusConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("vocab".to_string(), Json::Num(c.vocab as f64));
    m.insert("n_tokens".to_string(), Json::Num(c.n_tokens as f64));
    m.insert("seed".to_string(), Json::Num(c.seed as f64));
    m.insert("zipf_s".to_string(), Json::Num(c.zipf_s));
    m.insert("k_succ".to_string(), Json::Num(c.k_succ as f64));
    m.insert("smoothing".to_string(), Json::Num(c.smoothing));
    m.insert("valid_frac".to_string(), Json::Num(c.valid_frac));
    Json::Obj(m)
}

/// [`corpus_json`]`.dump()` into a caller-owned buffer (appended):
/// the zero-realloc wire-frame path.  Hand-writes the same sorted-key
/// object byte-for-byte (all fields numeric, alphabetical order).
pub(crate) fn corpus_json_into(c: &CorpusConfig, out: &mut String) {
    use crate::util::write_json_num as num;
    out.push_str("{\"k_succ\":");
    num(c.k_succ as f64, out);
    out.push_str(",\"n_tokens\":");
    num(c.n_tokens as f64, out);
    out.push_str(",\"seed\":");
    num(c.seed as f64, out);
    out.push_str(",\"smoothing\":");
    num(c.smoothing, out);
    out.push_str(",\"valid_frac\":");
    num(c.valid_frac, out);
    out.push_str(",\"vocab\":");
    num(c.vocab as f64, out);
    out.push_str(",\"zipf_s\":");
    num(c.zipf_s, out);
    out.push('}');
}

/// The content address of one run, as a 16-hex-digit string.
pub fn run_key(manifest: &str, corpus: &Corpus, cfg: &RunConfig) -> String {
    run_key_from_dumps(
        manifest,
        &corpus_json(&corpus.config).dump(),
        &cfg.canonical_json().dump(),
    )
}

/// [`run_key`] over pre-serialized canonical forms — the memoized path
/// ([`crate::engine::EngineJob`] computes each dump once and reuses it
/// here and on the worker wire).
pub(crate) fn run_key_from_dumps(manifest: &str, corpus_dump: &str, config_dump: &str) -> String {
    let payload = format!("{manifest}\n{corpus_dump}\n{config_dump}");
    format!("{:016x}", fnv1a64(payload.as_bytes()))
}

// ------------------------------------------------------------- sharding

/// One slice of a sharded sweep: this process owns every run key whose
/// hash lands in residue class `index` mod `count`.
///
/// Ownership is a pure function of the content address, so N processes
/// given the same job list and the same `count` partition it into
/// disjoint, deterministic slices without any coordination — the slices
/// are hash-balanced, not contiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub count: usize,
}

impl Shard {
    /// Parse the CLI form `i/n` (0-based, `i < n`).
    pub fn parse(s: &str) -> Result<Shard> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("bad shard spec {s:?} (expected i/n, e.g. 0/4)"))?;
        let index: usize = i.trim().parse().with_context(|| format!("bad shard index {i:?}"))?;
        let count: usize = n.trim().parse().with_context(|| format!("bad shard count {n:?}"))?;
        if count == 0 {
            bail!("shard count must be >= 1");
        }
        if index >= count {
            bail!("shard index {index} out of range for count {count} (0-based)");
        }
        Ok(Shard { index, count })
    }

    /// Does this shard own the run with content address `key`?
    pub fn owns(&self, key: &str) -> bool {
        self.index_of(key) == self.index
    }

    /// Which shard (0..count) owns `key`.
    pub fn index_of(&self, key: &str) -> usize {
        // run keys are 16-hex FNV digests; fall back to re-hashing for
        // anything else so arbitrary strings still partition stably
        let h = u64::from_str_radix(key, 16).unwrap_or_else(|_| fnv1a64(key.as_bytes()));
        (mix64(h) % self.count as u64) as usize
    }
}

/// splitmix64 finalizer.  FNV-1a's multiply only carries differences
/// *upward*, so related payloads cluster in the digest's low bits —
/// taking `h % count` directly can park an entire sweep in one shard
/// (observed: 8/8 same-parity keys for an eta-only grid).  Mixing
/// high bits back down first makes the partition track the whole
/// digest.  Partition assignment only — never part of the on-disk key.
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

impl std::fmt::Display for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

// ----------------------------------------------------------- RunCache

/// The engine's run cache: a lazy key index over segmented JSONL
/// persistence, with on-demand (memoized) record loading.
///
/// `records` holds every record this cache has *materialized*: results
/// `put` this session plus disk entries touched by [`RunCache::get`].
/// The full key set lives in the byte-offset `index` — records for the
/// untouched tail of a 10⁵-entry history are never parsed, so open and
/// refresh cost scales with keys / new bytes, not with total curve
/// data.  (Mirroring the eager reader it replaced, records once
/// materialized are kept until the cache is dropped; a gc running in
/// another process can remove keys from *future* opens, not from a live
/// cache's memo.)
pub struct RunCache {
    /// Memoized / locally-recorded records (a subset of the index keys
    /// for persistent caches; the whole cache for in-memory ones).
    records: HashMap<String, RunRecord>,
    /// Lazy key index over the cache directory; `None` for in-memory.
    index: Option<CacheIndex>,
    file: Option<File>,
    path: Option<PathBuf>,
    /// Held for the cache's lifetime; releases (deletes) on drop.
    _lock: Option<SegmentLock>,
}

impl RunCache {
    /// A process-local cache (still deduplicates within a sweep and
    /// across an engine's lifetime; nothing is written to disk).
    pub fn in_memory() -> RunCache {
        RunCache { records: HashMap::new(), index: None, file: None, path: None, _lock: None }
    }

    /// Open the persistent, unsharded cache at `dir/runs.jsonl`
    /// (equivalent to [`RunCache::open_sharded`] with no shard).
    pub fn open(dir: &Path, resume: bool) -> Result<RunCache> {
        Self::open_sharded(dir, None, resume)
    }

    /// Open the persistent cache in `dir`, appending to this opener's
    /// segment (`runs.jsonl`, or `runs.<k>.jsonl` for shard `k`).
    ///
    /// The segment is locked against concurrent writers for the cache's
    /// lifetime.  With `resume`, pre-existing keys from **all**
    /// segments are indexed (corrupt lines are skipped with a warning —
    /// a truncated tail from a killed process must not poison the
    /// sweep; records load lazily on first [`RunCache::get`]), and —
    /// for *unsharded* openers only, since shard children open one
    /// directory concurrently — a directory that has accreted more than
    /// [`AUTO_COMPACT_SEGMENT_THRESHOLD`] segments is first compacted
    /// into one (best-effort: skipped with a note if any segment has a
    /// live writer).  Without `resume`, this opener's own segment is
    /// truncated (a fresh recording); other shards' segments are left
    /// alone, since their writers may be live — use `repro cache gc` to
    /// clear a directory wholesale.
    pub fn open_sharded(dir: &Path, shard: Option<Shard>, resume: bool) -> Result<RunCache> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        if resume && shard.is_none() {
            // auto-compaction: a long-lived sharded cache dir otherwise
            // turns every open into an N-file merge.  Runs before this
            // opener takes its own segment lock (gc wants them all).
            // Unsharded opens only: N shard children resume-open one dir
            // *concurrently*, and a child's gc would grab every sibling's
            // segment lock and fail their opens mid-drive — the final
            // unsharded --resume pass (or the next single-process open)
            // is the natural compaction point instead.
            let n_segments = list_segments(dir)?.len();
            if n_segments > AUTO_COMPACT_SEGMENT_THRESHOLD {
                match gc(dir, &GcOptions::default()) {
                    Ok(rep) => eprintln!(
                        "run-cache: auto-compacted {} segments into runs.jsonl \
                         ({} entries, {} duplicate lines dropped)",
                        rep.segments_before, rep.kept, rep.deduped
                    ),
                    Err(e) => eprintln!(
                        "run-cache: auto-compaction of {n_segments} segments skipped \
                         (live writer?): {e:#}"
                    ),
                }
            }
        }
        let path = dir.join(segment_name(shard));
        let lock = SegmentLock::acquire(&path)?;
        let mut file = if resume {
            OpenOptions::new().create(true).append(true).open(&path)
        } else {
            // truncating invalidates any sidecar built over the old
            // content; delete it rather than leave readers a filter
            // that fails (or worse, passes) its prefix check by chance
            filter::remove_sidecar(&path);
            File::create(&path)
        }
        .with_context(|| format!("opening run cache {} for append", path.display()))?;
        if resume && tail_is_torn(&path) {
            // a killed writer left a line without its newline: start the
            // next append on a fresh line so the new record isn't
            // concatenated onto (and lost with) the torn one.  Healing
            // runs *before* the index scan, so the scan consumes the
            // (now terminated) torn line as one corrupt line and lands
            // its tail offset exactly at the append position.
            file.write_all(b"\n").context("healing torn run-cache tail")?;
        }
        let mut index = CacheIndex::new(dir);
        if resume {
            // initial full key scan (sorted segment order, later lines
            // win — the same merge the eager reader performed)
            index.refresh();
        } else {
            // a fresh recording: nothing pre-existing is visible, but
            // the (just truncated) own segment is tracked so local
            // appends index at the right offsets; a later
            // refresh_from_disk still merges sibling segments in full
            index.track_segment(&path);
        }
        Ok(RunCache {
            records: HashMap::new(),
            index: Some(index),
            file: Some(file),
            path: Some(path),
            _lock: Some(lock),
        })
    }

    /// Number of addressable records (index keys for persistent caches).
    pub fn len(&self) -> usize {
        match &self.index {
            Some(i) => i.len(),
            None => self.records.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// The cache directory (`None` for in-memory caches) — what a
    /// [`Compactor`] or [`gc()`] wants handed to it.
    pub fn dir(&self) -> Option<&Path> {
        self.path.as_deref().and_then(Path::parent)
    }

    /// How much work the key-presence sidecar filters have saved this
    /// cache (zeroes for in-memory caches and filterless directories).
    pub fn filter_stats(&self) -> FilterStats {
        self.index.as_ref().map(|i| i.filter_stats()).unwrap_or_default()
    }

    /// Look up a record by content address.  For persistent caches this
    /// is the lazy path: the first hit parses the record from its
    /// indexed byte span and memoizes it; later hits are map lookups.
    /// (`&mut self` because of that memoization — the engine keeps its
    /// cache behind a mutex anyway.)
    pub fn get(&mut self, key: &str) -> Option<&RunRecord> {
        if !self.records.contains_key(key) {
            let rec = self.index.as_mut()?.load(key)?;
            self.records.insert(key.to_string(), rec);
        }
        self.records.get(key)
    }

    /// Is `key` addressable (without loading its record)?
    pub fn contains(&self, key: &str) -> bool {
        self.records.contains_key(key)
            || self.index.as_ref().is_some_and(|i| i.contains(key))
    }

    /// The manifest a cached run was recorded under — answered from the
    /// index alone, no record parse (`None` for in-memory caches and
    /// unknown keys).
    pub fn manifest_of(&self, key: &str) -> Option<&str> {
        self.index.as_ref()?.manifest_of(key)
    }

    /// Unix-seconds completion time of a cached run (0 for
    /// pre-lifecycle lines; `None` for in-memory caches and unknown
    /// keys).  An index read — no record parse.
    pub fn recorded_ts(&self, key: &str) -> Option<u64> {
        self.index.as_ref()?.recorded_ts(key)
    }

    /// Merge in any entries *other* writers appended to this cache
    /// directory since open — a sharded drain polls this between rounds
    /// to pick up sibling shards' results.  Incremental: only bytes
    /// appended since the last call are read (this opener's own appends
    /// are indexed at write time and never re-read).  Returns the
    /// number of newly visible records.  No-op (0) for in-memory
    /// caches.
    pub fn refresh_from_disk(&mut self) -> usize {
        match &mut self.index {
            Some(i) => i.refresh(),
            None => 0,
        }
    }

    /// Record a completed run (idempotent per key) and, if persistent,
    /// append + flush its JSONL line to this opener's segment.
    pub fn put(&mut self, key: &str, manifest: &str, record: &RunRecord) -> Result<()> {
        if self.contains(key) {
            return Ok(());
        }
        self.records.insert(key.to_string(), record.clone());
        if let (Some(f), Some(index), Some(path)) =
            (self.file.as_mut(), self.index.as_mut(), self.path.as_deref())
        {
            let ts = now_ts();
            let line = entry_line(key, manifest, ts, record);
            let appended = writeln!(f, "{line}")
                .context("appending run-cache line")
                .and_then(|()| f.flush().context("flushing run cache"));
            match appended {
                Ok(()) => index.note_local_append(path, key, manifest, ts, line.len()),
                Err(e) => {
                    // a partial write may sit on disk: terminate it
                    // (best-effort — a stray blank line is harmless,
                    // an unterminated fragment would swallow the next
                    // successful append into one corrupt line) and
                    // re-align the tail with reality so later offsets
                    // stay truthful.  The record itself stays served
                    // from memory.
                    let _ = f.write_all(b"\n").and_then(|()| f.flush());
                    index.resync_local(path);
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write as _;
    use std::time::Duration;

    use super::segment::is_segment_name;
    use super::*;

    fn rec(label: &str, loss: f64) -> RunRecord {
        RunRecord {
            label: label.to_string(),
            train_curve: vec![(1, loss)],
            valid_curve: vec![],
            final_valid_loss: loss,
            rms_curves: BTreeMap::new(),
            final_rms: vec![],
            diverged: false,
            wall_seconds: 0.0,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("umup-cache-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// The hand-rolled entry codec must stay byte-identical to the
    /// sorted-key tree form it replaced — the wire format *is* the
    /// cache format, so a drifted writer would break cross-backend
    /// byte-determinism, not just aesthetics.
    #[test]
    fn entry_line_matches_the_tree_writer_byte_for_byte() {
        let record = rec("pä\"y\nl", 4.8125);
        let line = entry_line("cbf29ce484222325", "w64_d4 \"q\"", 1_700_000_000, &record);
        let mut obj = BTreeMap::new();
        obj.insert("key".to_string(), Json::Str("cbf29ce484222325".to_string()));
        obj.insert("manifest".to_string(), Json::Str("w64_d4 \"q\"".to_string()));
        obj.insert("record".to_string(), record.to_json());
        obj.insert("ts".to_string(), Json::Num(1_700_000_000u64 as f64));
        assert_eq!(line, Json::Obj(obj).dump());
        // and the _into variant appends without clearing
        let mut buf = String::from("keep:");
        entry_line_into("k", "m", 7, &record, &mut buf);
        assert_eq!(buf, format!("keep:{}", entry_line("k", "m", 7, &record)));
        // the corpus hand-writer obeys the same contract
        let corpus = CorpusConfig { vocab: 64, n_tokens: 12345, seed: 9, ..Default::default() };
        let mut buf = String::new();
        corpus_json_into(&corpus, &mut buf);
        assert_eq!(buf, corpus_json(&corpus).dump());
    }

    #[test]
    fn key_depends_on_manifest_and_corpus() {
        let cfg = RunConfig::quick(
            "x",
            crate::parametrization::Parametrization::new(crate::parametrization::Scheme::Umup),
            crate::parametrization::HpSet::default(),
            8,
        );
        let corpus = |n_tokens: usize| Corpus {
            config: CorpusConfig { vocab: 64, n_tokens, ..Default::default() },
            tokens: vec![],
            n_train: 0,
        };
        let (small, big) = (corpus(1000), corpus(2000));
        assert_eq!(run_key("m1", &small, &cfg), run_key("m1", &small, &cfg));
        assert_ne!(run_key("m1", &small, &cfg), run_key("m2", &small, &cfg));
        // a quick-mode corpus must never satisfy a full-corpus run
        assert_ne!(run_key("m1", &small, &cfg), run_key("m1", &big, &cfg));
    }

    #[test]
    fn shard_parse_and_ownership_partition() {
        let s = Shard::parse("1/4").unwrap();
        assert_eq!((s.index, s.count), (1, 4));
        assert!(Shard::parse("4/4").is_err());
        assert!(Shard::parse("0/0").is_err());
        assert!(Shard::parse("x/4").is_err());
        assert!(Shard::parse("3").is_err());
        // every key is owned by exactly one shard, deterministically
        for key in ["00000000000000ff", "cbf29ce484222325", "not-hex-at-all"] {
            let owners: Vec<usize> = (0..4)
                .filter(|&i| Shard { index: i, count: 4 }.owns(key))
                .collect();
            assert_eq!(owners.len(), 1, "{key}: {owners:?}");
            assert_eq!(owners[0], Shard { index: 0, count: 4 }.index_of(key));
        }
        // count=1 owns everything
        assert!(Shard { index: 0, count: 1 }.owns("cbf29ce484222325"));
    }

    #[test]
    fn segment_names_are_recognized() {
        assert!(is_segment_name("runs.jsonl"));
        assert!(is_segment_name("runs.0.jsonl"));
        assert!(is_segment_name("runs.12.jsonl"));
        assert!(!is_segment_name("runs.jsonl.lock"));
        assert!(!is_segment_name("runs.0.jsonl.lock"));
        assert!(!is_segment_name("runs.x.jsonl"));
        assert!(!is_segment_name("runs..jsonl"));
        assert!(!is_segment_name("other.jsonl"));
        assert!(!is_segment_name("runs.jsonl.tmp"));
        assert!(!is_segment_name(".generation"));
    }

    #[test]
    fn sharded_segments_merge_on_resume() {
        let dir = tmp_dir("merge");
        {
            let mut c0 =
                RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
            c0.put("aaaa", "m1", &rec("a", 1.0)).unwrap();
        }
        {
            let mut c1 =
                RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
            c1.put("bbbb", "m2", &rec("b", 2.0)).unwrap();
        }
        let mut merged = RunCache::open(&dir, true).unwrap();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged.get("aaaa").unwrap().final_valid_loss, 1.0);
        assert_eq!(merged.get("bbbb").unwrap().final_valid_loss, 2.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_lock_blocks_second_writer_and_stale_lock_is_reclaimed() {
        let dir = tmp_dir("lock");
        let cache = RunCache::open(&dir, true).unwrap();
        let err = RunCache::open(&dir, true).unwrap_err().to_string();
        assert!(err.contains("locked by live process"), "{err}");
        // a different segment is fine while the first is held
        let other =
            RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
        drop(other);
        drop(cache);
        // stale lock: dead pid -> reclaimed silently (warning only)
        std::fs::write(dir.join("runs.jsonl.lock"), "4294967294\n").unwrap();
        let cache = RunCache::open(&dir, true).unwrap();
        drop(cache);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_and_non_utf8_tails_are_skipped_on_resume() {
        let dir = tmp_dir("torn");
        {
            let mut c = RunCache::open(&dir, false).unwrap();
            c.put("aaaa", "m", &rec("a", 1.5)).unwrap();
        }
        // simulate a crash mid-append: truncated JSON then raw bytes
        {
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join("runs.jsonl"))
                .unwrap();
            f.write_all(b"{\"key\":\"bbbb\",\"manifest\":\"m\",\"rec").unwrap();
            f.write_all(&[0xff, 0xfe, 0x80]).unwrap();
        }
        let mut c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 1, "torn tail must be skipped, not fatal");
        assert!(c.get("aaaa").is_some());
        // the torn tail is healed: a post-resume append must not be
        // concatenated onto (and lost with) the garbage line
        c.put("cccc", "m", &rec("c", 2.5)).unwrap();
        drop(c);
        let mut c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.get("cccc").is_some(), "append after torn tail must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_prunes_by_manifest_and_age_and_compacts() {
        let dir = tmp_dir("gc");
        // (timestamps are the real clock here: mutating the process-wide
        // UMUP_CACHE_TS env would race sibling unit tests' appends.  The
        // deterministic-ts path is covered per-child-process by
        // tests/engine_concurrency.rs.)
        {
            let mut c0 =
                RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
            c0.put("aaaa", "m1", &rec("a", 1.0)).unwrap();
            let mut c1 =
                RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
            c1.put("bbbb", "m2", &rec("b", 2.0)).unwrap();
            c1.put("cccc", "m2", &rec("c", 3.0)).unwrap();
        }

        let st = stats(&dir).unwrap();
        assert_eq!(st.segments.len(), 2);
        assert_eq!(st.unique_keys, 3);
        assert_eq!(st.duplicate_keys, 0);
        assert_eq!(st.per_manifest["m1"], 1);
        assert_eq!(st.per_manifest["m2"], 2);
        assert!(st.oldest_ts.is_some() && st.newest_ts >= st.oldest_ts);

        // dry-run changes nothing
        let dry = gc(
            &dir,
            &GcOptions { manifest: Some("m2".into()), dry_run: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!((dry.kept, dry.pruned), (1, 2));
        assert_eq!(stats(&dir).unwrap().unique_keys, 3);

        // prune one manifest; survivors land compacted in runs.jsonl
        let rep =
            gc(&dir, &GcOptions { manifest: Some("m2".into()), ..Default::default() }).unwrap();
        assert_eq!((rep.kept, rep.pruned), (1, 2));
        let st = stats(&dir).unwrap();
        assert_eq!(st.unique_keys, 1);
        assert_eq!(st.segments.len(), 1);
        assert_eq!(st.segments[0].name, "runs.jsonl");
        let mut merged = RunCache::open(&dir, true).unwrap();
        assert_eq!(merged.len(), 1);
        assert!(merged.get("aaaa").is_some());
        drop(merged);

        // age-based: every entry's ts <= now, so --older-than 0s prunes all
        let rep = gc(
            &dir,
            &GcOptions { older_than: Some(Duration::from_secs(0)), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.kept, 0);
        assert_eq!(rep.pruned, 1);
        let st = stats(&dir).unwrap();
        assert_eq!(st.unique_keys, 0);
        assert!(st.segments.is_empty(), "emptied cache has no segment files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_refuses_while_a_writer_is_live() {
        let dir = tmp_dir("gc-live");
        let mut c = RunCache::open(&dir, true).unwrap();
        c.put("aaaa", "m", &rec("a", 1.0)).unwrap();
        let err = gc(&dir, &GcOptions::default()).unwrap_err().to_string();
        assert!(err.contains("locked by live process"), "{err}");
        drop(c);
        assert_eq!(gc(&dir, &GcOptions::default()).unwrap().kept, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_max_bytes_evicts_oldest_first() {
        let dir = tmp_dir("gc-bytes");
        // three entries with strictly increasing ts (distinct keys);
        // UMUP_CACHE_TS can't be used here (process-wide env races
        // sibling tests), so write the lines directly
        std::fs::create_dir_all(&dir).unwrap();
        let mut lines = String::new();
        for (i, key) in ["aaaa", "bbbb", "cccc"].iter().enumerate() {
            lines.push_str(&entry_line(key, "m", 100 + i as u64, &rec(key, i as f64)));
            lines.push('\n');
        }
        std::fs::write(dir.join("runs.jsonl"), &lines).unwrap();

        // budget that fits exactly the two newest lines
        let line_len = |key: &str, i: u64| {
            entry_line(key, "m", 100 + i, &rec(key, i as f64)).len() as u64 + 1
        };
        let budget = line_len("bbbb", 1) + line_len("cccc", 2);
        // dry run reports the projection without touching the file
        let dry = gc(
            &dir,
            &GcOptions { max_bytes: Some(budget), dry_run: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!((dry.kept, dry.evicted, dry.pruned), (2, 1, 0));
        assert!(dry.bytes_after <= budget);
        assert_eq!(stats(&dir).unwrap().unique_keys, 3);

        let rep =
            gc(&dir, &GcOptions { max_bytes: Some(budget), ..Default::default() }).unwrap();
        assert_eq!((rep.kept, rep.evicted, rep.pruned), (2, 1, 0));
        assert!(rep.bytes_after <= budget, "{} > {budget}", rep.bytes_after);
        let mut merged = RunCache::open(&dir, true).unwrap();
        assert!(merged.get("aaaa").is_none(), "oldest entry must be evicted");
        assert!(merged.get("bbbb").is_some() && merged.get("cccc").is_some());
        drop(merged);

        // a generous budget evicts nothing
        let rep = gc(
            &dir,
            &GcOptions { max_bytes: Some(u64::MAX), ..Default::default() },
        )
        .unwrap();
        assert_eq!((rep.kept, rep.evicted), (2, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_open_auto_compacts_past_the_segment_threshold() {
        let dir = tmp_dir("auto-compact");
        let n = AUTO_COMPACT_SEGMENT_THRESHOLD + 2;
        for i in 0..n {
            // resume: false — auto-compaction is a resume-open behavior,
            // so seeding the segments here must not trigger it early
            let mut c =
                RunCache::open_sharded(&dir, Some(Shard { index: i, count: n }), false).unwrap();
            c.put(&format!("{i:016x}"), "m", &rec("r", i as f64)).unwrap();
        }
        assert_eq!(list_segments(&dir).unwrap().len(), n);
        // resume-open triggers compaction: all entries survive, but the
        // shard segments collapse into runs.jsonl (+ the opener's own)
        let c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), n, "auto-compaction must not lose entries");
        drop(c);
        let segs = list_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1, "segments must be compacted: {segs:?}");
        assert!(segs[0].ends_with("runs.jsonl"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_count_parsing() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("65536").unwrap(), 65536);
        assert_eq!(parse_bytes("512k").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("10m").unwrap(), 10 * 1024 * 1024);
        assert_eq!(parse_bytes("1g").unwrap(), 1024 * 1024 * 1024);
        assert_eq!(parse_bytes("2KiB").unwrap(), 2048);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert!(parse_bytes("abc").is_err());
        assert!(parse_bytes("5 parsecs").is_err());
    }

    #[test]
    fn duration_parsing() {
        assert_eq!(parse_duration("0s").unwrap(), Duration::from_secs(0));
        assert_eq!(parse_duration("90").unwrap(), Duration::from_secs(90));
        assert_eq!(parse_duration("5m").unwrap(), Duration::from_secs(300));
        assert_eq!(parse_duration("2h").unwrap(), Duration::from_secs(7200));
        assert_eq!(parse_duration("30d").unwrap(), Duration::from_secs(2_592_000));
        assert_eq!(parse_duration("1w").unwrap(), Duration::from_secs(604_800));
        assert_eq!(parse_duration("1.5h").unwrap(), Duration::from_secs(5400));
        assert!(parse_duration("abc").is_err());
        assert!(parse_duration("5 fortnights").is_err());
        // u64-overflow seconds must be an error, not a panic
        assert!(parse_duration("10000000000000000d").is_err());
    }

    // ------------------------------------------- lazy-index behaviors

    /// A record with enough structure to catch span/offset bugs.
    fn rich_rec(label: &str, i: u64) -> RunRecord {
        let loss = 3.0 - (i as f64) * 0.125;
        RunRecord {
            label: label.to_string(),
            train_curve: (1..=i + 1).map(|t| (t, loss + 1.0 / t as f64)).collect(),
            valid_curve: vec![(i + 1, loss)],
            final_valid_loss: if i % 7 == 3 { f64::INFINITY } else { loss },
            rms_curves: BTreeMap::from([(
                format!("w.site{}", i % 3),
                vec![(1u64, 0.5f64), (i + 1, 1.0)],
            )]),
            final_rms: vec![(format!("w.site{}", i % 3), 1.0)],
            diverged: i % 7 == 3,
            wall_seconds: 0.25 * i as f64,
        }
    }

    /// The old eager reader, reconstructed as the reference: full-parse
    /// every line of every segment (sorted order, later lines win).
    fn eager_entries(dir: &Path) -> HashMap<String, (String, u64, RunRecord)> {
        let mut out = HashMap::new();
        for seg in list_segments(dir).unwrap() {
            segment::for_each_line(&seg, |line| {
                if line.trim().is_empty() {
                    return;
                }
                if let Ok(e) = parse_full_entry(line) {
                    out.insert(e.key, (e.manifest, e.ts, e.record));
                }
            })
            .unwrap();
        }
        out
    }

    /// Property: the index-backed lazy path resolves exactly the keys,
    /// and exactly the records, the eager full-parse path did — across
    /// multiple segments, cross-segment duplicate keys, corrupt lines,
    /// unicode, non-finite losses, and blank lines.
    #[test]
    fn lazy_reads_are_equivalent_to_eager_full_parse() {
        use crate::util::prop::{check, Config};
        check(
            "lazy cache == eager cache",
            Config { cases: 24, seed: 0x1a5e_cafe },
            |g| {
                let dir = tmp_dir(&format!("prop-{}", g.case));
                std::fs::create_dir_all(&dir).unwrap();
                let n_segments = g.usize_in(1, 3);
                // a small key pool forces cross-segment duplicates
                let key_pool: Vec<String> =
                    (0..6).map(|k| format!("{:016x}", 0xabc0 + k)).collect();
                for s in 0..n_segments {
                    let mut body = String::new();
                    for e in 0..g.usize_in(0, 10) {
                        match g.rng.below(10) {
                            // 0-6: a valid entry (varied shape/unicode)
                            0..=6 => {
                                let key = &key_pool[g.rng.below(key_pool.len())];
                                let manifest = ["w32", "w64-µ", "w128"][g.rng.below(3)];
                                let label = format!("s{s}e{e}-\"q\"-ü");
                                let line = entry_line(
                                    key,
                                    manifest,
                                    g.rng.below(1000) as u64,
                                    &rich_rec(&label, g.rng.below(9) as u64),
                                );
                                body.push_str(&line);
                                body.push('\n');
                            }
                            // 7: a blank line (skipped by both paths)
                            7 => body.push('\n'),
                            // 8: structural garbage
                            8 => body.push_str("** not json **\n"),
                            // 9: a truncated entry (always invalid: the
                            // closing brace is lost) with a stray tail
                            _ => {
                                let line = entry_line(
                                    &key_pool[g.rng.below(key_pool.len())],
                                    "w32",
                                    1,
                                    &rich_rec("torn", 2),
                                );
                                let mut cut = 1 + g.rng.below(line.len() - 1);
                                while !line.is_char_boundary(cut) {
                                    cut -= 1;
                                }
                                body.push_str(&line[..cut]);
                                body.push('\u{fffd}');
                                body.push('\n');
                            }
                        }
                    }
                    let name =
                        if s == 0 { "runs.jsonl".into() } else { format!("runs.{s}.jsonl") };
                    std::fs::write(dir.join(name), &body).unwrap();
                }

                let eager = eager_entries(&dir);
                let mut lazy = RunCache::open(&dir, true).unwrap();
                assert_eq!(lazy.len(), eager.len(), "key sets must match");
                for (key, (_, _, record)) in &eager {
                    assert!(lazy.contains(key));
                    let got = lazy.get(key).unwrap_or_else(|| panic!("missing {key}"));
                    assert_eq!(got, record, "record for {key} must match eager parse");
                    // memoized second read agrees
                    assert_eq!(lazy.get(key).unwrap(), record);
                }
                assert!(lazy.get("0000000000000000").is_none());
                // the streamed stats agree on the merged key set
                assert_eq!(stats(&dir).unwrap().unique_keys, eager.len());
                drop(lazy);
                let _ = std::fs::remove_dir_all(&dir);
            },
        );
    }

    /// Regression: a sibling writer caught mid-append (torn, unterminated
    /// tail) must not be consumed by the incremental tailer — and the
    /// completed line must surface on the *next* refresh.
    #[test]
    fn torn_tail_while_tailing_is_deferred_not_lost() {
        let dir = tmp_dir("tail-torn");
        let mut reader = RunCache::open(&dir, true).unwrap();
        assert_eq!(reader.len(), 0);

        let sibling = dir.join("runs.0.jsonl");
        let line_a = entry_line("aaaa", "m", 10, &rec("a", 1.0));
        let line_b = entry_line("bbbb", "m", 11, &rec("b", 2.0));
        let (b_head, b_tail) = line_b.split_at(line_b.len() / 2);

        // one complete line + half of the next, no newline
        std::fs::write(&sibling, format!("{line_a}\n{b_head}")).unwrap();
        assert_eq!(reader.refresh_from_disk(), 1, "complete line is visible");
        assert_eq!(reader.get("aaaa").unwrap().final_valid_loss, 1.0);
        assert!(reader.get("bbbb").is_none(), "torn line must not be indexed");
        // polling again while the tail is still torn consumes nothing
        assert_eq!(reader.refresh_from_disk(), 0);

        // the writer finishes the line
        {
            let mut f = OpenOptions::new().append(true).open(&sibling).unwrap();
            writeln!(f, "{b_tail}").unwrap();
        }
        assert_eq!(reader.refresh_from_disk(), 1, "completed line surfaces");
        assert_eq!(reader.get("bbbb").unwrap().final_valid_loss, 2.0);

        // a tail that completes into garbage is skipped, and later
        // appends still index at the right offsets
        {
            let mut f = OpenOptions::new().append(true).open(&sibling).unwrap();
            write!(f, "{{\"key\":\"cc").unwrap();
        }
        assert_eq!(reader.refresh_from_disk(), 0);
        {
            let mut f = OpenOptions::new().append(true).open(&sibling).unwrap();
            let line_d = entry_line("dddd", "m", 12, &rec("d", 4.0));
            writeln!(f, "\u{fffd}garbage\n{line_d}").unwrap();
        }
        assert_eq!(reader.refresh_from_disk(), 1, "only the valid line lands");
        assert_eq!(reader.get("dddd").unwrap().final_valid_loss, 4.0);
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Refresh cost model: a no-op refresh consumes nothing and new
    /// appends are visible exactly once (the incremental contract the
    /// benches measure).
    #[test]
    fn refresh_counts_only_new_entries() {
        let dir = tmp_dir("refresh-delta");
        let mut reader = RunCache::open(&dir, true).unwrap();
        let mut writer =
            RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
        assert_eq!(reader.refresh_from_disk(), 0);
        writer.put("aaaa", "m", &rec("a", 1.0)).unwrap();
        writer.put("bbbb", "m", &rec("b", 2.0)).unwrap();
        assert_eq!(reader.refresh_from_disk(), 2);
        assert_eq!(reader.refresh_from_disk(), 0, "no-op refresh sees nothing");
        writer.put("cccc", "m", &rec("c", 3.0)).unwrap();
        assert_eq!(reader.refresh_from_disk(), 1);
        // own appends are indexed at write time, not re-read: a reader
        // refresh after its own put is still a no-op
        reader.put("dddd", "m", &rec("d", 4.0)).unwrap();
        assert_eq!(reader.refresh_from_disk(), 0);
        assert_eq!(reader.len(), 4);
        // index-only metadata reads — no record parse behind these
        assert_eq!(reader.manifest_of("aaaa"), Some("m"));
        assert_eq!(reader.manifest_of("dddd"), Some("m"));
        assert!(reader.recorded_ts("dddd").is_some());
        assert_eq!(reader.manifest_of("not-a-key"), None);
        drop((reader, writer));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The compaction-generation contract, seen from a lock-free
    /// watcher: gc rewrites the directory under it, and the next poll
    /// rescans instead of trusting dead offsets.
    #[test]
    fn watcher_survives_compaction_via_generation_rescan() {
        let dir = tmp_dir("watcher-gen");
        {
            let mut c0 =
                RunCache::open_sharded(&dir, Some(Shard { index: 0, count: 2 }), true).unwrap();
            c0.put("aaaa", "m1", &rec("a", 1.0)).unwrap();
            let mut c1 =
                RunCache::open_sharded(&dir, Some(Shard { index: 1, count: 2 }), true).unwrap();
            c1.put("bbbb", "m2", &rec("b", 2.0)).unwrap();
            c1.put("cccc", "m2", &rec("c", 3.0)).unwrap();
        }
        let mut w = CacheWatcher::new(&dir);
        assert_eq!(w.poll(), 3);
        assert_eq!((w.unique_keys(), w.segments()), (3, 2));
        assert_eq!(w.poll(), 0);

        // compaction: same keys, different files/offsets
        gc(&dir, &GcOptions::default()).unwrap();
        w.poll();
        assert_eq!((w.unique_keys(), w.segments()), (3, 1));

        // pruning: keys disappear — visible only because of the rescan
        gc(&dir, &GcOptions { manifest: Some("m2".into()), ..Default::default() }).unwrap();
        w.poll();
        assert_eq!(w.unique_keys(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The sidecar fast path is an *accelerator*, not the truth: every
    /// lookup kind must resolve identically with the filter adopted and
    /// with it deleted — including shadowing in both directions (an
    /// append to the compacted segment beats its own sidecar; a
    /// lower-sorting shard's duplicate loses to the sidecar).
    #[test]
    fn sidecar_adoption_matches_a_full_scan_with_shadowing_both_ways() {
        let dir = tmp_dir("sidecar-equiv");
        {
            let mut c = RunCache::open(&dir, true).unwrap();
            for i in 0..20u64 {
                c.put(&format!("{i:016x}"), "m1", &rich_rec("seed", i % 9)).unwrap();
            }
        }
        gc(&dir, &GcOptions::default()).unwrap();

        // equal-rank shadowing: appends to the compacted segment land
        // beyond the sidecar's covered prefix and must beat it
        let key5 = format!("{:016x}", 5u64);
        let key7 = format!("{:016x}", 7u64);
        let override5 = entry_line(&key5, "m2", 999, &rich_rec("override", 1));
        let fresh = entry_line("00000000000000aa", "m2", 1000, &rich_rec("fresh", 2));
        {
            let mut f =
                OpenOptions::new().append(true).open(dir.join("runs.jsonl")).unwrap();
            writeln!(f, "{override5}").unwrap();
            writeln!(f, "{fresh}").unwrap();
        }
        // cross-rank shadowing: a lower-sorting shard segment's
        // duplicate of key 7 must lose to the compacted segment
        let loser7 = entry_line(&key7, "m3", 777, &rich_rec("loser", 3));
        let shard_new = entry_line("00000000000000bb", "m3", 778, &rich_rec("shard", 4));
        std::fs::write(dir.join("runs.0.jsonl"), format!("{loser7}\n{shard_new}\n")).unwrap();

        let expected = eager_entries(&dir);
        assert_eq!(expected.len(), 22);
        let verify = |c: &mut RunCache| {
            assert_eq!(c.len(), expected.len());
            for (key, (manifest, ts, record)) in &expected {
                assert!(c.contains(key));
                assert_eq!(c.manifest_of(key), Some(manifest.as_str()), "manifest for {key}");
                assert_eq!(c.recorded_ts(key), Some(*ts), "ts for {key}");
                assert_eq!(c.get(key).unwrap(), record, "record for {key}");
            }
            assert!(!c.contains("00000000000000cc"));
        };

        {
            let mut c = RunCache::open(&dir, true).unwrap();
            assert_eq!(
                c.filter_stats().segments_skipped,
                1,
                "the compacted segment must be adopted, not scanned"
            );
            verify(&mut c);
            assert_eq!(c.manifest_of(&key5), Some("m2"), "append outranks the sidecar");
            assert_eq!(c.manifest_of(&key7), Some("m1"), "sidecar outranks the lower shard");
        }
        std::fs::remove_file(dir.join("runs.jsonl.idx")).unwrap();
        {
            let mut c = RunCache::open(&dir, true).unwrap();
            assert_eq!(c.filter_stats().segments_skipped, 0, "no sidecar, pure scan");
            verify(&mut c);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The miss-heavy path the filters exist for: after a compaction, a
    /// cold open adopts the sidecar (no segment scan) and absent-key
    /// probes die at the bloom filter instead of touching the segment.
    #[test]
    fn miss_heavy_lookups_stop_at_the_bloom_filter() {
        let dir = tmp_dir("miss-heavy");
        {
            let mut c = RunCache::open(&dir, true).unwrap();
            for i in 0..50u64 {
                c.put(&format!("{i:016x}"), "m", &rec("r", i as f64)).unwrap();
            }
        }
        gc(&dir, &GcOptions::default()).unwrap();

        let c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 50, "adoption must count keys without a scan");
        assert_eq!(c.filter_stats().segments_skipped, 1);
        for i in 0..50u64 {
            assert!(c.contains(&format!("{i:016x}")));
        }
        for i in 0..1000u64 {
            assert!(!c.contains(&format!("{:016x}", 0xdead_0000u64 + i)));
        }
        let st = c.filter_stats();
        assert_eq!(st.sidecar_hits, 50, "present keys resolve via the sidecar: {st:?}");
        assert!(st.bloom_rejects >= 900, "bloom must answer most misses: {st:?}");
        assert!(st.fence_probes <= 150, "few misses may reach a fence scan: {st:?}");
        drop(c);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Graceful degradation: a line whose record is valid JSON of the
    /// wrong shape indexes (the scanner cannot tell) but resolves as a
    /// miss at hit time and is dropped from the index.
    #[test]
    fn malformed_record_shape_degrades_to_a_miss_at_hit_time() {
        let dir = tmp_dir("bad-shape");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("runs.jsonl"),
            "{\"key\":\"aaaa\",\"manifest\":\"m\",\"record\":{\"bogus\":1},\"ts\":1}\n",
        )
        .unwrap();
        let mut c = RunCache::open(&dir, true).unwrap();
        assert_eq!(c.len(), 1, "scanner indexes the structurally valid line");
        assert!(c.get("aaaa").is_none(), "hit-time parse rejects the shape");
        assert_eq!(c.len(), 0, "the dud entry is dropped");
        drop(c);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
