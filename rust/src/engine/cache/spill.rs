//! Bounded-memory external sort for compaction: fixed-size chunks are
//! sorted in memory and spilled as length-prefixed binary runs under a
//! `.gc-spill.<pid>.<tag>/` temp directory, then k-way merged back in
//! sorted order through a [`std::collections::BinaryHeap`].
//!
//! Only *metadata* is spilled, never record payloads: [`KeyedLine`]
//! carries a key plus the (segment, offset, len) needed to re-read the
//! winning line later, and [`AgeKey`] carries the (ts, key, len) triple
//! the size-budget eviction planner sorts by.  Peak memory is therefore
//! `O(chunk_entries)`, not `O(cache bytes)` — the property the 10⁶-entry
//! bench pins.
//!
//! Spill runs always go to disk (no in-memory fast path): every unit
//! test then exercises the exact code the million-entry case runs, and
//! the chunk size stays a pure performance knob with no behavior cliff.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Default in-memory chunk (entries per sorted run).  64Ki entries of
/// spill metadata is a few MiB resident; a 10⁶-entry cache spills ~16
/// runs, well inside a single merge pass.
pub(crate) const DEFAULT_SPILL_CHUNK: usize = 64 * 1024;

/// An item that can ride a spill run: a fixed self-delimiting binary
/// codec plus the total order the runs are sorted and merged by.
pub(crate) trait SpillItem: Sized {
    fn encode(&self, out: &mut Vec<u8>);
    /// `Ok(None)` on clean end-of-run; a torn record is a hard error.
    fn decode(r: &mut BufReader<File>) -> Result<Option<Self>>;
    fn cmp_key(a: &Self, b: &Self) -> Ordering;
}

// ---------------------------------------------------------------- codec

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("torn spill record")?;
    Ok(u32::from_le_bytes(b))
}

fn get_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("torn spill record")?;
    Ok(u64::from_le_bytes(b))
}

/// Fill `buf` exactly, or report a clean EOF (`Ok(false)`) if the
/// stream ends *before the first byte*.  Ending mid-record is an error:
/// spill runs are written by this process moments ago, so a short run
/// means disk trouble, and the merge must abort rather than silently
/// treat the tail as absent.
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut n = 0;
    while n < buf.len() {
        let k = r.read(&mut buf[n..]).context("reading spill run")?;
        if k == 0 {
            if n == 0 {
                return Ok(false);
            }
            bail!("torn spill record ({n} of {} header bytes)", buf.len());
        }
        n += k;
    }
    Ok(true)
}

fn decode_key(r: &mut BufReader<File>) -> Result<Option<String>> {
    let mut lb = [0u8; 4];
    if !read_exact_or_eof(r, &mut lb)? {
        return Ok(None);
    }
    let mut kb = vec![0u8; u32::from_le_bytes(lb) as usize];
    r.read_exact(&mut kb).context("torn spill record (key bytes)")?;
    Ok(Some(String::from_utf8(kb).context("non-utf8 spill key")?))
}

// ---------------------------------------------------------------- items

/// One scanned cache line, by reference: where it lives on disk plus the
/// metadata the merge filters on.  `seq` is the global scan order
/// (segment-sorted, then file order), so for duplicate keys the item
/// with the largest `seq` is the last write and wins the merge.
#[derive(Debug, Clone)]
pub(crate) struct KeyedLine {
    pub(crate) key: String,
    pub(crate) seq: u64,
    /// Index into the gc's sorted segment list.
    pub(crate) seg: u32,
    /// Byte offset of the line within its segment.
    pub(crate) offset: u64,
    /// Raw line length in bytes (no trailing newline).
    pub(crate) len: u32,
    pub(crate) ts: u64,
    /// Index into the gc's interned manifest-name table.
    pub(crate) manifest: u32,
}

impl SpillItem for KeyedLine {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.key.len() as u32);
        out.extend_from_slice(self.key.as_bytes());
        put_u64(out, self.seq);
        put_u32(out, self.seg);
        put_u64(out, self.offset);
        put_u32(out, self.len);
        put_u64(out, self.ts);
        put_u32(out, self.manifest);
    }

    fn decode(r: &mut BufReader<File>) -> Result<Option<Self>> {
        let Some(key) = decode_key(r)? else { return Ok(None) };
        Ok(Some(KeyedLine {
            key,
            seq: get_u64(r)?,
            seg: get_u32(r)?,
            offset: get_u64(r)?,
            len: get_u32(r)?,
            ts: get_u64(r)?,
            manifest: get_u32(r)?,
        }))
    }

    fn cmp_key(a: &Self, b: &Self) -> Ordering {
        a.key.cmp(&b.key).then(a.seq.cmp(&b.seq))
    }
}

/// The eviction planner's sort item: per-key winners ordered oldest
/// first (key tiebreak, so repeated gc over the same data is
/// deterministic), with the line length needed to walk the size budget.
#[derive(Debug, Clone)]
pub(crate) struct AgeKey {
    pub(crate) ts: u64,
    pub(crate) key: String,
    pub(crate) len: u32,
}

impl SpillItem for AgeKey {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.key.len() as u32);
        out.extend_from_slice(self.key.as_bytes());
        put_u64(out, self.ts);
        put_u32(out, self.len);
    }

    fn decode(r: &mut BufReader<File>) -> Result<Option<Self>> {
        let Some(key) = decode_key(r)? else { return Ok(None) };
        Ok(Some(AgeKey { key, ts: get_u64(r)?, len: get_u32(r)? }))
    }

    fn cmp_key(a: &Self, b: &Self) -> Ordering {
        a.ts.cmp(&b.ts).then_with(|| a.key.cmp(&b.key))
    }
}

// ------------------------------------------------------------ spill dir

/// Owns the temp spill directory; best-effort removal on drop so an
/// aborted gc doesn't leave runs behind (the pid-stamped name means a
/// crashed process's leftovers are overwritten by the next run anyway).
struct TempDirGuard {
    path: PathBuf,
}

impl TempDirGuard {
    fn create(path: PathBuf) -> Result<TempDirGuard> {
        // clobber leftovers from a dead process that had our pid
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating spill dir {}", path.display()))?;
        Ok(TempDirGuard { path })
    }
}

impl Drop for TempDirGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

// --------------------------------------------------------------- writer

/// Accumulates items, spilling a sorted run every `chunk` entries.
pub(crate) struct SpillWriter<T> {
    dir: TempDirGuard,
    chunk: usize,
    buf: Vec<T>,
    runs: Vec<PathBuf>,
    scratch: Vec<u8>,
}

impl<T: SpillItem> SpillWriter<T> {
    /// `parent` is the cache directory; the spill dir is named after the
    /// pid and `tag` so concurrent phases (key runs vs. age runs) and
    /// concurrent processes never collide.  The dotted name is not a
    /// segment name, so cache readers ignore it.
    pub(crate) fn new(parent: &Path, tag: &str, chunk_entries: usize) -> Result<SpillWriter<T>> {
        let dir =
            TempDirGuard::create(parent.join(format!(".gc-spill.{}.{tag}", std::process::id())))?;
        Ok(SpillWriter {
            dir,
            chunk: chunk_entries.max(1),
            buf: Vec::new(),
            runs: Vec::new(),
            scratch: Vec::new(),
        })
    }

    pub(crate) fn push(&mut self, item: T) -> Result<()> {
        self.buf.push(item);
        if self.buf.len() >= self.chunk {
            self.flush_run()?;
        }
        Ok(())
    }

    fn flush_run(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.buf.sort_unstable_by(T::cmp_key);
        let path = self.dir.path.join(format!("run.{:06}", self.runs.len()));
        let mut w = BufWriter::new(
            File::create(&path).with_context(|| format!("creating spill run {}", path.display()))?,
        );
        for item in self.buf.drain(..) {
            self.scratch.clear();
            item.encode(&mut self.scratch);
            w.write_all(&self.scratch).context("writing spill run")?;
        }
        w.flush().context("flushing spill run")?;
        self.runs.push(path);
        Ok(())
    }

    /// Spill the final partial chunk and seal the run set.
    pub(crate) fn finish(mut self) -> Result<SpillRuns<T>> {
        self.flush_run()?;
        let SpillWriter { dir, runs, .. } = self;
        Ok(SpillRuns { _dir: dir, runs, _marker: PhantomData })
    }
}

/// A sealed, sorted run set.  [`SpillRuns::merge`] can be called more
/// than once — gc's planning pass and its write pass each replay the
/// same runs.
pub(crate) struct SpillRuns<T> {
    _dir: TempDirGuard,
    runs: Vec<PathBuf>,
    _marker: PhantomData<T>,
}

impl<T: SpillItem> SpillRuns<T> {
    pub(crate) fn merge(&self) -> Result<Merge<T>> {
        let mut heap = BinaryHeap::with_capacity(self.runs.len());
        for (src, path) in self.runs.iter().enumerate() {
            let mut reader = BufReader::new(
                File::open(path)
                    .with_context(|| format!("opening spill run {}", path.display()))?,
            );
            if let Some(item) = T::decode(&mut reader)? {
                heap.push(HeapEntry { item, src, reader });
            }
        }
        Ok(Merge { heap })
    }
}

// ---------------------------------------------------------------- merge

struct HeapEntry<T> {
    item: T,
    src: usize,
    reader: BufReader<File>,
}

impl<T: SpillItem> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T: SpillItem> Eq for HeapEntry<T> {}

impl<T: SpillItem> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: SpillItem> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, the merge wants the min;
        // ties broken by run index for a deterministic replay order
        T::cmp_key(&self.item, &other.item).then(self.src.cmp(&other.src)).reverse()
    }
}

/// Streaming k-way merge over a run set, smallest item first.
pub(crate) struct Merge<T> {
    heap: BinaryHeap<HeapEntry<T>>,
}

impl<T: SpillItem> Merge<T> {
    pub(crate) fn next(&mut self) -> Result<Option<T>> {
        let Some(mut top) = self.heap.pop() else { return Ok(None) };
        let out = match T::decode(&mut top.reader)? {
            Some(next) => {
                let out = std::mem::replace(&mut top.item, next);
                self.heap.push(top);
                out
            }
            None => top.item,
        };
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("umup-spill-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn kl(key: &str, seq: u64) -> KeyedLine {
        KeyedLine {
            key: key.to_string(),
            seq,
            seg: (seq % 3) as u32,
            offset: seq * 100,
            len: 42,
            ts: 1000 + seq,
            manifest: (seq % 2) as u32,
        }
    }

    #[test]
    fn spill_merge_is_globally_sorted_and_lossless() {
        let dir = tmp_dir("sorted");
        let mut w: SpillWriter<KeyedLine> = SpillWriter::new(&dir, "keys", 16).unwrap();
        // push in descending order across several runs, with duplicates
        for i in (0..100u64).rev() {
            w.push(kl(&format!("{:016x}", i % 40), i)).unwrap();
        }
        let runs = w.finish().unwrap();
        for _ in 0..2 {
            // merge twice: the run set must be replayable
            let mut m = runs.merge().unwrap();
            let mut got = Vec::new();
            while let Some(item) = m.next().unwrap() {
                got.push((item.key.clone(), item.seq, item.offset));
            }
            assert_eq!(got.len(), 100);
            let mut sorted = got.clone();
            sorted.sort();
            assert_eq!(got, sorted);
            // offsets survive the roundtrip
            assert!(got.iter().all(|(_, seq, off)| *off == seq * 100));
        }
        drop(runs);
        // the spill dir cleans up after itself
        assert!(std::fs::read_dir(&dir).unwrap().next().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn age_keys_merge_oldest_first_with_key_tiebreak() {
        let dir = tmp_dir("age");
        let mut w: SpillWriter<AgeKey> = SpillWriter::new(&dir, "age", 4).unwrap();
        for (ts, key) in [(5u64, "b"), (3, "z"), (5, "a"), (3, "a"), (9, "m")] {
            w.push(AgeKey { ts, key: key.to_string(), len: 10 }).unwrap();
        }
        let runs = w.finish().unwrap();
        let mut m = runs.merge().unwrap();
        let mut got = Vec::new();
        while let Some(item) = m.next().unwrap() {
            got.push((item.ts, item.key));
        }
        assert_eq!(
            got,
            vec![
                (3, "a".to_string()),
                (3, "z".to_string()),
                (5, "a".to_string()),
                (5, "b".to_string()),
                (9, "m".to_string()),
            ]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_spill_record_is_a_hard_error() {
        let dir = tmp_dir("torn");
        let mut bytes = Vec::new();
        kl("00000000000000ab", 7).encode(&mut bytes);
        let full = dir.join("full.run");
        std::fs::write(&full, &bytes).unwrap();
        let mut r = BufReader::new(File::open(&full).unwrap());
        assert!(KeyedLine::decode(&mut r).unwrap().is_some());
        assert!(KeyedLine::decode(&mut r).unwrap().is_none());

        let torn = dir.join("torn.run");
        std::fs::write(&torn, &bytes[..bytes.len() - 3]).unwrap();
        let mut r = BufReader::new(File::open(&torn).unwrap());
        assert!(KeyedLine::decode(&mut r).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
