//! The lazy byte-offset index: O(keys) resident memory, O(new bytes)
//! refresh.
//!
//! # Why an index
//!
//! A u-µP-scale HP sweep accretes 10⁵–10⁶ cached runs.  The eager
//! reader materialized every [`RunRecord`] (full train/valid/RMS
//! curves) into a `HashMap` on open, and re-read **every** segment byte
//! on every `refresh_from_disk` poll of the sharded converge loop.
//! [`CacheIndex`] instead scans segments only for *keys*, building
//! `key → (segment, byte offset, line length, ts, manifest)` without
//! building a single record tree; records are parsed on demand at hit
//! time ([`CacheIndex::load`]) and memoized by the owning
//! [`super::RunCache`], so resident memory is proportional to the key
//! set plus the records actually touched.
//!
//! # Incremental refresh
//!
//! The index remembers, per segment, how many bytes it has consumed
//! (`read_to`, always a line boundary).  [`CacheIndex::refresh`] seeks
//! each segment to its remembered offset and tails only the appended
//! bytes, so the sharded idle-retry loop and the drive monitor poll at
//! a cost proportional to *new* work, not total history.  Newly
//! appearing segments (a sibling shard starting up) are tailed from
//! offset 0.
//!
//! A partially-appended final line (no terminating newline — a sibling
//! writer mid-`write`, or a killed writer's torn tail) is never
//! consumed: `read_to` stops at the last newline, and the line is
//! indexed by a later refresh once its newline lands.
//!
//! # The compaction-generation contract
//!
//! Remembered offsets are only valid while segments are append-only.
//! Any rewrite — [`super::gc`] compaction, pruning, segment removal —
//! bumps the directory's generation marker
//! ([`super::segment::bump_generation`]) *after* taking every segment's
//! writer lock.  `refresh` re-reads the marker (one tiny file) each
//! poll; a changed generation, a vanished segment, or a segment shorter
//! than its remembered offset all trigger one full rescan, after which
//! tailing resumes incrementally.  Live `RunCache` writers hold their
//! segment lock for their whole lifetime, so gc can never rewrite under
//! an open cache — the rescan path exists for lock-free readers
//! ([`CacheWatcher`]) and for caches observing a directory another
//! process compacted between their polls.
//!
//! # Sidecar adoption and segment precedence
//!
//! Compaction leaves a key-presence sidecar (`<segment>.idx`, see
//! [`super::filter`]) next to each segment it writes.  When `refresh`
//! meets a segment it has never read a byte of, it tries to *adopt* a
//! valid sidecar instead of scanning: the segment's covered prefix is
//! marked consumed, its keys are counted without entering the map, and
//! point lookups are answered from the sidecar's bloom filter + fence
//! pointers.  A miss-heavy open therefore skips whole segments.
//!
//! Mixing in-map entries with sidecar-resident ones needs an explicit
//! precedence: each tracked segment carries its *rank* (its position in
//! the sorted segment listing — the same order gc merges in, later
//! names win).  A lookup prefers the highest-rank source; at equal rank
//! the map wins, because in-map entries for a sidecar'd segment can
//! only come from bytes appended *after* the covered prefix, which are
//! newer by append-only construction.

use std::cell::Cell;
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufRead, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::train::RunRecord;

use super::filter::Sidecar;
use super::segment::{for_each_line, list_segments, parse_full_entry, read_generation};

// ------------------------------------------------------------- scanner

/// Metadata extracted from one cache line without materializing the
/// record: the index's unit of work.
pub(crate) struct LineMeta {
    pub(crate) key: String,
    pub(crate) manifest: String,
    pub(crate) ts: u64,
}

/// Structurally validate one cache line and extract `key` / `manifest` /
/// `ts`, *skipping* (not building) the `record` value.
///
/// Accepts exactly the lines [`parse_full_entry`] accepts at the JSON
/// level: full-grammar validation, no trailing garbage, `key` and
/// `manifest` must be strings, `ts` (optional, default 0) a number, and
/// a `record` member must be present.  A line whose `record` is valid
/// JSON of the wrong *shape* is indexed here and rejected at hit time
/// instead — the graceful-degradation path, not the common one.
pub(crate) fn scan_line(line: &str) -> Result<LineMeta> {
    let mut s = Scan { b: line.as_bytes(), i: 0 };
    s.ws();
    s.expect(b'{')?;
    let mut key: Option<String> = None;
    let mut manifest: Option<String> = None;
    let mut ts: Option<f64> = None;
    let mut have_record = false;
    s.ws();
    if s.peek()? == b'}' {
        s.i += 1;
    } else {
        loop {
            s.ws();
            let name = s.string()?;
            s.ws();
            s.expect(b':')?;
            s.ws();
            match name.as_str() {
                "key" => key = Some(s.string()?),
                "manifest" => manifest = Some(s.string()?),
                "ts" => ts = Some(s.number()?),
                "record" => {
                    s.skip_value()?;
                    have_record = true;
                }
                _ => s.skip_value()?,
            }
            s.ws();
            match s.peek()? {
                b',' => s.i += 1,
                b'}' => {
                    s.i += 1;
                    break;
                }
                c => bail!("expected , or }} got {:?} at byte {}", c as char, s.i),
            }
        }
    }
    s.ws();
    if s.i != s.b.len() {
        bail!("trailing characters at byte {}", s.i);
    }
    let key = key.ok_or_else(|| anyhow::anyhow!("missing key \"key\""))?;
    let manifest = manifest.ok_or_else(|| anyhow::anyhow!("missing key \"manifest\""))?;
    if !have_record {
        bail!("missing key \"record\"");
    }
    Ok(LineMeta { key, manifest, ts: ts.unwrap_or(0.0) as u64 })
}

/// A validating JSON *skipper*: same grammar as `util::Json::parse`,
/// but allocates only for the strings the caller asks for.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    /// Parse (and allocate) a string value.
    fn string(&mut self) -> Result<String> {
        let start = self.i;
        self.skip_string()?;
        // the span is known valid; decode via the reference parser so
        // escape semantics can never drift from util::Json
        let span = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| anyhow::anyhow!("non-UTF-8 string at byte {start}: {e}"))?;
        match crate::util::Json::parse(span)? {
            crate::util::Json::Str(s) => Ok(s),
            _ => bail!("not a string at byte {start}"),
        }
    }

    fn skip_string(&mut self) -> Result<()> {
        self.expect(b'"')?;
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f' => {}
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape at byte {}", self.i);
                            }
                            let hex = &self.b[self.i..self.i + 4];
                            if !hex.iter().all(|b| b.is_ascii_hexdigit()) {
                                bail!("bad \\u escape at byte {}", self.i);
                            }
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {}
            }
        }
    }

    fn number(&mut self) -> Result<f64> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        s.parse::<f64>()
            .map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))
    }

    fn skip_number(&mut self) -> Result<()> {
        self.number().map(|_| ())
    }

    fn lit(&mut self, word: &str) -> Result<()> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(())
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn skip_value(&mut self) -> Result<()> {
        match self.peek()? {
            b'{' => self.skip_object(),
            b'[' => self.skip_array(),
            b'"' => self.skip_string(),
            b't' => self.lit("true"),
            b'f' => self.lit("false"),
            b'n' => self.lit("null"),
            _ => self.skip_number(),
        }
    }

    fn skip_array(&mut self) -> Result<()> {
        self.expect(b'[')?;
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.skip_value()?;
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected , or ] got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn skip_object(&mut self) -> Result<()> {
        self.expect(b'{')?;
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.skip_string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            self.skip_value()?;
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(());
                }
                c => bail!("expected , or }} got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

// -------------------------------------------------------------- index

/// Where one key's record lives on disk.  `manifest` is an id into the
/// index's intern table — at 10⁵⁺ keys over a handful of manifests,
/// per-entry `String`s would dominate the index's memory.
#[derive(Clone, Copy)]
pub(crate) struct Loc {
    seg: u32,
    offset: u64,
    /// Line length in bytes, newline excluded (one cache line is far
    /// below 4 GiB; the wire protocol caps frames at 64 MiB already).
    len: u32,
    ts: u64,
    manifest: u32,
}

/// Per-segment tail state.
struct SegTail {
    path: PathBuf,
    /// Bytes consumed so far; always a line boundary (or a sidecar's
    /// covered prefix, which gc ends on a line boundary).
    read_to: u64,
    /// Complete lines consumed (for warning line numbers).
    lines: usize,
    /// Position in the sorted segment listing — the merge-precedence
    /// order (higher rank wins a key collision).  Reassigned on every
    /// refresh.
    rank: u32,
    /// An adopted key-presence sidecar covering `[0, read_to)` at
    /// adoption time; lookups for keys not in the map consult it.
    sidecar: Option<Sidecar>,
}

/// How much work the key-presence sidecars saved — a snapshot of the
/// index's internal counters (see [`CacheWatcher::filter_stats`]).
#[derive(Debug, Clone, Default)]
pub struct FilterStats {
    /// Whole-segment scans avoided by adopting a sidecar on refresh.
    pub segments_skipped: u64,
    /// Point probes a bloom filter answered "definitely absent".
    pub bloom_rejects: u64,
    /// Point probes that read a sidecar's fence-indexed entry block.
    pub fence_probes: u64,
    /// Lookups resolved from sidecar metadata (no segment scan).
    pub sidecar_hits: u64,
}

/// Interior-mutable counters: lookups take `&self`.
#[derive(Default)]
struct FilterCounters {
    segments_skipped: Cell<u64>,
    bloom_rejects: Cell<u64>,
    fence_probes: Cell<u64>,
    sidecar_hits: Cell<u64>,
}

impl FilterCounters {
    fn bump(cell: &Cell<u64>) {
        cell.set(cell.get() + 1);
    }

    fn snapshot(&self) -> FilterStats {
        FilterStats {
            segments_skipped: self.segments_skipped.get(),
            bloom_rejects: self.bloom_rejects.get(),
            fence_probes: self.fence_probes.get(),
            sidecar_hits: self.sidecar_hits.get(),
        }
    }
}

/// A sidecar lookup result, with the rank that decides precedence.
struct SidecarHit {
    rank: u32,
    seg: usize,
    offset: u64,
    len: u32,
    ts: u64,
    /// Id into the *sidecar's own* manifest table, not the index's
    /// intern table.
    manifest: u32,
}

/// The lazy key index over one cache directory.  See the module docs
/// for the refresh / rescan contract.
pub(crate) struct CacheIndex {
    dir: PathBuf,
    segs: Vec<SegTail>,
    by_path: HashMap<PathBuf, u32>,
    keys: HashMap<String, Loc>,
    manifests: Vec<String>,
    manifest_ids: HashMap<String, u32>,
    generation: u64,
    /// Keys visible only through adopted sidecars — exactly
    /// `|∪ sidecar keys \ map keys|`; [`CacheIndex::len`] adds this to
    /// the map size so adopted segments count without being scanned.
    sidecar_only: usize,
    filtering: FilterCounters,
}

impl CacheIndex {
    /// An empty index over `dir`; nothing is scanned until
    /// [`CacheIndex::refresh`] (or [`CacheIndex::track_segment`] for a
    /// writer registering its own fresh segment).
    pub(crate) fn new(dir: &Path) -> CacheIndex {
        CacheIndex {
            dir: dir.to_path_buf(),
            segs: Vec::new(),
            by_path: HashMap::new(),
            keys: HashMap::new(),
            manifests: Vec::new(),
            manifest_ids: HashMap::new(),
            generation: read_generation(dir),
            sidecar_only: 0,
            filtering: FilterCounters::default(),
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.keys.len() + self.sidecar_only
    }

    pub(crate) fn contains(&self, key: &str) -> bool {
        if self.keys.contains_key(key) {
            return true;
        }
        if self.sidecar_probe(key, None, true).is_some() {
            FilterCounters::bump(&self.filtering.sidecar_hits);
            return true;
        }
        false
    }

    pub(crate) fn n_segments(&self) -> usize {
        self.segs.len()
    }

    pub(crate) fn filter_stats(&self) -> FilterStats {
        self.filtering.snapshot()
    }

    /// Probe every adopted sidecar — optionally only those strictly
    /// outranking `min_rank_exclusive` (the rank of an in-map entry
    /// that would otherwise win) — and return the highest-rank hit.
    /// `count` routes the probe through the public stats counters;
    /// internal bookkeeping probes pass `false` so the counters only
    /// reflect lookup traffic.
    fn sidecar_probe(
        &self,
        key: &str,
        min_rank_exclusive: Option<u32>,
        count: bool,
    ) -> Option<SidecarHit> {
        let mut best: Option<SidecarHit> = None;
        for (i, seg) in self.segs.iter().enumerate() {
            let Some(sc) = &seg.sidecar else { continue };
            if min_rank_exclusive.is_some_and(|r| seg.rank <= r) {
                continue;
            }
            if best.as_ref().is_some_and(|b| b.rank > seg.rank) {
                continue;
            }
            if !sc.might_contain(key) {
                if count {
                    FilterCounters::bump(&self.filtering.bloom_rejects);
                }
                continue;
            }
            if count {
                FilterCounters::bump(&self.filtering.fence_probes);
            }
            if let Some((offset, len, ts, manifest)) = sc.lookup(key) {
                best = Some(SidecarHit { rank: seg.rank, seg: i, offset, len, ts, manifest });
            }
        }
        best
    }

    fn intern(&mut self, manifest: &str) -> u32 {
        if let Some(&id) = self.manifest_ids.get(manifest) {
            return id;
        }
        let id = self.manifests.len() as u32;
        self.manifests.push(manifest.to_string());
        self.manifest_ids.insert(manifest.to_string(), id);
        id
    }

    /// The manifest a key was recorded under — an index (or sidecar)
    /// read, no record parse.
    pub(crate) fn manifest_of(&self, key: &str) -> Option<&str> {
        let map_loc = self.keys.get(key);
        let min_rank = map_loc.map(|l| self.segs[l.seg as usize].rank);
        if let Some(hit) = self.sidecar_probe(key, min_rank, true) {
            FilterCounters::bump(&self.filtering.sidecar_hits);
            return self.segs[hit.seg].sidecar.as_ref().and_then(|sc| sc.manifest(hit.manifest));
        }
        map_loc.map(|l| self.manifests[l.manifest as usize].as_str())
    }

    /// The `ts` a key was recorded with (0 for pre-lifecycle lines).
    pub(crate) fn recorded_ts(&self, key: &str) -> Option<u64> {
        let map_loc = self.keys.get(key);
        let min_rank = map_loc.map(|l| self.segs[l.seg as usize].rank);
        if let Some(hit) = self.sidecar_probe(key, min_rank, true) {
            FilterCounters::bump(&self.filtering.sidecar_hits);
            return Some(hit.ts);
        }
        map_loc.map(|l| l.ts)
    }

    /// Segment id for `path`, registering it (tail at 0) if new.
    fn seg_id(&mut self, path: &Path) -> u32 {
        if let Some(&id) = self.by_path.get(path) {
            return id;
        }
        let id = self.segs.len() as u32;
        self.segs.push(SegTail {
            path: path.to_path_buf(),
            read_to: 0,
            lines: 0,
            rank: id,
            sidecar: None,
        });
        self.by_path.insert(path.to_path_buf(), id);
        id
    }

    /// Insert a scanned key, honoring segment precedence: an existing
    /// entry from a *higher*-rank segment wins over the incoming one
    /// (equal rank means same segment, where later lines — larger
    /// offsets — legitimately overwrite).  Keeps `sidecar_only` exact:
    /// a key entering the map that some sidecar already counted moves
    /// from the sidecar-only set to the map.
    fn insert_key(&mut self, key: String, loc: Loc) {
        if let Some(old) = self.keys.get(&key) {
            if self.segs[old.seg as usize].rank > self.segs[loc.seg as usize].rank {
                return;
            }
            self.keys.insert(key, loc);
            return;
        }
        if self.sidecar_only > 0 && self.sidecar_probe(&key, None, false).is_some() {
            self.sidecar_only -= 1;
        }
        self.keys.insert(key, loc);
    }

    /// Drop a key from the map (a failed hit-time load), keeping
    /// `sidecar_only` exact — the key may remain visible via a sidecar.
    fn drop_key(&mut self, key: &str) {
        if self.keys.remove(key).is_some() && self.sidecar_probe(key, None, false).is_some() {
            self.sidecar_only += 1;
        }
    }

    /// Adopt a valid sidecar for a segment no byte of which has been
    /// read: mark its covered prefix consumed and count its keys
    /// without scanning.  Counting is O(1) when the index is otherwise
    /// empty (the common cold-open-after-compaction case); otherwise
    /// the sidecar's entries stream once to count keys nothing else
    /// already covers.
    fn maybe_adopt_sidecar(&mut self, id: usize) {
        if self.segs[id].read_to != 0 || self.segs[id].lines != 0 || self.segs[id].sidecar.is_some()
        {
            return;
        }
        let path = self.segs[id].path.clone();
        let sc = match Sidecar::open(&path) {
            Ok(Some(sc)) => sc,
            Ok(None) => return,
            Err(e) => {
                eprintln!(
                    "run-cache: ignoring malformed sidecar for {}: {e:#}",
                    path.display()
                );
                return;
            }
        };
        if !sc.validate(&path) {
            return;
        }
        let any_adopted = self.segs.iter().any(|s| s.sidecar.is_some());
        let fresh = if self.keys.is_empty() && !any_adopted {
            sc.n_entries() as usize
        } else {
            let mut fresh = 0usize;
            let counted = sc.for_each_entry(|key, _, _, _, _| {
                if !self.keys.contains_key(key) && self.sidecar_probe(key, None, false).is_none() {
                    fresh += 1;
                }
            });
            if counted.is_err() {
                // unreadable entries: fall back to scanning the segment
                return;
            }
            fresh
        };
        FilterCounters::bump(&self.filtering.segments_skipped);
        self.sidecar_only += fresh;
        self.segs[id].read_to = sc.covered_bytes();
        self.segs[id].sidecar = Some(sc);
    }

    /// Register `path` without scanning it — a writer's own segment,
    /// just created or truncated, whose appends will be indexed via
    /// [`CacheIndex::note_local_append`].
    pub(crate) fn track_segment(&mut self, path: &Path) {
        self.seg_id(path);
    }

    /// Merge in whatever changed on disk since the last call, tailing
    /// only appended bytes (one full rescan instead when the compaction
    /// generation moved, a segment vanished, or a segment shrank).
    /// Segments never read before may be *adopted* via their sidecar
    /// instead of scanned — see [`CacheIndex::maybe_adopt_sidecar`].
    /// Returns the number of newly visible keys.
    pub(crate) fn refresh(&mut self) -> usize {
        let before = self.len();
        let listed = match list_segments(&self.dir) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("run-cache: refresh failed: {e:#}");
                return 0;
            }
        };
        let disk_generation = read_generation(&self.dir);
        let mut rescan = disk_generation != self.generation;
        self.generation = disk_generation;
        if !rescan {
            // a tracked segment that disappeared or shrank means a
            // rewrite happened under us (gc from a process that didn't
            // bump the marker is impossible; this is belt-and-braces
            // for hand-edited directories)
            for seg in &self.segs {
                let len = std::fs::metadata(&seg.path).map(|m| m.len()).unwrap_or(0);
                if (!listed.contains(&seg.path) && seg.read_to > 0) || len < seg.read_to {
                    rescan = true;
                    break;
                }
            }
        }
        if rescan {
            self.keys.clear();
            self.segs.clear();
            self.by_path.clear();
            self.sidecar_only = 0;
        }
        for (rank, path) in listed.iter().enumerate() {
            let id = self.seg_id(path) as usize;
            // ranks track the *current* sorted listing: a new segment
            // appearing early in sort order shifts everyone after it
            self.segs[id].rank = rank as u32;
            self.maybe_adopt_sidecar(id);
            self.tail_segment(id);
        }
        // saturating: a rescan after a *pruning* gc legitimately shrinks
        // the key set, and "newly visible" is then zero, not underflow
        self.len().saturating_sub(before)
    }

    /// Read and index `[read_to, len)` of one segment, consuming only
    /// complete (newline-terminated) lines.  Streams line by line — a
    /// cold scan of a multi-GB compacted segment must cost O(one line)
    /// of buffer, not a whole-file slurp (the index's memory contract
    /// is O(keys), including transiently).
    fn tail_segment(&mut self, id: usize) {
        let path = self.segs[id].path.clone();
        let start = self.segs[id].read_to;
        let Ok(mut f) = File::open(&path) else {
            // vanished mid-poll; the next refresh's liveness check
            // turns this into a rescan
            return;
        };
        let len = match f.metadata() {
            Ok(m) => m.len(),
            Err(_) => return,
        };
        if len <= start || f.seek(SeekFrom::Start(start)).is_err() {
            return;
        }
        // take() bounds the scan: bytes appended *while* we read are
        // picked up by the next refresh at a clean line boundary
        let mut reader = std::io::BufReader::new(f.take(len - start));
        let mut consumed = 0u64;
        let mut buf = Vec::new();
        loop {
            buf.clear();
            let n = match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break,
                Ok(n) => n,
                Err(e) => {
                    eprintln!("run-cache: stopping scan of {}: {e}", path.display());
                    break;
                }
            };
            if buf.last() != Some(&b'\n') {
                // unterminated tail (a sibling mid-append, or a killed
                // writer): defer — never consume a torn line
                break;
            }
            let offset = start + consumed;
            consumed += n as u64;
            self.segs[id].lines += 1;
            let raw = &buf[..buf.len() - 1];
            let text = String::from_utf8_lossy(raw);
            let line = text.trim_end_matches('\r');
            if line.trim().is_empty() {
                continue;
            }
            match scan_line(line) {
                Ok(meta) => {
                    let manifest = self.intern(&meta.manifest);
                    let loc = Loc {
                        seg: id as u32,
                        offset,
                        len: raw.len() as u32,
                        ts: meta.ts,
                        manifest,
                    };
                    self.insert_key(meta.key, loc);
                }
                Err(e) => {
                    eprintln!(
                        "run-cache: skipping corrupt line {} of {}: {e:#}",
                        self.segs[id].lines,
                        path.display()
                    );
                }
            }
        }
        self.segs[id].read_to = start + consumed;
    }

    /// Index a line this process just appended to its own segment (at
    /// the segment's current tail), without re-reading it from disk.
    /// `line_len` excludes the trailing newline.
    pub(crate) fn note_local_append(
        &mut self,
        path: &Path,
        key: &str,
        manifest: &str,
        ts: u64,
        line_len: usize,
    ) {
        let id = self.seg_id(path);
        let offset = self.segs[id as usize].read_to;
        let manifest = self.intern(manifest);
        self.insert_key(
            key.to_string(),
            Loc { seg: id, offset, len: line_len as u32, ts, manifest },
        );
        self.segs[id as usize].read_to = offset + line_len as u64 + 1;
        self.segs[id as usize].lines += 1;
    }

    /// A local append failed partway: re-align the segment's tail with
    /// the bytes actually on disk so later offsets stay truthful.
    pub(crate) fn resync_local(&mut self, path: &Path) {
        let id = self.seg_id(path) as usize;
        if let Ok(m) = std::fs::metadata(path) {
            self.segs[id].read_to = m.len();
        }
    }

    /// Parse the record for `key` from disk (the hit path; the caller
    /// memoizes).  The winner may live behind an adopted sidecar rather
    /// than the in-memory map — whichever has the higher segment rank
    /// answers.  A record that no longer parses — hand-edited file,
    /// offset drift — is dropped from the index with a warning and
    /// reported as a miss, mirroring the eager reader's corrupt-line
    /// tolerance.
    pub(crate) fn load(&mut self, key: &str) -> Option<RunRecord> {
        let map_loc = self.keys.get(key).copied();
        let min_rank = map_loc.map(|l| self.segs[l.seg as usize].rank);
        if let Some(hit) = self.sidecar_probe(key, min_rank, true) {
            FilterCounters::bump(&self.filtering.sidecar_hits);
            let path = self.segs[hit.seg].path.clone();
            let parsed = read_span(&path, hit.offset, hit.len as usize).and_then(|raw| {
                let text = String::from_utf8_lossy(&raw);
                parse_full_entry(text.trim_end_matches(['\n', '\r']))
            });
            match parsed {
                Ok(e) if e.key == key => return Some(e.record),
                Ok(e) => eprintln!(
                    "run-cache: sidecar entry for {key} resolved to {} in {} (stale \
                     sidecar?); falling back to the scanned index",
                    e.key,
                    path.display()
                ),
                Err(err) => eprintln!(
                    "run-cache: could not load {key} via sidecar from {}: {err:#}; \
                     falling back to the scanned index",
                    path.display()
                ),
            }
        }
        let loc = map_loc?;
        let path = self.segs[loc.seg as usize].path.clone();
        let parsed = read_span(&path, loc.offset, loc.len as usize).and_then(|raw| {
            let text = String::from_utf8_lossy(&raw);
            parse_full_entry(text.trim_end_matches(['\n', '\r']))
        });
        match parsed {
            Ok(e) if e.key == key => Some(e.record),
            Ok(e) => {
                eprintln!(
                    "run-cache: index entry for {key} resolved to {} in {} (stale \
                     offset?); dropping it",
                    e.key,
                    path.display()
                );
                self.drop_key(key);
                None
            }
            Err(err) => {
                eprintln!(
                    "run-cache: could not load {key} from {}: {err:#}; dropping it",
                    path.display()
                );
                self.drop_key(key);
                None
            }
        }
    }
}

fn read_span(path: &Path, offset: u64, len: usize) -> Result<Vec<u8>> {
    let mut f = File::open(path)?;
    f.seek(SeekFrom::Start(offset))?;
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    Ok(buf)
}

// ------------------------------------------------------------- watcher

/// A read-only, lock-free incremental observer of a cache directory —
/// the shard driver's progress monitor.  Each [`CacheWatcher::poll`]
/// costs O(bytes appended since the last poll) instead of a full
/// re-read of every segment; compaction under the watcher is handled
/// by the generation contract (one full rescan, then incremental
/// again).  Takes no locks, so a line being appended concurrently is
/// simply picked up one poll later.
pub struct CacheWatcher {
    idx: CacheIndex,
}

impl CacheWatcher {
    pub fn new(dir: &Path) -> CacheWatcher {
        CacheWatcher { idx: CacheIndex::new(dir) }
    }

    /// Tail whatever was appended since the last poll; returns the
    /// number of newly visible keys.
    pub fn poll(&mut self) -> usize {
        self.idx.refresh()
    }

    /// Unique run keys seen across all segments (after the last poll).
    pub fn unique_keys(&self) -> usize {
        self.idx.len()
    }

    /// Segments currently tracked (after the last poll).
    pub fn segments(&self) -> usize {
        self.idx.n_segments()
    }

    /// Counters for how much work the per-segment sidecar filters have
    /// saved this watcher (segments adopted without a scan, bloom
    /// rejects, fence probes, sidecar-served lookups).
    pub fn filter_stats(&self) -> FilterStats {
        self.idx.filter_stats()
    }
}

// -------------------------------------------------------------- stats

/// Per-segment summary from [`stats`].
#[derive(Debug, Clone)]
pub struct SegmentStats {
    pub name: String,
    pub entries: usize,
    pub corrupt: usize,
    pub bytes: u64,
}

/// Whole-directory summary from [`stats`].
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub segments: Vec<SegmentStats>,
    /// Total lines parsed across segments (including cross-segment
    /// duplicates of one key).
    pub total_entries: usize,
    pub unique_keys: usize,
    /// `total_entries - unique_keys`: same key recorded in several
    /// segments (compaction removes these).
    pub duplicate_keys: usize,
    pub corrupt_lines: usize,
    pub total_bytes: u64,
    /// Unique keys per manifest name.
    pub per_manifest: std::collections::BTreeMap<String, usize>,
    pub oldest_ts: Option<u64>,
    pub newest_ts: Option<u64>,
}

/// Summarize a cache directory without taking any locks (read-only; a
/// line being appended concurrently may be counted as corrupt).
///
/// Streams every line through the key scanner (`scan_line`) — no
/// record is ever materialized, so `repro cache stats` on a 10⁵-entry
/// directory allocates per *key*, not per curve point.
pub fn stats(dir: &Path) -> Result<CacheStats> {
    let mut st = CacheStats::default();
    let mut manifest_of: HashMap<String, String> = HashMap::new();
    for seg in list_segments(dir)? {
        let bytes = std::fs::metadata(&seg).map(|m| m.len()).unwrap_or(0);
        let (mut loaded, mut corrupt) = (0usize, 0usize);
        for_each_line(&seg, |line| {
            if line.trim().is_empty() {
                return;
            }
            match scan_line(line) {
                Ok(meta) => {
                    loaded += 1;
                    if meta.ts > 0 {
                        st.oldest_ts = Some(st.oldest_ts.map_or(meta.ts, |t| t.min(meta.ts)));
                        st.newest_ts = Some(st.newest_ts.map_or(meta.ts, |t| t.max(meta.ts)));
                    }
                    manifest_of.insert(meta.key, meta.manifest);
                }
                Err(_) => corrupt += 1,
            }
        })?;
        st.total_entries += loaded;
        st.corrupt_lines += corrupt;
        st.total_bytes += bytes;
        st.segments.push(SegmentStats {
            name: seg.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string(),
            entries: loaded,
            corrupt,
            bytes,
        });
    }
    st.unique_keys = manifest_of.len();
    st.duplicate_keys = st.total_entries - st.unique_keys;
    for manifest in manifest_of.into_values() {
        *st.per_manifest.entry(manifest).or_insert(0) += 1;
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_line_matches_the_eager_parser_on_well_formed_lines() {
        let rec = RunRecord {
            label: "l\"esc\\ape\nü".to_string(),
            train_curve: vec![(1, 2.5), (2, f64::NAN)],
            valid_curve: vec![(2, 2.25)],
            final_valid_loss: 2.25,
            rms_curves: std::collections::BTreeMap::from([(
                "w.emb".to_string(),
                vec![(1u64, 0.5f64)],
            )]),
            final_rms: vec![("w.emb".to_string(), 0.5)],
            diverged: false,
            wall_seconds: 0.125,
        };
        let line = super::super::segment::entry_line("00ff00ff00ff00ff", "man-ü", 1234, &rec);
        let meta = scan_line(&line).unwrap();
        let full = parse_full_entry(&line).unwrap();
        assert_eq!(meta.key, full.key);
        assert_eq!(meta.manifest, full.manifest);
        assert_eq!(meta.ts, full.ts);
    }

    #[test]
    fn scan_line_defaults_missing_ts_to_zero() {
        let meta = scan_line(r#"{"key":"aa","manifest":"m","record":{}}"#).unwrap();
        assert_eq!(meta.ts, 0);
    }

    #[test]
    fn scan_line_rejects_what_the_eager_parser_rejects() {
        for bad in [
            "",
            "not json",
            r#"{"key":"aa","manifest":"m","record":{}"#, // unterminated
            r#"{"key":"aa","manifest":"m","record":{}} trailing"#,
            r#"{"key":12,"manifest":"m","record":{}}"#, // key not a string
            r#"{"key":"aa","manifest":5,"record":{}}"#,
            r#"{"key":"aa","manifest":"m","record":{},"ts":"soon"}"#, // ts not a number
            r#"{"key":"aa","manifest":"m"}"#,           // no record
            r#"{"manifest":"m","record":{}}"#,          // no key
            r#"{"key":"aa","manifest":"m","record":{"x":}}"#, // bad nested value
            r#"[1,2,3]"#,
        ] {
            assert!(scan_line(bad).is_err(), "scanner accepted {bad:?}");
            assert!(parse_full_entry(bad).is_err(), "eager parser accepted {bad:?}");
        }
    }

    #[test]
    fn scan_line_skips_arbitrary_nested_values() {
        let line = r#"{"extra":[{"deep":[null,true,false,-1e-3,"séq"]},[]],"key":"kk","manifest":"mm","record":{"a":[1,[2,[3]]],"b":"x"},"ts":7}"#;
        let meta = scan_line(line).unwrap();
        assert_eq!((meta.key.as_str(), meta.manifest.as_str(), meta.ts), ("kk", "mm", 7));
    }
}
