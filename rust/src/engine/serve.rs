//! The `repro serve` control plane: a long-lived daemon that owns one
//! [`Engine`] and exposes it over a JSONL RPC socket.
//!
//! # Protocol
//!
//! Clients dial the endpoint ([`Endpoint`] syntax: `host:port` or
//! `unix:/path`), read the daemon's `umup-serve` hello frame (see
//! [`wire::serve_hello_line`] — deliberately distinct from the worker
//! hello so cross-wired sockets fail their handshake), then exchange
//! id-tagged request/reply frames ([`wire::rpc_request_line`] /
//! [`wire::decode_rpc_reply`]).  Verbs:
//!
//! * `submit {jobs: [..]}` — job objects in the worker wire-frame
//!   encoding ([`wire::encode_job`]); replies `{sweep, total}` with a
//!   fresh sweep id.
//! * `status {sweep?}` — one sweep's counters, or every live sweep
//!   plus `cache_records` when `sweep` is omitted.
//! * `cancel {sweep}` — unqueue the sweep's pending jobs; in-flight
//!   jobs finish and are cached, so a cancelled sweep never leaves the
//!   cache inconsistent.
//! * `cache-stats` — refresh and report the run cache (records, and
//!   when the engine persists to disk, watcher-side unique keys and
//!   segment count).
//! * `events` — subscribe to the engine's telemetry bus
//!   ([`crate::engine::events`]): the connection switches to *stream
//!   mode* and every event envelope is re-served as an ok-reply frame
//!   tagged with the subscribing request's id, until the client hangs
//!   up or the daemon exits.  `repro ctl watch` is the tailing client.
//! * `shutdown` — cancel and drain every sweep, reply, then exit the
//!   daemon.
//!
//! Unknown verbs and bad params come back as tagged error replies; the
//! connection stays usable.  Each accepted client gets its own thread,
//! so a slow client never blocks another.
//!
//! # Threading
//!
//! In `xla` builds the [`Engine`] is `!Sync` (it keeps caller-thread
//! session state), so the daemon funnels every verb through one
//! *engine-owner thread* that constructs the engine itself, receives
//! commands over a channel, and pumps live [`SweepHandle`]s between
//! commands (outcomes drain and counters advance even while no client
//! is connected).  Client threads only parse frames and wait on their
//! reply channel — no engine state crosses threads.
//!
//! The owner loop's command wait uses an [`IdleBackoff`]: each quiet
//! round doubles the poll timeout from [`IDLE_BACKOFF_FLOOR`] up to
//! [`IDLE_BACKOFF_CAP`], and any activity — a command, a pumped sweep
//! outcome — snaps it back to the floor.  A busy daemon keeps the old
//! 10 ms-class responsiveness; an idle one stops spinning its core at
//! 100 Hz.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::data::{Corpus, CorpusConfig};
use crate::runtime::{Manifest, Registry, Spec};
use crate::util::Json;

use super::backend::{wire, Backend, Endpoint, Listener};
use super::cache::{corpus_json, CacheWatcher};
use super::{Engine, EngineConfig, EngineJob, EventStream, SweepHandle};

/// Floor of the engine-owner loop's idle poll timeout (and the wait it
/// snaps back to on any activity).
pub const IDLE_BACKOFF_FLOOR: Duration = Duration::from_millis(1);

/// Ceiling of the idle poll timeout: the longest a quiet daemon sleeps
/// between looking for commands (and, equivalently, the worst-case
/// extra latency the first command after a long lull can see).
pub const IDLE_BACKOFF_CAP: Duration = Duration::from_millis(250);

/// Exponential idle backoff for a poll loop: every quiet round doubles
/// the next wait ([`IDLE_BACKOFF_FLOOR`] → [`IDLE_BACKOFF_CAP`]), and
/// [`IdleBackoff::on_activity`] snaps back to the floor.  Replaces the
/// old fixed 10 ms `recv_timeout` spin, which burned a core at 100 Hz
/// on a daemon with nothing to do.
#[derive(Debug)]
pub struct IdleBackoff {
    current: Duration,
}

impl IdleBackoff {
    /// Start at the floor.
    pub fn new() -> IdleBackoff {
        IdleBackoff { current: IDLE_BACKOFF_FLOOR }
    }

    /// The wait for the next idle poll.  Each call doubles the one
    /// after it, up to [`IDLE_BACKOFF_CAP`]; never below the floor.
    pub fn next_wait(&mut self) -> Duration {
        let wait = self.current;
        self.current = (self.current * 2).min(IDLE_BACKOFF_CAP);
        wait
    }

    /// Something happened: snap the next wait back to the floor.
    pub fn on_activity(&mut self) {
        self.current = IDLE_BACKOFF_FLOOR;
    }

    /// The wait the next [`IdleBackoff::next_wait`] call would return.
    pub fn current(&self) -> Duration {
        self.current
    }
}

impl Default for IdleBackoff {
    fn default() -> Self {
        IdleBackoff::new()
    }
}

/// Construction options for [`serve`].
pub struct ServeOptions {
    /// Where to listen: `host:port` (port 0 binds ephemeral) or
    /// `unix:/path`.
    pub endpoint: String,
    /// The engine the daemon owns (workers, cache dir, resume, …).
    pub engine: EngineConfig,
    /// Artifact registry root; manifests named by submitted jobs are
    /// resolved here first.
    pub artifacts: PathBuf,
    /// Generate real corpus tokens (and require real manifests) for
    /// submitted jobs — needed for in-process execution.  Out-of-process
    /// backends leave this off: workers regenerate corpora and load
    /// manifests by name on their side, so the daemon only needs the
    /// content addresses.
    pub materialize_corpora: bool,
    /// Shared-secret auth (`--token` / `UMUP_TOKEN`): when set, the
    /// daemon's hello advertises auth and every client must send a
    /// matching token frame before its first verb; a mismatch gets a
    /// tagged error and a hang-up.  `None` keeps the socket open.
    pub token: Option<String>,
    /// Graceful-drain flag (wired to [`crate::util::signal`] by `repro
    /// serve`, or flipped directly in tests): when it goes true the
    /// daemon runs the `shutdown` verb's drain — cancel queued jobs,
    /// let in-flight ones finish and persist — then [`serve`] returns.
    pub drain: Option<Arc<AtomicBool>>,
}

/// Run the daemon until a `shutdown` verb arrives.  `on_ready` fires
/// once with the bound endpoint (the real port when binding `:0`)
/// after the engine has passed its health probe.
pub fn serve(
    opts: ServeOptions,
    backend: Arc<dyn Backend>,
    on_ready: impl FnOnce(&str),
) -> Result<()> {
    let ep = Endpoint::parse(&opts.endpoint)?;
    let listener = Listener::bind(&ep)?;
    let desc = listener.local_desc();
    let stop = Arc::new(AtomicBool::new(false));
    let token = opts.token.clone();
    let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
    let (boot_tx, boot_rx) = mpsc::channel::<Result<(), String>>();
    let engine_thread = {
        let cfg = opts.engine.clone();
        let artifacts = opts.artifacts.clone();
        let materialize = opts.materialize_corpora;
        let stop = Arc::clone(&stop);
        let drain = opts.drain.clone();
        let dial_back = desc.clone();
        std::thread::spawn(move || {
            engine_owner_loop(
                cfg,
                backend,
                artifacts,
                materialize,
                cmd_rx,
                boot_tx,
                stop,
                drain,
                dial_back,
            )
        })
    };
    match boot_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            let _ = engine_thread.join();
            bail!("serve: engine failed to start: {e}");
        }
        Err(_) => {
            let _ = engine_thread.join();
            bail!("serve: engine thread died during startup");
        }
    }
    on_ready(&desc);
    loop {
        let accepted = listener.accept();
        if stop.load(Ordering::SeqCst) {
            // the shutdown path self-dials to unblock this accept; the
            // connection (if any) is dropped unserved
            break;
        }
        match accepted {
            Ok((r, w, _peer)) => {
                let tx = cmd_tx.clone();
                let token = token.clone();
                std::thread::spawn(move || {
                    if let Err(e) = client_loop(BufReader::new(r), w, tx, token) {
                        eprintln!("serve: client connection error: {e:#}");
                    }
                });
            }
            Err(e) => eprintln!("serve: accept failed: {e:#}"),
        }
    }
    drop(cmd_tx);
    engine_thread.join().map_err(|_| anyhow!("serve: engine thread panicked"))?;
    Ok(())
}

// ------------------------------------------------------------ commands

enum Cmd {
    Submit { jobs: Vec<wire::WireJob>, reply: mpsc::Sender<Result<Json, String>> },
    Status { sweep: Option<u64>, reply: mpsc::Sender<Result<Json, String>> },
    Cancel { sweep: u64, reply: mpsc::Sender<Result<Json, String>> },
    CacheStats { reply: mpsc::Sender<Result<Json, String>> },
    /// Subscribe to the engine's event bus; the reply carries the
    /// consuming end, which the client thread then drains onto its
    /// socket.
    Subscribe { reply: mpsc::Sender<EventStream> },
    Shutdown { reply: mpsc::Sender<Result<Json, String>> },
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(x: usize) -> Json {
    Json::Num(x as f64)
}

// --------------------------------------------------- client connection

/// One accepted client: hello (advertising auth when a token is
/// configured), the token gate, then request/reply frames until EOF.
fn client_loop(
    mut input: impl BufRead,
    mut output: impl Write,
    tx: mpsc::Sender<Cmd>,
    token: Option<String>,
) -> Result<()> {
    wire::write_frame(&mut output, &wire::serve_hello_line_auth(token.is_some()))?;
    if let Some(expect) = token.as_deref() {
        // nothing is served before the token checks out; a mismatch
        // gets a tagged error (id 0 — no request exists yet) + hang-up
        let Some(line) = wire::read_frame(&mut input)? else {
            return Ok(());
        };
        if let Err(e) = wire::check_token_frame(&line, expect) {
            let _ = wire::write_frame(&mut output, &wire::rpc_err_line(0, &format!("{e:#}")));
            return Ok(());
        }
    }
    while let Some(line) = wire::read_frame(&mut input)? {
        let req = match wire::decode_rpc_request(&line) {
            Ok(r) => r,
            Err(e) => {
                // a malformed frame means the stream can't be trusted:
                // answer (id 0 — the real one is unknowable) and hang up
                let _ = wire::write_frame(&mut output, &wire::rpc_err_line(0, &format!("{e:#}")));
                break;
            }
        };
        // `events` flips the connection into stream mode: frames flow
        // one way (bus → client) until one side hangs up, so it cannot
        // go through the one-reply dispatch round trip below
        if req.verb == "events" {
            let (sub_tx, sub_rx) = mpsc::channel();
            if tx.send(Cmd::Subscribe { reply: sub_tx }).is_err() {
                let frame = wire::rpc_err_line(req.id, "server is shutting down");
                let _ = wire::write_frame(&mut output, &frame);
                break;
            }
            let Ok(stream) = sub_rx.recv() else {
                let frame = wire::rpc_err_line(req.id, "server dropped the request");
                let _ = wire::write_frame(&mut output, &frame);
                break;
            };
            // each envelope rides the existing id-tagged reply wire:
            // the serialized line is spliced raw, never re-encoded
            while let Some(env) = stream.recv() {
                let frame = wire::rpc_ok_line(req.id, &Json::Raw(env.line()));
                if wire::write_frame(&mut output, &frame).is_err() {
                    break;
                }
            }
            break;
        }
        let frame = match dispatch(&tx, &req) {
            Ok(result) => wire::rpc_ok_line(req.id, &result),
            Err(e) => wire::rpc_err_line(req.id, &e),
        };
        wire::write_frame(&mut output, &frame)?;
    }
    Ok(())
}

/// Parse one request into a [`Cmd`], round-trip it through the engine
/// owner, and return the verb's result.
fn dispatch(tx: &mpsc::Sender<Cmd>, req: &wire::RpcRequest) -> Result<Json, String> {
    let (reply_tx, reply_rx) = mpsc::channel();
    let cmd = match req.verb.as_str() {
        "submit" => {
            let arr = req
                .params
                .get("jobs")
                .and_then(|j| Ok(j.as_arr()?.to_vec()))
                .map_err(|e| format!("submit params: {e:#}"))?;
            let mut jobs = Vec::with_capacity(arr.len());
            for el in &arr {
                jobs.push(
                    wire::decode_job(&el.dump()).map_err(|e| format!("submit job: {e:#}"))?,
                );
            }
            Cmd::Submit { jobs, reply: reply_tx }
        }
        "status" => {
            let sweep = match req.params.get("sweep") {
                Ok(s) => {
                    Some(s.as_usize().map_err(|e| format!("status params: {e:#}"))? as u64)
                }
                Err(_) => None,
            };
            Cmd::Status { sweep, reply: reply_tx }
        }
        "cancel" => {
            let sweep = req
                .params
                .get("sweep")
                .and_then(|s| s.as_usize())
                .map_err(|e| format!("cancel params: {e:#}"))? as u64;
            Cmd::Cancel { sweep, reply: reply_tx }
        }
        "cache-stats" => Cmd::CacheStats { reply: reply_tx },
        "shutdown" => Cmd::Shutdown { reply: reply_tx },
        other => {
            return Err(format!(
                "unknown verb {other:?} (expected \
                 submit/status/cancel/cache-stats/events/shutdown)"
            ))
        }
    };
    tx.send(cmd).map_err(|_| "server is shutting down".to_string())?;
    reply_rx.recv().map_err(|_| "server dropped the request".to_string())?
}

// ----------------------------------------------------- engine owner

#[allow(clippy::too_many_arguments)]
fn engine_owner_loop(
    cfg: EngineConfig,
    backend: Arc<dyn Backend>,
    artifacts: PathBuf,
    materialize: bool,
    cmd_rx: mpsc::Receiver<Cmd>,
    boot_tx: mpsc::Sender<Result<(), String>>,
    stop: Arc<AtomicBool>,
    drain: Option<Arc<AtomicBool>>,
    dial_back: String,
) {
    let cache_dir = cfg.cache_dir.clone();
    let engine = match Engine::with_backend(cfg, backend) {
        Ok(e) => {
            let _ = boot_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = boot_tx.send(Err(format!("{e:#}")));
            return;
        }
    };
    let registry = Registry::open(&artifacts).ok();
    let mut synthetic: HashMap<String, Arc<Manifest>> = HashMap::new();
    let mut corpora: HashMap<String, Arc<Corpus>> = HashMap::new();
    let mut sweeps: BTreeMap<u64, SweepHandle> = BTreeMap::new();
    let mut watcher = cache_dir.as_deref().map(CacheWatcher::new);
    let mut next_sweep: u64 = 1;
    let mut backoff = IdleBackoff::new();
    loop {
        // a signal-initiated drain is the `shutdown` verb minus the
        // reply: checked here (not in a monitor thread, which would
        // hold a cmd sender and could deadlock the final join) — the
        // recv_timeout below caps at IDLE_BACKOFF_CAP, bounding drain
        // latency to one idle round
        if drain.as_ref().map_or(false, |d| d.load(Ordering::SeqCst)) {
            for h in sweeps.values_mut() {
                h.cancel();
            }
            for h in sweeps.values_mut() {
                while h.recv().is_some() {}
            }
            eprintln!("serve: drain signal received; {} sweeps drained", sweeps.len());
            stop.store(true, Ordering::SeqCst);
            // unblock the accept loop so serve() can return
            if let Ok(ep) = Endpoint::parse(&dial_back) {
                let _ = ep.connect();
            }
            break;
        }
        // quiet rounds back the poll timeout off exponentially; any
        // command (below) or pumped outcome (loop tail) resets it
        let cmd = match cmd_rx.recv_timeout(backoff.next_wait()) {
            Ok(cmd) => {
                backoff.on_activity();
                Some(cmd)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        match cmd {
            Some(Cmd::Submit { jobs, reply }) => {
                let r = do_submit(
                    &engine,
                    registry.as_ref(),
                    &mut synthetic,
                    &mut corpora,
                    materialize,
                    jobs,
                    &mut sweeps,
                    &mut next_sweep,
                );
                let _ = reply.send(r);
            }
            Some(Cmd::Status { sweep, reply }) => {
                let _ = reply.send(do_status(&engine, &sweeps, sweep));
            }
            Some(Cmd::Cancel { sweep, reply }) => {
                let r = match sweeps.get_mut(&sweep) {
                    Some(h) => {
                        h.cancel();
                        Ok(obj(vec![("cancelled", Json::Bool(true)), ("sweep", num(sweep as usize))]))
                    }
                    None => Err(format!("no such sweep {sweep}")),
                };
                let _ = reply.send(r);
            }
            Some(Cmd::CacheStats { reply }) => {
                engine.refresh_cache();
                let mut pairs = vec![("records", num(engine.cache_len()))];
                if let Some(w) = watcher.as_mut() {
                    w.poll();
                    pairs.push(("segments", num(w.segments())));
                    pairs.push(("unique_keys", num(w.unique_keys())));
                }
                let _ = reply.send(Ok(obj(pairs)));
            }
            Some(Cmd::Subscribe { reply }) => {
                // capacity bounds a stalled watcher's damage: once its
                // buffer fills, its events drop (counted on the bus)
                // instead of backing up into publishers
                let _ = reply.send(engine.events().subscribe(1024));
            }
            Some(Cmd::Shutdown { reply }) => {
                // cancel everything queued, then drain fully: in-flight
                // jobs complete and are cached before the daemon exits
                for h in sweeps.values_mut() {
                    h.cancel();
                }
                for h in sweeps.values_mut() {
                    while h.recv().is_some() {}
                }
                let _ = reply.send(Ok(obj(vec![
                    ("shutdown", Json::Bool(true)),
                    ("sweeps_drained", num(sweeps.len())),
                ])));
                stop.store(true, Ordering::SeqCst);
                // unblock the accept loop so serve() can return
                if let Ok(ep) = Endpoint::parse(&dial_back) {
                    let _ = ep.connect();
                }
                break;
            }
            None => {}
        }
        // pump live sweeps between commands: outcomes drain (the worker
        // already cached them) and the per-sweep counters stay current
        let mut pumped = false;
        for h in sweeps.values_mut() {
            while h.try_recv().is_some() {
                pumped = true;
            }
        }
        if pumped {
            backoff.on_activity();
        }
    }
    // dropping the engine joins its workers
    drop(engine);
}

#[allow(clippy::too_many_arguments)]
fn do_submit(
    engine: &Engine,
    registry: Option<&Registry>,
    synthetic: &mut HashMap<String, Arc<Manifest>>,
    corpora: &mut HashMap<String, Arc<Corpus>>,
    materialize: bool,
    jobs: Vec<wire::WireJob>,
    sweeps: &mut BTreeMap<u64, SweepHandle>,
    next_sweep: &mut u64,
) -> Result<Json, String> {
    let mut engine_jobs = Vec::with_capacity(jobs.len());
    for wj in jobs {
        let man = resolve_manifest(registry, synthetic, materialize, &wj.manifest)?;
        let corpus = resolve_corpus(corpora, materialize, wj.corpus);
        let job = EngineJob::new(man, corpus, wj.config, Vec::new());
        // the run key is recomputed server-side; a mismatch means the
        // client and daemon disagree on the job's identity (reject the
        // whole submit rather than cache under a surprising address)
        if job.key() != wj.key {
            return Err(format!(
                "job key mismatch for {:?}: client sent {}, daemon computed {}",
                job.config.label,
                wj.key,
                job.key()
            ));
        }
        engine_jobs.push(job);
    }
    let total = engine_jobs.len();
    let handle = engine.submit(engine_jobs);
    let id = *next_sweep;
    *next_sweep += 1;
    sweeps.insert(id, handle);
    Ok(obj(vec![("sweep", num(id as usize)), ("total", num(total))]))
}

fn resolve_manifest(
    registry: Option<&Registry>,
    synthetic: &mut HashMap<String, Arc<Manifest>>,
    materialize: bool,
    name: &str,
) -> Result<Arc<Manifest>, String> {
    if let Some(reg) = registry {
        if let Ok(m) = reg.manifest(name) {
            return Ok(m);
        }
    }
    if materialize {
        return Err(format!(
            "manifest {name:?} not found in the artifact registry (in-process execution \
             needs real artifacts; out-of-process workers resolve manifests themselves)"
        ));
    }
    Ok(Arc::clone(
        synthetic.entry(name.to_string()).or_insert_with(|| Arc::new(synthetic_manifest(name))),
    ))
}

/// A shell manifest for out-of-process execution: only the *name* feeds
/// the run key ([`crate::engine::run_key`] hashes manifest name, corpus
/// config and canonical run config), and workers load the real artifact
/// by name on their side — so a placeholder keeps every content address
/// intact without requiring artifacts on the daemon host.
fn synthetic_manifest(name: &str) -> Manifest {
    Manifest {
        name: name.to_string(),
        dir: PathBuf::from("."),
        spec: Spec {
            width: 32,
            depth: 2,
            batch: 4,
            seq: 16,
            vocab: 64,
            head_dim: 16,
            trainable_norms: false,
        },
        tensors: vec![],
        n_params: 0,
        state_ext_len: 1,
        loss_offset: 0,
        rms_offset: 1,
        scale_sites: BTreeMap::new(),
        n_scale_sites: 0,
        quant_sites: BTreeMap::new(),
        n_quant_sites: 0,
        rms_sites: vec![],
    }
}

fn resolve_corpus(
    corpora: &mut HashMap<String, Arc<Corpus>>,
    materialize: bool,
    config: CorpusConfig,
) -> Arc<Corpus> {
    let key = corpus_json(&config).dump();
    Arc::clone(corpora.entry(key).or_insert_with(|| {
        Arc::new(if materialize {
            Corpus::generate(config)
        } else {
            // out-of-process workers regenerate tokens from the config;
            // the daemon only hashes it into run keys
            Corpus { config, tokens: Vec::new(), n_train: 0 }
        })
    }))
}

fn sweep_json(id: u64, h: &SweepHandle) -> Json {
    obj(vec![
        ("cache_hits", num(h.cache_hits)),
        ("cancelled", num(h.cancelled)),
        ("deduped", num(h.deduped)),
        ("done", Json::Bool(h.is_done())),
        ("emitted", num(h.emitted())),
        ("executed", num(h.executed)),
        ("failed", num(h.failed)),
        ("skipped", num(h.skipped)),
        ("sweep", num(id as usize)),
        ("total", num(h.len())),
    ])
}

fn do_status(
    engine: &Engine,
    sweeps: &BTreeMap<u64, SweepHandle>,
    sweep: Option<u64>,
) -> Result<Json, String> {
    match sweep {
        Some(id) => match sweeps.get(&id) {
            Some(h) => Ok(sweep_json(id, h)),
            None => Err(format!("no such sweep {id}")),
        },
        None => {
            let arr: Vec<Json> = sweeps.iter().map(|(id, h)| sweep_json(*id, h)).collect();
            Ok(obj(vec![
                ("cache_records", num(engine.cache_len())),
                ("sweeps", Json::Arr(arr)),
            ]))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::MockBackend;

    #[test]
    fn idle_backoff_doubles_to_cap_and_snaps_back_on_activity() {
        let mut b = IdleBackoff::new();
        assert_eq!(b.next_wait(), IDLE_BACKOFF_FLOOR);
        let mut prev = IDLE_BACKOFF_FLOOR;
        for _ in 0..16 {
            let w = b.next_wait();
            assert!(w >= prev, "idle waits must be monotone");
            assert!(w <= IDLE_BACKOFF_CAP, "idle waits must respect the cap");
            prev = w;
        }
        assert_eq!(b.current(), IDLE_BACKOFF_CAP, "long lulls settle at the cap");
        b.on_activity();
        assert_eq!(b.next_wait(), IDLE_BACKOFF_FLOOR, "activity snaps to the floor");
    }

    /// The owner loop's wait primitive — an empty command channel
    /// polled under [`IdleBackoff`] — must actually *block* for at
    /// least the backoff floor on every quiet round.  This pins out
    /// the old fixed-10 ms spin's failure mode (a zero-length or
    /// busy-wait poll burning a core on an idle daemon).
    #[test]
    fn quiet_owner_loop_sleeps_at_least_the_backoff_floor() {
        let (_tx, rx) = mpsc::channel::<Cmd>();
        let mut backoff = IdleBackoff::new();
        let t0 = std::time::Instant::now();
        let mut waited = Duration::ZERO;
        for _ in 0..4 {
            let wait = backoff.next_wait();
            assert!(wait >= IDLE_BACKOFF_FLOOR);
            match rx.recv_timeout(wait) {
                Err(mpsc::RecvTimeoutError::Timeout) => waited += wait,
                Ok(_) => panic!("quiet channel yielded a command"),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    panic!("sender is still alive; channel cannot disconnect")
                }
            }
        }
        assert!(
            t0.elapsed() >= waited,
            "4 quiet rounds must sleep >= {waited:?} total, measured {:?}",
            t0.elapsed()
        );
    }

    /// End-to-end over loopback with no subprocess: hello handshake,
    /// submit/status/unknown-verb/shutdown round trips, ids echoed.
    #[test]
    fn serve_round_trips_rpc_over_loopback() {
        let opts = ServeOptions {
            endpoint: "127.0.0.1:0".to_string(),
            engine: EngineConfig { workers: 1, ..EngineConfig::default() },
            artifacts: PathBuf::from("definitely-missing-artifacts"),
            materialize_corpora: false,
            token: None,
            drain: None,
        };
        let backend = Arc::new(MockBackend::deterministic());
        let (desc_tx, desc_rx) = mpsc::channel();
        let daemon = std::thread::spawn(move || {
            serve(opts, backend, move |d| {
                let _ = desc_tx.send(d.to_string());
            })
        });
        let desc = desc_rx.recv().expect("serve never became ready");
        let ep = Endpoint::parse(&desc).unwrap();
        let (r, mut w) = ep.connect().unwrap();
        let mut r = BufReader::new(r);
        let hello = wire::read_frame(&mut r).unwrap().expect("serve hello");
        wire::check_serve_hello(&hello).unwrap();

        fn ask(
            r: &mut impl BufRead,
            w: &mut impl Write,
            id: u64,
            verb: &str,
            params: &Json,
        ) -> wire::RpcReply {
            wire::write_frame(w, &wire::rpc_request_line(id, verb, params)).unwrap();
            let line = wire::read_frame(r).unwrap().expect("reply frame");
            wire::decode_rpc_reply(&line).unwrap()
        }

        // empty submit: a sweep that is immediately done
        let params = Json::parse("{\"jobs\":[]}").unwrap();
        match ask(&mut r, &mut w, 11, "submit", &params) {
            wire::RpcReply::Ok { id, result } => {
                assert_eq!(id, 11);
                assert_eq!(result.get("sweep").unwrap().as_usize().unwrap(), 1);
                assert_eq!(result.get("total").unwrap().as_usize().unwrap(), 0);
            }
            wire::RpcReply::Err { error, .. } => panic!("submit failed: {error}"),
        }
        // status for that sweep
        let params = Json::parse("{\"sweep\":1}").unwrap();
        match ask(&mut r, &mut w, 12, "status", &params) {
            wire::RpcReply::Ok { id, result } => {
                assert_eq!(id, 12);
                assert!(result.get("done").unwrap().as_bool().unwrap());
            }
            wire::RpcReply::Err { error, .. } => panic!("status failed: {error}"),
        }
        // unknown sweep and unknown verb: tagged errors, connection lives
        match ask(&mut r, &mut w, 13, "cancel", &Json::parse("{\"sweep\":99}").unwrap()) {
            wire::RpcReply::Err { id, error } => {
                assert_eq!(id, 13);
                assert!(error.contains("no such sweep"), "got: {error}");
            }
            wire::RpcReply::Ok { .. } => panic!("cancel of unknown sweep succeeded"),
        }
        match ask(&mut r, &mut w, 14, "frobnicate", &Json::Null) {
            wire::RpcReply::Err { id, error } => {
                assert_eq!(id, 14);
                assert!(error.contains("unknown verb"), "got: {error}");
            }
            wire::RpcReply::Ok { .. } => panic!("unknown verb succeeded"),
        }
        // cache-stats on the in-memory cache
        match ask(&mut r, &mut w, 15, "cache-stats", &Json::Null) {
            wire::RpcReply::Ok { id, result } => {
                assert_eq!(id, 15);
                assert_eq!(result.get("records").unwrap().as_usize().unwrap(), 0);
            }
            wire::RpcReply::Err { error, .. } => panic!("cache-stats failed: {error}"),
        }
        // shutdown: ok reply, then the daemon thread exits cleanly
        match ask(&mut r, &mut w, 16, "shutdown", &Json::Null) {
            wire::RpcReply::Ok { id, .. } => assert_eq!(id, 16),
            wire::RpcReply::Err { error, .. } => panic!("shutdown failed: {error}"),
        }
        daemon.join().expect("daemon thread panicked").expect("serve returned an error");
    }

    /// Flipping the drain flag (what the SIGTERM handler does) must
    /// bring the daemon down cleanly with no client involved.
    #[test]
    fn drain_flag_stops_the_daemon_without_a_client() {
        let drain = Arc::new(AtomicBool::new(false));
        let opts = ServeOptions {
            endpoint: "127.0.0.1:0".to_string(),
            engine: EngineConfig { workers: 1, ..EngineConfig::default() },
            artifacts: PathBuf::from("definitely-missing-artifacts"),
            materialize_corpora: false,
            token: None,
            drain: Some(Arc::clone(&drain)),
        };
        let backend = Arc::new(MockBackend::deterministic());
        let (desc_tx, desc_rx) = mpsc::channel();
        let daemon = std::thread::spawn(move || {
            serve(opts, backend, move |d| {
                let _ = desc_tx.send(d.to_string());
            })
        });
        let _desc = desc_rx.recv().expect("serve never became ready");
        drain.store(true, Ordering::SeqCst);
        daemon.join().expect("daemon thread panicked").expect("serve returned an error");
    }

    /// A token-configured daemon advertises auth in its hello, serves
    /// a client that presents the matching token, and rejects a wrong
    /// one with a tagged error naming the mismatch.
    #[test]
    fn token_auth_gates_the_serve_handshake() {
        let opts = ServeOptions {
            endpoint: "127.0.0.1:0".to_string(),
            engine: EngineConfig { workers: 1, ..EngineConfig::default() },
            artifacts: PathBuf::from("definitely-missing-artifacts"),
            materialize_corpora: false,
            token: Some("sesame".to_string()),
            drain: None,
        };
        let backend = Arc::new(MockBackend::deterministic());
        let (desc_tx, desc_rx) = mpsc::channel();
        let daemon = std::thread::spawn(move || {
            serve(opts, backend, move |d| {
                let _ = desc_tx.send(d.to_string());
            })
        });
        let desc = desc_rx.recv().expect("serve never became ready");
        let ep = Endpoint::parse(&desc).unwrap();

        // wrong token: a tagged error frame, then the daemon hangs up
        let (r, mut w) = ep.connect().unwrap();
        let mut r = BufReader::new(r);
        let hello = wire::read_frame(&mut r).unwrap().expect("serve hello");
        wire::check_serve_hello(&hello).unwrap();
        assert!(wire::hello_advertises_auth(&hello), "token daemon must advertise auth");
        wire::write_frame(&mut w, &wire::token_frame("wrong")).unwrap();
        let line = wire::read_frame(&mut r).unwrap().expect("auth rejection frame");
        match wire::decode_rpc_reply(&line).unwrap() {
            wire::RpcReply::Err { error, .. } => {
                assert!(error.contains("mismatch"), "got: {error}");
                assert!(!error.contains("sesame"), "error must not echo the secret");
            }
            wire::RpcReply::Ok { .. } => panic!("wrong token was accepted"),
        }
        assert!(wire::read_frame(&mut r).unwrap().is_none(), "daemon must hang up");

        // right token: verbs work, including shutdown
        let (r, mut w) = ep.connect().unwrap();
        let mut r = BufReader::new(r);
        let hello = wire::read_frame(&mut r).unwrap().expect("serve hello");
        wire::check_serve_hello(&hello).unwrap();
        wire::write_frame(&mut w, &wire::token_frame("sesame")).unwrap();
        wire::write_frame(&mut w, &wire::rpc_request_line(7, "shutdown", &Json::Null)).unwrap();
        let line = wire::read_frame(&mut r).unwrap().expect("shutdown reply");
        match wire::decode_rpc_reply(&line).unwrap() {
            wire::RpcReply::Ok { id, .. } => assert_eq!(id, 7),
            wire::RpcReply::Err { error, .. } => panic!("authed shutdown failed: {error}"),
        }
        daemon.join().expect("daemon thread panicked").expect("serve returned an error");
    }
}
