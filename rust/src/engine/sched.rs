//! The engine's job scheduler: priorities, manifest affinity, and
//! submission-level cancellation.
//!
//! The pre-handle engine fed its workers from a single mpsc FIFO, which
//! had two costs on multi-shape batches: (1) interleaved manifests made
//! every worker thrash its session pool (each cross-manifest hop risks
//! an XLA recompile measured in seconds), and (2) a second caller's jobs
//! could only queue strictly behind the first batch.  This module
//! replaces the FIFO with a small in-memory scheduler:
//!
//! * **Priority first.**  Every submission carries a priority
//!   ([`crate::engine::SubmitOptions::priority`]); a higher-priority
//!   task is always dispatched before a lower-priority one, regardless
//!   of affinity or age.
//! * **Affinity second.**  Within a priority level, a worker prefers
//!   tasks whose manifest it has dispatched recently — the scheduler
//!   mirrors each worker's [`crate::engine::LruPool`] contents (same
//!   capacity, same MRU discipline), so "recently dispatched" is
//!   exactly "session still warm".  A worker only crosses manifests
//!   (a *steal*) when none of its warm manifests have pending work,
//!   which is the moment it would otherwise go idle.
//! * **FIFO last.**  Ties break by submission order, so equal-priority
//!   same-warmness work drains in the order callers queued it.
//!
//! Affinity tracking is capability-gated: a backend that advertises no
//! per-manifest warm state (`Capabilities::session_affinity == false`)
//! gets plain priority+FIFO dispatch with no warm mirror and no
//! hit/steal accounting — the scheduler asks the backend, not the
//! other way around.
//!
//! Hit/steal totals are surfaced through
//! [`crate::engine::EngineStats::pool_hits`] /
//! [`EngineStats::pool_steals`](crate::engine::EngineStats::pool_steals):
//! on a healthy multi-shape sweep hits should dominate, and `steals ≤
//! workers × distinct manifests` (each worker pays at most one cold
//! dispatch per shape it ever touches).
//!
//! Cancellation is per submission: [`Scheduler::cancel`] atomically
//! removes every still-queued task of one submission and replies
//! [`Reply::Cancelled`] for each, so the owning handle can account for
//! them.  Tasks already handed to a worker are *in flight* and run to
//! completion (their results are still cached — a cancelled sweep never
//! leaves the cache inconsistent).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::train::RunRecord;

use super::job::EngineJob;
use super::lock;

/// One worker→handle message: a finished (or cancelled-before-start)
/// task, identified by its index within the owning submission.
pub(crate) enum Reply {
    /// The task ran on a worker (successfully or not).
    Done { idx: usize, result: Result<RunRecord, String> },
    /// The task was cancelled while still queued; it never executed.
    Cancelled { idx: usize },
}

/// Shared state of one submission, held by its handle and by every one
/// of its queued tasks.
pub(crate) struct SubmissionCtl {
    pub(crate) id: u64,
    pub(crate) cancelled: AtomicBool,
}

/// One queued unit of work.
pub(crate) struct Task {
    /// Global dispatch order tiebreaker (FIFO within equal priority and
    /// warmness), assigned at enqueue time.
    seq: u64,
    pub(crate) priority: i32,
    /// Sweep id of the owning submission in the engine's event stream
    /// (stamped into the worker's `job_done` event).
    pub(crate) sweep: u64,
    /// Index of this job within its submission (outcome addressing).
    pub(crate) idx: usize,
    /// Content address, precomputed at submit time (the worker persists
    /// the result under it).
    pub(crate) key: String,
    pub(crate) job: EngineJob,
    pub(crate) reply: Sender<Reply>,
    pub(crate) ctl: Arc<SubmissionCtl>,
}

impl Task {
    pub(crate) fn new(
        priority: i32,
        sweep: u64,
        idx: usize,
        key: String,
        job: EngineJob,
        reply: Sender<Reply>,
        ctl: Arc<SubmissionCtl>,
    ) -> Task {
        // seq is assigned under the scheduler lock at enqueue time
        Task { seq: 0, priority, sweep, idx, key, job, reply, ctl }
    }
}

struct SchedState {
    queue: Vec<Task>,
    /// Per-worker MRU manifest list (front = warmest), mirroring that
    /// worker's session pool at `warm_cap` entries.
    warm: Vec<Vec<String>>,
    warm_cap: usize,
    /// Whether the engine's backend keeps per-manifest warm state worth
    /// scheduling around (`Capabilities::session_affinity`).  When
    /// false the scheduler dispatches plain priority+FIFO: no warm
    /// mirror is maintained and no hits/steals are counted.
    affinity: bool,
    hits: u64,
    steals: u64,
    cancelled: u64,
    next_seq: u64,
    next_submission: u64,
    shutdown: bool,
}

pub(crate) struct Scheduler {
    state: Mutex<SchedState>,
    available: Condvar,
}

impl Scheduler {
    pub(crate) fn new(workers: usize, warm_cap: usize, affinity: bool) -> Scheduler {
        Scheduler {
            state: Mutex::new(SchedState {
                queue: Vec::new(),
                warm: vec![Vec::new(); workers.max(1)],
                warm_cap: warm_cap.max(1),
                affinity,
                hits: 0,
                steals: 0,
                cancelled: 0,
                next_seq: 0,
                next_submission: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
        }
    }

    /// Allocate the control block for a new submission.
    pub(crate) fn new_submission(&self) -> Arc<SubmissionCtl> {
        let mut state = lock(&self.state);
        let id = state.next_submission;
        state.next_submission += 1;
        Arc::new(SubmissionCtl { id, cancelled: AtomicBool::new(false) })
    }

    /// Queue a submission's runnable tasks and wake the workers.
    pub(crate) fn enqueue(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut state = lock(&self.state);
        for mut t in tasks {
            t.seq = state.next_seq;
            state.next_seq += 1;
            state.queue.push(t);
        }
        drop(state);
        self.available.notify_all();
    }

    /// Blocking pop for worker `w`: the highest-priority task, warm
    /// manifests preferred within a priority level, FIFO otherwise.
    /// Returns `None` only when the scheduler is shut down *and* the
    /// queue is drained — queued work always completes, mirroring the
    /// old pool's hang-up semantics.
    pub(crate) fn next_for(&self, w: usize) -> Option<Task> {
        let mut state = lock(&self.state);
        loop {
            if let Some(i) = pick(&state, w) {
                let task = state.queue.remove(i);
                if state.affinity {
                    let was_warm = touch_warm(&mut state, w, &task.job.manifest.name);
                    if was_warm {
                        state.hits += 1;
                    } else {
                        state.steals += 1;
                    }
                }
                return Some(task);
            }
            if state.shutdown {
                return None;
            }
            state = self.available.wait(state).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Batched pop for a pipelining worker: block for the first task
    /// exactly like [`Scheduler::next_for`] (priority first, warm
    /// preferred, FIFO last — and this first pull may *steal* a cold
    /// manifest), then top the batch up to `depth` with queued tasks
    /// non-blockingly.  Top-up pulls are warm-affine **only**: a worker
    /// steals singly, never a batch — grabbing `depth` cold-manifest
    /// jobs at once would defeat the affinity design by thrashing a
    /// sibling's warm session the moment two workers go idle together.
    /// (Without `session_affinity` there is no warm state to protect,
    /// so top-ups take plain priority+FIFO order.)  Returns an empty
    /// vector only at drained shutdown.
    ///
    /// The deliberate cost: a lower-priority warm task can ride in a
    /// batch ahead of a higher-priority cold one — bounded by `depth-1`
    /// jobs per pull, the price of keeping a pipelined connection's
    /// window full.
    pub(crate) fn next_batch_for(&self, w: usize, depth: usize) -> Vec<Task> {
        let Some(first) = self.next_for(w) else {
            return Vec::new();
        };
        let mut batch = vec![first];
        if depth <= 1 {
            return batch;
        }
        let mut state = lock(&self.state);
        while batch.len() < depth {
            let Some(i) = pick_warm_only(&state, w) else {
                break;
            };
            let task = state.queue.remove(i);
            if state.affinity {
                touch_warm(&mut state, w, &task.job.manifest.name);
                state.hits += 1;
            }
            batch.push(task);
        }
        batch
    }

    /// Cancel a submission: remove its queued tasks (replying
    /// [`Reply::Cancelled`] for each) and mark the control block so the
    /// owner can observe the state.  In-flight tasks are unaffected.
    pub(crate) fn cancel(&self, ctl: &SubmissionCtl) {
        ctl.cancelled.store(true, Ordering::SeqCst);
        let mut state = lock(&self.state);
        let mut i = 0;
        while i < state.queue.len() {
            if state.queue[i].ctl.id == ctl.id {
                let task = state.queue.remove(i);
                state.cancelled += 1;
                let _ = task.reply.send(Reply::Cancelled { idx: task.idx });
            } else {
                i += 1;
            }
        }
    }

    /// (affinity hits, cross-manifest steals, tasks cancelled while
    /// queued) over the scheduler's lifetime.
    pub(crate) fn counters(&self) -> (u64, u64, u64) {
        let state = lock(&self.state);
        (state.hits, state.steals, state.cancelled)
    }

    /// Wake everyone for shutdown; workers drain the remaining queue
    /// first (see [`Scheduler::next_for`]).
    pub(crate) fn shutdown(&self) {
        lock(&self.state).shutdown = true;
        self.available.notify_all();
    }
}

/// Index of the best task for worker `w`: max by (priority, warmness,
/// earliest submission order).
fn pick(state: &SchedState, w: usize) -> Option<usize> {
    let mut best: Option<(usize, (i32, bool, std::cmp::Reverse<u64>))> = None;
    for (i, t) in state.queue.iter().enumerate() {
        let warm = state.affinity && state.warm[w].iter().any(|n| n == &t.job.manifest.name);
        let score = (t.priority, warm, std::cmp::Reverse(t.seq));
        if best.as_ref().is_none_or(|(_, s)| score > *s) {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// Index of the best *warm* task for worker `w` — the batch top-up
/// filter: under affinity only tasks whose manifest is already in the
/// worker's warm mirror qualify (max by priority, then FIFO); without
/// affinity every task qualifies and this is plain [`pick`].
fn pick_warm_only(state: &SchedState, w: usize) -> Option<usize> {
    if !state.affinity {
        return pick(state, w);
    }
    let mut best: Option<(usize, (i32, std::cmp::Reverse<u64>))> = None;
    for (i, t) in state.queue.iter().enumerate() {
        if !state.warm[w].iter().any(|n| n == &t.job.manifest.name) {
            continue;
        }
        let score = (t.priority, std::cmp::Reverse(t.seq));
        if best.as_ref().is_none_or(|(_, s)| score > *s) {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// Record a dispatch of `name` to worker `w` in the scheduler's mirror
/// of that worker's session pool; returns whether it was already warm.
fn touch_warm(state: &mut SchedState, w: usize, name: &str) -> bool {
    let cap = state.warm_cap;
    let warm = &mut state.warm[w];
    if let Some(pos) = warm.iter().position(|n| n == name) {
        let n = warm.remove(pos);
        warm.insert(0, n);
        true
    } else {
        warm.insert(0, name.to_string());
        warm.truncate(cap);
        false
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    use super::*;
    use crate::data::{Corpus, CorpusConfig};
    use crate::parametrization::{HpSet, Parametrization, Scheme};
    use crate::runtime::{Manifest, Spec};
    use crate::train::RunConfig;

    fn job_on(manifest: &str) -> EngineJob {
        let man = Arc::new(Manifest {
            name: manifest.to_string(),
            dir: std::path::PathBuf::from("."),
            spec: Spec {
                width: 32,
                depth: 2,
                batch: 4,
                seq: 16,
                vocab: 64,
                head_dim: 16,
                trainable_norms: false,
            },
            tensors: vec![],
            n_params: 0,
            state_ext_len: 1,
            loss_offset: 0,
            rms_offset: 1,
            scale_sites: std::collections::BTreeMap::new(),
            n_scale_sites: 0,
            quant_sites: std::collections::BTreeMap::new(),
            n_quant_sites: 0,
            rms_sites: vec![],
        });
        let corpus = Arc::new(Corpus {
            config: CorpusConfig { vocab: 64, n_tokens: 256, seed: 1, ..Default::default() },
            tokens: vec![],
            n_train: 0,
        });
        let config = RunConfig::quick(
            manifest,
            Parametrization::new(Scheme::Umup),
            HpSet::with_eta(0.5),
            4,
        );
        EngineJob::new(man, corpus, config, vec![])
    }

    fn enqueue_one(sched: &Scheduler, manifest: &str, priority: i32) {
        let (tx, rx) = channel();
        std::mem::forget(rx); // tests never reply; keep the sender alive
        let ctl = sched.new_submission();
        sched.enqueue(vec![Task::new(priority, 0, 0, "k".into(), job_on(manifest), tx, ctl)]);
    }

    fn manifests(batch: &[Task]) -> Vec<&str> {
        batch.iter().map(|t| t.job.manifest.name.as_str()).collect()
    }

    /// Top-up pulls only take manifests already warm for the worker —
    /// the first (blocking) pull steals, the batch never does.
    #[test]
    fn batch_topup_is_warm_affine_only() {
        let sched = Scheduler::new(2, 2, true);
        for m in ["a", "a", "a", "b", "b"] {
            enqueue_one(&sched, m, 0);
        }
        let batch = sched.next_batch_for(0, 4);
        assert_eq!(manifests(&batch), ["a", "a", "a"], "cold `b` must not ride the batch");
        let batch = sched.next_batch_for(0, 4);
        assert_eq!(manifests(&batch), ["b", "b"]);
        let (hits, steals, _) = sched.counters();
        assert_eq!((hits, steals), (3, 2), "one steal per manifest, top-ups are hits");
    }

    /// Without session affinity there is no warm state to protect:
    /// top-ups take plain priority+FIFO order across manifests.
    #[test]
    fn batch_topup_without_affinity_is_priority_fifo() {
        let sched = Scheduler::new(1, 2, false);
        for m in ["a", "b", "a"] {
            enqueue_one(&sched, m, 0);
        }
        assert_eq!(manifests(&sched.next_batch_for(0, 2)), ["a", "b"]);
        assert_eq!(manifests(&sched.next_batch_for(0, 2)), ["a"]);
        let (hits, steals, _) = sched.counters();
        assert_eq!((hits, steals), (0, 0));
    }

    /// Depth 1 is exactly the single-pull path, and a drained shutdown
    /// yields an empty batch (the worker's exit signal).
    #[test]
    fn batch_depth_one_and_shutdown_drain() {
        let sched = Scheduler::new(1, 2, true);
        enqueue_one(&sched, "a", 0);
        enqueue_one(&sched, "a", 0);
        assert_eq!(manifests(&sched.next_batch_for(0, 1)), ["a"]);
        sched.shutdown();
        // queued work still drains after shutdown...
        assert_eq!(manifests(&sched.next_batch_for(0, 4)), ["a"]);
        // ...then the empty batch says "exit"
        assert!(sched.next_batch_for(0, 4).is_empty());
    }

    /// The first pull honors priority even when a warm lower-priority
    /// task exists; top-ups then drain by priority within the warm set.
    #[test]
    fn batch_first_pull_takes_priority_over_warmth() {
        let sched = Scheduler::new(1, 2, true);
        // warm the worker on `a`
        enqueue_one(&sched, "a", 0);
        assert_eq!(manifests(&sched.next_batch_for(0, 1)), ["a"]);
        enqueue_one(&sched, "a", 0);
        enqueue_one(&sched, "b", 5);
        let batch = sched.next_batch_for(0, 4);
        // priority wins the blocking pull; after it, both manifests are
        // warm and the top-up takes the remaining `a`
        assert_eq!(manifests(&batch), ["b", "a"]);
        assert_eq!(batch[0].priority, 5);
    }
}
