//! The engine's job scheduler: priorities, manifest affinity, and
//! submission-level cancellation.
//!
//! The pre-handle engine fed its workers from a single mpsc FIFO, which
//! had two costs on multi-shape batches: (1) interleaved manifests made
//! every worker thrash its session pool (each cross-manifest hop risks
//! an XLA recompile measured in seconds), and (2) a second caller's jobs
//! could only queue strictly behind the first batch.  This module
//! replaces the FIFO with a small in-memory scheduler:
//!
//! * **Priority first.**  Every submission carries a priority
//!   ([`crate::engine::SubmitOptions::priority`]); a higher-priority
//!   task is always dispatched before a lower-priority one, regardless
//!   of affinity or age.
//! * **Affinity second.**  Within a priority level, a worker prefers
//!   tasks whose manifest it has dispatched recently — the scheduler
//!   mirrors each worker's [`crate::engine::LruPool`] contents (same
//!   capacity, same MRU discipline), so "recently dispatched" is
//!   exactly "session still warm".  A worker only crosses manifests
//!   (a *steal*) when none of its warm manifests have pending work,
//!   which is the moment it would otherwise go idle.
//! * **FIFO last.**  Ties break by submission order, so equal-priority
//!   same-warmness work drains in the order callers queued it.
//!
//! Affinity tracking is capability-gated: a backend that advertises no
//! per-manifest warm state (`Capabilities::session_affinity == false`)
//! gets plain priority+FIFO dispatch with no warm mirror and no
//! hit/steal accounting — the scheduler asks the backend, not the
//! other way around.
//!
//! Hit/steal totals are surfaced through
//! [`crate::engine::EngineStats::pool_hits`] /
//! [`EngineStats::pool_steals`](crate::engine::EngineStats::pool_steals):
//! on a healthy multi-shape sweep hits should dominate, and `steals ≤
//! workers × distinct manifests` (each worker pays at most one cold
//! dispatch per shape it ever touches).
//!
//! Cancellation is per submission: [`Scheduler::cancel`] atomically
//! removes every still-queued task of one submission and replies
//! [`Reply::Cancelled`] for each, so the owning handle can account for
//! them.  Tasks already handed to a worker are *in flight* and run to
//! completion (their results are still cached — a cancelled sweep never
//! leaves the cache inconsistent).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::train::RunRecord;

use super::job::EngineJob;
use super::lock;

/// One worker→handle message: a finished (or cancelled-before-start)
/// task, identified by its index within the owning submission.
pub(crate) enum Reply {
    /// The task ran on a worker (successfully or not).
    Done { idx: usize, result: Result<RunRecord, String> },
    /// The task was cancelled while still queued; it never executed.
    Cancelled { idx: usize },
}

/// Shared state of one submission, held by its handle and by every one
/// of its queued tasks.
pub(crate) struct SubmissionCtl {
    pub(crate) id: u64,
    pub(crate) cancelled: AtomicBool,
}

/// One queued unit of work.
pub(crate) struct Task {
    /// Global dispatch order tiebreaker (FIFO within equal priority and
    /// warmness), assigned at enqueue time.
    seq: u64,
    pub(crate) priority: i32,
    /// Sweep id of the owning submission in the engine's event stream
    /// (stamped into the worker's `job_done` event).
    pub(crate) sweep: u64,
    /// Index of this job within its submission (outcome addressing).
    pub(crate) idx: usize,
    /// Content address, precomputed at submit time (the worker persists
    /// the result under it).
    pub(crate) key: String,
    pub(crate) job: EngineJob,
    pub(crate) reply: Sender<Reply>,
    pub(crate) ctl: Arc<SubmissionCtl>,
}

impl Task {
    pub(crate) fn new(
        priority: i32,
        sweep: u64,
        idx: usize,
        key: String,
        job: EngineJob,
        reply: Sender<Reply>,
        ctl: Arc<SubmissionCtl>,
    ) -> Task {
        // seq is assigned under the scheduler lock at enqueue time
        Task { seq: 0, priority, sweep, idx, key, job, reply, ctl }
    }
}

struct SchedState {
    queue: Vec<Task>,
    /// Per-worker MRU manifest list (front = warmest), mirroring that
    /// worker's session pool at `warm_cap` entries.
    warm: Vec<Vec<String>>,
    warm_cap: usize,
    /// Whether the engine's backend keeps per-manifest warm state worth
    /// scheduling around (`Capabilities::session_affinity`).  When
    /// false the scheduler dispatches plain priority+FIFO: no warm
    /// mirror is maintained and no hits/steals are counted.
    affinity: bool,
    hits: u64,
    steals: u64,
    cancelled: u64,
    next_seq: u64,
    next_submission: u64,
    shutdown: bool,
}

pub(crate) struct Scheduler {
    state: Mutex<SchedState>,
    available: Condvar,
}

impl Scheduler {
    pub(crate) fn new(workers: usize, warm_cap: usize, affinity: bool) -> Scheduler {
        Scheduler {
            state: Mutex::new(SchedState {
                queue: Vec::new(),
                warm: vec![Vec::new(); workers.max(1)],
                warm_cap: warm_cap.max(1),
                affinity,
                hits: 0,
                steals: 0,
                cancelled: 0,
                next_seq: 0,
                next_submission: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
        }
    }

    /// Allocate the control block for a new submission.
    pub(crate) fn new_submission(&self) -> Arc<SubmissionCtl> {
        let mut state = lock(&self.state);
        let id = state.next_submission;
        state.next_submission += 1;
        Arc::new(SubmissionCtl { id, cancelled: AtomicBool::new(false) })
    }

    /// Queue a submission's runnable tasks and wake the workers.
    pub(crate) fn enqueue(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut state = lock(&self.state);
        for mut t in tasks {
            t.seq = state.next_seq;
            state.next_seq += 1;
            state.queue.push(t);
        }
        drop(state);
        self.available.notify_all();
    }

    /// Blocking pop for worker `w`: the highest-priority task, warm
    /// manifests preferred within a priority level, FIFO otherwise.
    /// Returns `None` only when the scheduler is shut down *and* the
    /// queue is drained — queued work always completes, mirroring the
    /// old pool's hang-up semantics.
    pub(crate) fn next_for(&self, w: usize) -> Option<Task> {
        let mut state = lock(&self.state);
        loop {
            if let Some(i) = pick(&state, w) {
                let task = state.queue.remove(i);
                if state.affinity {
                    let was_warm = touch_warm(&mut state, w, &task.job.manifest.name);
                    if was_warm {
                        state.hits += 1;
                    } else {
                        state.steals += 1;
                    }
                }
                return Some(task);
            }
            if state.shutdown {
                return None;
            }
            state = self.available.wait(state).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Cancel a submission: remove its queued tasks (replying
    /// [`Reply::Cancelled`] for each) and mark the control block so the
    /// owner can observe the state.  In-flight tasks are unaffected.
    pub(crate) fn cancel(&self, ctl: &SubmissionCtl) {
        ctl.cancelled.store(true, Ordering::SeqCst);
        let mut state = lock(&self.state);
        let mut i = 0;
        while i < state.queue.len() {
            if state.queue[i].ctl.id == ctl.id {
                let task = state.queue.remove(i);
                state.cancelled += 1;
                let _ = task.reply.send(Reply::Cancelled { idx: task.idx });
            } else {
                i += 1;
            }
        }
    }

    /// (affinity hits, cross-manifest steals, tasks cancelled while
    /// queued) over the scheduler's lifetime.
    pub(crate) fn counters(&self) -> (u64, u64, u64) {
        let state = lock(&self.state);
        (state.hits, state.steals, state.cancelled)
    }

    /// Wake everyone for shutdown; workers drain the remaining queue
    /// first (see [`Scheduler::next_for`]).
    pub(crate) fn shutdown(&self) {
        lock(&self.state).shutdown = true;
        self.available.notify_all();
    }
}

/// Index of the best task for worker `w`: max by (priority, warmness,
/// earliest submission order).
fn pick(state: &SchedState, w: usize) -> Option<usize> {
    let mut best: Option<(usize, (i32, bool, std::cmp::Reverse<u64>))> = None;
    for (i, t) in state.queue.iter().enumerate() {
        let warm = state.affinity && state.warm[w].iter().any(|n| n == &t.job.manifest.name);
        let score = (t.priority, warm, std::cmp::Reverse(t.seq));
        if best.as_ref().is_none_or(|(_, s)| score > *s) {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// Record a dispatch of `name` to worker `w` in the scheduler's mirror
/// of that worker's session pool; returns whether it was already warm.
fn touch_warm(state: &mut SchedState, w: usize, name: &str) -> bool {
    let cap = state.warm_cap;
    let warm = &mut state.warm[w];
    if let Some(pos) = warm.iter().position(|n| n == name) {
        let n = warm.remove(pos);
        warm.insert(0, n);
        true
    } else {
        warm.insert(0, name.to_string());
        warm.truncate(cap);
        false
    }
}
