//! Submission handles: the streaming, non-blocking face of the engine.
//!
//! [`crate::engine::Engine::submit`] resolves what it can immediately
//! (cache hits, foreign-shard skips, in-batch duplicates) and queues the
//! rest on the shared worker pool, returning a [`SweepHandle`] at once.
//! The handle is a receiver: outcomes stream through it in *completion*
//! order as workers finish, so callers can plot, early-stop or schedule
//! follow-up work while the tail of a sweep is still training.
//!
//! Lifecycle notes:
//!
//! * Immediate cache-hit outcomes are *lazy* hits: submit resolves them
//!   against the run cache's key index, parsing (and memoizing) each
//!   hit record from its byte span on first touch — a submission over a
//!   10⁵-entry cache pays for the records it hits, not the history it
//!   doesn't.
//! * Results are persisted to the run cache by the *worker*, before the
//!   outcome is delivered — dropping a handle abandons the stream, not
//!   the work, and everything executed is still resumable from disk.
//! * [`SweepHandle::cancel`] unqueues the submission's pending jobs
//!   (they come back as cancelled outcomes and never execute); jobs
//!   already on a worker run to completion and are cached normally.
//! * Handles are independent: any number may be live at once, feeding
//!   one engine from multiple threads, each with its own priority.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;

use anyhow::Result;

use super::events::{Event, JobStatus, SweepCounters};
use super::job::{EngineReport, JobOutcome, SweepResult};
use super::sched::{Reply, Scheduler, SubmissionCtl};
use super::{lock, EngineJob, Shared};

/// Per-submission options for [`crate::engine::Engine::submit_with`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Dispatch priority relative to other live submissions: all queued
    /// jobs of a higher-priority submission are dispatched before any
    /// lower-priority job, regardless of age or affinity.  Default 0;
    /// negative values yield to everything.
    pub priority: i32,
}

/// A live submission: streams [`JobOutcome`]s as workers finish them.
///
/// Also an [`Iterator`] over outcomes, so `for outcome in handle { … }`
/// consumes the stream in completion order.
pub struct SweepHandle {
    pub(crate) shared: Arc<Shared>,
    pub(crate) sched: Arc<Scheduler>,
    pub(crate) ctl: Arc<SubmissionCtl>,
    pub(crate) rx: Receiver<Reply>,
    /// Sweep id in the engine's event stream.
    pub(crate) sweep: u64,
    /// Submission instant, for the `sweep_finished` duration.
    pub(crate) t0: std::time::Instant,
    /// All jobs, in submission order.
    pub(crate) jobs: Vec<EngineJob>,
    /// Resolved outcomes by submission index (filled as replies arrive).
    pub(crate) outcomes: Vec<Option<JobOutcome>>,
    /// Resolved-but-not-yet-emitted indices, in resolution order.
    pub(crate) ready: VecDeque<usize>,
    /// follower indices per primary index (in-batch duplicates).
    pub(crate) followers_of: Vec<Vec<usize>>,
    /// Indices dispatched to the worker pool (one reply owed for each).
    pub(crate) dispatched: Vec<usize>,
    /// Replies still owed by the pool.
    pub(crate) outstanding: usize,
    /// Outcomes with a terminal resolution (drives `sweep_finished`).
    pub(crate) resolved: usize,
    /// `sweep_finished` already published.
    pub(crate) finished: bool,
    pub(crate) emitted: usize,
    // per-submission counters for the final report
    pub(crate) cache_hits: usize,
    pub(crate) deduped: usize,
    pub(crate) skipped: usize,
    pub(crate) executed: usize,
    pub(crate) failed: usize,
    pub(crate) cancelled: usize,
}

impl SweepHandle {
    /// Total jobs in this submission.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Outcomes already handed out by `recv`/`try_recv`.
    pub fn emitted(&self) -> usize {
        self.emitted
    }

    /// Outcomes not yet handed out.
    pub fn remaining(&self) -> usize {
        self.jobs.len() - self.emitted
    }

    /// True once every outcome has been emitted.
    pub fn is_done(&self) -> bool {
        self.emitted == self.jobs.len()
    }

    /// Next outcome in completion order, blocking until one is
    /// available; `None` once all outcomes have been emitted.
    pub fn recv(&mut self) -> Option<JobOutcome> {
        loop {
            if let Some(i) = self.ready.pop_front() {
                self.emitted += 1;
                return self.outcomes[i].clone();
            }
            if self.outstanding == 0 {
                return None;
            }
            match self.rx.recv() {
                Ok(reply) => self.integrate(reply),
                Err(_) => self.fail_outstanding(),
            }
        }
    }

    /// Non-blocking variant of [`SweepHandle::recv`]: `None` either when
    /// nothing has completed *yet* or when the stream is exhausted —
    /// disambiguate with [`SweepHandle::is_done`].
    pub fn try_recv(&mut self) -> Option<JobOutcome> {
        loop {
            if let Some(i) = self.ready.pop_front() {
                self.emitted += 1;
                return self.outcomes[i].clone();
            }
            if self.outstanding == 0 {
                return None;
            }
            match self.rx.try_recv() {
                Ok(reply) => self.integrate(reply),
                Err(TryRecvError::Empty) => return None,
                Err(TryRecvError::Disconnected) => self.fail_outstanding(),
            }
        }
    }

    /// Cancel this submission's still-queued jobs.  They resolve as
    /// cancelled outcomes (streamed like any other) and never execute;
    /// in-flight jobs complete and are cached.  The handle remains
    /// drainable — `wait()` after `cancel()` yields the full report.
    pub fn cancel(&self) {
        self.sched.cancel(&self.ctl);
    }

    /// Block until every outcome is in and assemble the batch report
    /// (outcomes in submission order), the moral equivalent of the old
    /// blocking `Engine::run`.
    pub fn wait(mut self) -> EngineReport {
        while self.recv().is_some() {}
        self.into_report()
    }

    /// Drain the stream, calling `each(outcome, emitted_so_far, total)`
    /// per outcome as it completes, then return the strict
    /// submission-ordered results — or the first per-job error, after
    /// every job has still been attempted (nothing is silently
    /// abandoned on failure).
    pub fn drain_strict<F>(mut self, mut each: F) -> Result<Vec<SweepResult>>
    where
        F: FnMut(&JobOutcome, usize, usize),
    {
        let total = self.len();
        while let Some(o) = self.recv() {
            each(&o, self.emitted, total);
        }
        self.into_report().into_sweep_results()
    }

    fn into_report(self) -> EngineReport {
        let outcomes: Vec<JobOutcome> =
            self.outcomes.into_iter().map(|o| o.expect("all jobs resolved")).collect();
        let completed = outcomes.iter().filter(|o| o.outcome.is_ok()).count();
        EngineReport {
            outcomes,
            completed,
            failed: self.failed,
            cache_hits: self.cache_hits,
            deduped: self.deduped,
            skipped: self.skipped,
            executed: self.executed,
            cancelled: self.cancelled,
        }
    }

    /// Fold one worker reply into the outcome table (and resolve any
    /// in-batch duplicates of that job from the same result).
    fn integrate(&mut self, reply: Reply) {
        self.outstanding -= 1;
        match reply {
            Reply::Done { idx, result } => {
                self.executed += 1;
                let outcome = match result {
                    Ok(record) => Ok(record),
                    Err(e) => {
                        self.failed += 1;
                        Err(e)
                    }
                };
                // the worker already published this job's `executed`
                // event (with duration and worker id)
                self.resolve(idx, outcome, false, false);
            }
            Reply::Cancelled { idx } => {
                self.cancelled += 1;
                let err = "cancelled before execution".to_string();
                self.publish_done(idx, JobStatus::Cancelled, false, Some(err.clone()));
                self.resolve(idx, Err(err), false, true);
            }
        }
        self.maybe_finish();
    }

    /// One terminal `job_done` event for job `idx` (resolved on this
    /// handle's side — workers publish their own `executed` events).
    fn publish_done(&self, idx: usize, status: JobStatus, ok: bool, error: Option<String>) {
        if !self.shared.events.is_active() {
            return;
        }
        let job = &self.jobs[idx];
        self.shared.events.publish(Event::JobDone {
            sweep: self.sweep,
            idx,
            key: job.key(),
            manifest: job.manifest.name.clone(),
            label: job.config.label.clone(),
            status,
            ok,
            error,
            duration_ms: None,
            worker: None,
        });
    }

    /// Publish `sweep_finished` exactly once, when every job has a
    /// terminal outcome (whether or not anyone has drained them yet).
    pub(crate) fn maybe_finish(&mut self) {
        if self.finished || self.resolved != self.jobs.len() {
            return;
        }
        self.finished = true;
        self.shared.events.publish(Event::SweepFinished {
            sweep: self.sweep,
            counters: SweepCounters {
                total: self.jobs.len(),
                executed: self.executed,
                hits: self.cache_hits,
                dups: self.deduped,
                skips: self.skipped,
                cancelled: self.cancelled,
                failed: self.failed,
            },
            duration_ms: self.t0.elapsed().as_millis() as u64,
        });
    }

    /// Record `idx`'s outcome, then derive its followers' outcomes.
    fn resolve(
        &mut self,
        idx: usize,
        outcome: Result<crate::train::RunRecord, String>,
        cached: bool,
        cancelled: bool,
    ) {
        self.outcomes[idx] = Some(JobOutcome {
            idx,
            job: self.jobs[idx].clone(),
            outcome: outcome.clone(),
            cached,
            skipped: false,
            cancelled,
        });
        self.ready.push_back(idx);
        self.resolved += 1;
        for f in std::mem::take(&mut self.followers_of[idx]) {
            let fo = match &outcome {
                Ok(rec) => {
                    self.deduped += 1;
                    lock(&self.shared.stats).deduped += 1;
                    let mut rec = rec.clone();
                    rec.label = self.jobs[f].config.label.clone();
                    Ok(rec)
                }
                Err(e) => {
                    if cancelled {
                        self.cancelled += 1;
                        // queued primaries are counted by the scheduler;
                        // their followers only resolve here
                        lock(&self.shared.stats).cancelled += 1;
                    } else {
                        self.failed += 1;
                        lock(&self.shared.stats).failed += 1;
                    }
                    Err(e.clone())
                }
            };
            let (status, ok, err) = match (&fo, cancelled) {
                (_, true) => (JobStatus::Cancelled, false, fo.as_ref().err().cloned()),
                (Ok(_), _) => (JobStatus::Dup, true, None),
                (Err(e), _) => (JobStatus::Dup, false, Some(e.clone())),
            };
            self.publish_done(f, status, ok, err);
            self.outcomes[f] = Some(JobOutcome {
                idx: f,
                job: self.jobs[f].clone(),
                outcome: fo,
                cached: !cancelled,
                skipped: false,
                cancelled,
            });
            self.ready.push_back(f);
            self.resolved += 1;
        }
    }

    /// The worker pool vanished mid-submission (every worker thread
    /// gone): resolve whatever is still owed as explicit errors so the
    /// stream always terminates.
    fn fail_outstanding(&mut self) {
        for idx in self.dispatched.clone() {
            if self.outcomes[idx].is_none() {
                self.failed += 1;
                let err = "engine worker died before finishing this job".to_string();
                self.publish_done(idx, JobStatus::Executed, false, Some(err.clone()));
                self.resolve(idx, Err(err), false, false);
            }
        }
        self.outstanding = 0;
        self.maybe_finish();
    }
}

impl Iterator for SweepHandle {
    type Item = JobOutcome;

    fn next(&mut self) -> Option<JobOutcome> {
        self.recv()
    }
}

/// Handle for a single submitted job ([`crate::engine::Engine::submit_one`]).
pub struct JobHandle(pub(crate) SweepHandle);

impl JobHandle {
    /// Has the job finished (outcome ready to collect)?
    pub fn is_ready(&mut self) -> bool {
        // peek by integrating without emitting: try_recv would consume,
        // so probe the ready queue after a non-blocking pump
        if !self.0.ready.is_empty() {
            return true;
        }
        if self.0.outstanding == 0 {
            return true;
        }
        while let Ok(reply) = self.0.rx.try_recv() {
            self.0.integrate(reply);
        }
        !self.0.ready.is_empty() || self.0.outstanding == 0
    }

    /// Cancel the job if it has not started executing yet.
    pub fn cancel(&self) {
        self.0.cancel();
    }

    /// Block until the job concludes and return its outcome.
    pub fn wait(self) -> JobOutcome {
        let mut report = self.0.wait();
        report.outcomes.pop().expect("one job in, one outcome out")
    }

    /// Strict view: the result record, or the job's error.
    pub fn result(self) -> Result<SweepResult> {
        let o = self.wait();
        match o.outcome {
            Ok(record) => Ok(SweepResult {
                job: super::job::SweepJob { config: o.job.config, tag: o.job.tag },
                record,
            }),
            Err(e) => Err(anyhow::anyhow!("job {}: {e}", o.job.config.label)),
        }
    }
}
