//! Live sweep dashboard (feature `tui`) — a thin frontend over the
//! event stream.
//!
//! The container this repo builds in carries no third-party TUI crate,
//! so instead of ratatui this is a minimal in-tree renderer with the
//! same testing shape: [`Dashboard`] folds [`Envelope`]s into display
//! state and renders into a [`Buffer`] (a plain cell grid — the
//! stand-in for ratatui's `TestBackend`, so the smoke test asserts on
//! rendered cells with no terminal attached), and [`run`] is the ANSI
//! frontend that repaints a terminal from a live [`EventStream`] at
//! ~10 Hz.  Widgets: per-shard progress bars, the job-outcome counter
//! partition, pool hit/steal and cache size panels, throughput + ETA,
//! and a recent-failures pane fed by failed jobs and teed worker
//! stderr excerpts.
//!
//! Everything here consumes only the public event schema — the
//! dashboard state machine is exactly what any external frontend would
//! build from a `--progress jsonl` stream.

use std::collections::{BTreeMap, VecDeque};

use super::bus::Tick;
use super::{Envelope, Event, EventStream, JobStatus};

/// A `w`×`h` character grid — the render target.  Out-of-bounds writes
/// are clipped, so widgets never panic on small terminals.
pub struct Buffer {
    w: usize,
    h: usize,
    cells: Vec<char>,
}

impl Buffer {
    pub fn new(w: usize, h: usize) -> Buffer {
        Buffer { w, h, cells: vec![' '; w * h] }
    }

    pub fn width(&self) -> usize {
        self.w
    }

    pub fn height(&self) -> usize {
        self.h
    }

    /// Write `s` starting at column `x` of row `y`, clipping at the
    /// right edge (and ignoring rows outside the grid).
    pub fn set_str(&mut self, x: usize, y: usize, s: &str) {
        if y >= self.h {
            return;
        }
        for (i, c) in s.chars().enumerate() {
            let col = x + i;
            if col >= self.w {
                break;
            }
            self.cells[y * self.w + col] = if c == '\n' { ' ' } else { c };
        }
    }

    /// Row `y` as a string (right-trimmed).
    pub fn line(&self, y: usize) -> String {
        let row: String = self.cells[y * self.w..(y + 1) * self.w].iter().collect();
        row.trim_end().to_string()
    }

    /// All rows, right-trimmed — what the smoke test asserts against.
    pub fn to_strings(&self) -> Vec<String> {
        (0..self.h).map(|y| self.line(y)).collect()
    }

    /// Does any row contain `needle`?
    pub fn contains(&self, needle: &str) -> bool {
        (0..self.h).any(|y| self.line(y).contains(needle))
    }
}

#[derive(Default)]
struct ShardView {
    /// Jobs announced by this source's `sweep_started` events.
    total: usize,
    /// Terminal job outcomes seen from this source.
    done: usize,
    attempt: usize,
    alive: bool,
    note: String,
}

/// Event-stream fold: apply envelopes, render the current picture.
/// Pure state — no terminal, no clock — so tests drive it directly.
#[derive(Default)]
pub struct Dashboard {
    shards: BTreeMap<usize, ShardView>,
    /// Partition counters across every source (executed, hit, dup,
    /// skip, cancelled) plus the failure overlay.
    executed: usize,
    hits: usize,
    dups: usize,
    skips: usize,
    cancelled: usize,
    failed: usize,
    pool_hits: usize,
    pool_steals: usize,
    cached_keys: usize,
    segments: usize,
    throughput: f64,
    eta_s: Option<f64>,
    dropped: u64,
    compaction: String,
    failures: VecDeque<String>,
}

const FAILURE_PANE: usize = 6;

impl Dashboard {
    pub fn new() -> Dashboard {
        Dashboard::default()
    }

    fn shard_mut(&mut self, idx: Option<usize>) -> &mut ShardView {
        self.shards.entry(idx.unwrap_or(0)).or_default()
    }

    fn push_failure(&mut self, line: String) {
        if self.failures.len() == FAILURE_PANE {
            self.failures.pop_front();
        }
        self.failures.push_back(line);
    }

    /// Fold one envelope into the display state.  `child_line` events
    /// are parsed and recursed into (that is how a driver-side stream
    /// carries its shards' events).
    pub fn apply(&mut self, env: &Envelope) {
        let src = env.shard;
        match &env.event {
            Event::ChildLine { line } => {
                if let Ok(inner) = Envelope::parse(line) {
                    self.apply(&inner);
                }
            }
            Event::SweepStarted { total, .. } => {
                let s = self.shard_mut(src);
                s.total += total;
                s.alive = true;
            }
            Event::JobDone { status, ok, label, error, .. } => {
                self.shard_mut(src).done += 1;
                match status {
                    JobStatus::Executed => self.executed += 1,
                    JobStatus::Hit => self.hits += 1,
                    JobStatus::Dup => self.dups += 1,
                    JobStatus::Skip => self.skips += 1,
                    JobStatus::Cancelled => self.cancelled += 1,
                }
                if !ok && !matches!(status, JobStatus::Skip | JobStatus::Cancelled) {
                    self.failed += 1;
                    let shard = src.map(|s| format!("shard {s} ")).unwrap_or_default();
                    let err = error.as_deref().unwrap_or("failed");
                    self.push_failure(format!("{shard}{label}: {err}"));
                }
            }
            Event::WorkerRestarted { worker, restarts_left, stderr } => {
                let tail = stderr.lines().last().unwrap_or("").to_string();
                self.push_failure(format!(
                    "worker {worker} restarted ({restarts_left} left): {tail}"
                ));
            }
            Event::WorkerBudgetExhausted { worker, stderr } => {
                let tail = stderr.lines().last().unwrap_or("").to_string();
                self.push_failure(format!("worker {worker} budget exhausted: {tail}"));
            }
            Event::ShardSpawned { shard, attempt } => {
                let s = self.shard_mut(Some(*shard));
                s.attempt = *attempt;
                s.alive = true;
                s.note.clear();
            }
            Event::ShardExit { shard, ok, detail } => {
                let s = self.shard_mut(Some(*shard));
                s.alive = false;
                s.note = if *ok { "done".to_string() } else { detail.clone() };
                if !ok {
                    self.push_failure(format!("shard {shard}: {detail}"));
                }
            }
            Event::ShardRestarted { shard, attempt, max_attempts } => {
                // fresh attempt streams a fresh sweep: restart its bar
                let s = self.shard_mut(Some(*shard));
                s.total = 0;
                s.done = 0;
                s.attempt = *attempt;
                s.alive = true;
                s.note = format!("restarting ({attempt}/{max_attempts})");
            }
            Event::Snapshot {
                cached_keys,
                segments,
                throughput,
                eta_s,
                pool_hits,
                pool_steals,
                dropped,
                ..
            } => {
                self.cached_keys = *cached_keys;
                self.segments = *segments;
                self.throughput = *throughput;
                self.eta_s = *eta_s;
                self.pool_hits = (*pool_hits).max(self.pool_hits);
                self.pool_steals = (*pool_steals).max(self.pool_steals);
                self.dropped = *dropped;
            }
            Event::CacheRefresh { total_keys, .. } => {
                self.cached_keys = *total_keys;
            }
            Event::CacheCompaction { inputs, output, entries, .. } => {
                self.compaction = format!("compacted {inputs} segments -> {output} ({entries})");
            }
            Event::SweepFinished { .. }
            | Event::JobQueued { .. }
            | Event::WorkerSpawned { .. }
            | Event::Unknown { .. } => {}
        }
    }

    /// Render the current state into a fresh `w`×`h` [`Buffer`].
    pub fn render(&self, w: usize, h: usize) -> Buffer {
        let mut b = Buffer::new(w, h);
        b.set_str(0, 0, "repro — live sweep dashboard");
        let mut y = 2;
        for (idx, s) in &self.shards {
            let bar_w = 20usize;
            let filled = if s.total > 0 {
                (s.done * bar_w / s.total).min(bar_w)
            } else {
                0
            };
            let bar: String = std::iter::repeat_n('#', filled)
                .chain(std::iter::repeat_n('.', bar_w - filled))
                .collect();
            let state = if s.alive {
                "live"
            } else if s.note.is_empty() {
                "done"
            } else {
                &s.note
            };
            b.set_str(0, y, &format!("shard {idx} [{bar}] {}/{} {state}", s.done, s.total));
            y += 1;
        }
        y += 1;
        let done = self.executed + self.hits + self.dups + self.skips + self.cancelled;
        b.set_str(
            0,
            y,
            &format!(
                "jobs {done} = {} run + {} hit + {} dup + {} skip + {} cancelled | {} failed",
                self.executed, self.hits, self.dups, self.skips, self.cancelled, self.failed
            ),
        );
        b.set_str(
            0,
            y + 1,
            &format!(
                "pool {} hits / {} steals | cache {} keys in {} segments",
                self.pool_hits, self.pool_steals, self.cached_keys, self.segments
            ),
        );
        let eta = match self.eta_s {
            Some(e) => format!("{e:.0}s"),
            None => "-".to_string(),
        };
        b.set_str(
            0,
            y + 2,
            &format!(
                "rate {:.2} runs/s | eta {eta} | events dropped {}",
                self.throughput, self.dropped
            ),
        );
        if !self.compaction.is_empty() {
            b.set_str(0, y + 3, &self.compaction);
        }
        y += 4;
        b.set_str(0, y, "recent failures:");
        for (i, f) in self.failures.iter().enumerate() {
            b.set_str(2, y + 1 + i, f);
        }
        b
    }
}

/// The ANSI frontend: repaint `out` from `stream` at roughly 10 Hz
/// until the stream ends (every bus clone dropped).  Uses only clear +
/// home escapes, so it degrades to scrolling on dumb terminals.
pub fn run<W: std::io::Write>(stream: EventStream, out: &mut W) -> std::io::Result<()> {
    let mut dash = Dashboard::new();
    let mut dirty = true;
    loop {
        match stream.recv_timeout(std::time::Duration::from_millis(100)) {
            Tick::Event(env) => {
                dash.apply(&env);
                // drain whatever is buffered before repainting
                while let Some(env) = stream.try_recv() {
                    dash.apply(&env);
                }
                dirty = true;
            }
            Tick::Timeout => {}
            Tick::Ended => break,
        }
        if dirty {
            paint(&dash, out)?;
            dirty = false;
        }
    }
    paint(&dash, out)?;
    Ok(())
}

fn paint<W: std::io::Write>(dash: &Dashboard, out: &mut W) -> std::io::Result<()> {
    let buf = dash.render(100, 24);
    write!(out, "\x1b[2J\x1b[H")?;
    for line in buf.to_strings() {
        writeln!(out, "{line}")?;
    }
    out.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::events::{EventBus, SweepCounters};

    fn env(shard: Option<usize>, seq: u64, event: Event) -> Envelope {
        Envelope { v: super::super::EVENTS_VERSION, seq, ts_ms: 1_700_000_000_000, shard, event }
    }

    fn done(shard: usize, idx: usize, status: JobStatus, ok: bool) -> Envelope {
        env(
            Some(shard),
            10 + idx as u64,
            Event::JobDone {
                sweep: 0,
                idx,
                key: format!("{idx:016x}"),
                manifest: "w64".to_string(),
                label: format!("lr{idx}"),
                status,
                ok,
                error: if ok { None } else { Some("diverged at step 8".to_string()) },
                duration_ms: Some(12),
                worker: Some(0),
            },
        )
    }

    /// The no-terminal smoke test: feed a synthetic event sequence,
    /// render into a buffer, and assert the key widgets materialize.
    #[test]
    fn dashboard_renders_shard_bars_and_failure_pane() {
        let mut d = Dashboard::new();
        d.apply(&env(Some(0), 0, Event::SweepStarted { sweep: 0, total: 4 }));
        d.apply(&env(Some(1), 0, Event::SweepStarted { sweep: 0, total: 4 }));
        d.apply(&done(0, 0, JobStatus::Executed, true));
        d.apply(&done(0, 1, JobStatus::Hit, true));
        d.apply(&done(1, 0, JobStatus::Executed, false));
        d.apply(&env(
            Some(1),
            99,
            Event::WorkerRestarted {
                worker: 2,
                restarts_left: 1,
                stderr: "thread panicked\nsegfault imminent".to_string(),
            },
        ));
        d.apply(&env(
            None,
            3,
            Event::Snapshot {
                done: 3,
                total: Some(8),
                cached_keys: 17,
                segments: 2,
                throughput: 4.5,
                eta_s: Some(2.0),
                pool_hits: 5,
                pool_steals: 1,
                dropped: 0,
            },
        ));

        let buf = d.render(100, 24);
        // shard progress bars, half-filled for shard 0 (2/4)
        assert!(buf.contains("shard 0 [##########..........] 2/4 live"), "{:?}", buf.to_strings());
        assert!(buf.contains("shard 1 [#####...............] 1/4 live"));
        // counter partition line
        assert!(buf.contains("jobs 3 = 2 run + 1 hit + 0 dup + 0 skip + 0 cancelled | 1 failed"));
        // pool/cache panel from the snapshot
        assert!(buf.contains("pool 5 hits / 1 steals | cache 17 keys in 2 segments"));
        assert!(buf.contains("rate 4.50 runs/s | eta 2s"));
        // failure pane: the failed job and the teed stderr excerpt
        assert!(buf.contains("shard 1 lr0: diverged at step 8"));
        assert!(buf.contains("worker 2 restarted (1 left): segfault imminent"));
    }

    /// Driver-forwarded child lines fold exactly like native events.
    #[test]
    fn child_lines_recurse_into_the_fold() {
        let inner = done(3, 0, JobStatus::Executed, true).line();
        let mut d = Dashboard::new();
        d.apply(&env(Some(3), 0, Event::SweepStarted { sweep: 0, total: 1 }));
        d.apply(&env(None, 0, Event::ChildLine { line: inner }));
        let buf = d.render(80, 12);
        assert!(buf.contains("shard 3 [####################] 1/1"), "{:?}", buf.to_strings());
    }

    /// A shard restart resets its bar (the fresh attempt re-announces
    /// its sweep), and `sweep_finished` counters parse.
    #[test]
    fn restart_resets_and_finish_is_inert() {
        let mut d = Dashboard::new();
        d.apply(&env(Some(0), 0, Event::SweepStarted { sweep: 0, total: 4 }));
        d.apply(&done(0, 0, JobStatus::Executed, true));
        d.apply(&env(None, 1, Event::ShardRestarted { shard: 0, attempt: 2, max_attempts: 3 }));
        let buf = d.render(80, 12);
        assert!(buf.contains("shard 0 [....................] 0/0 restarting (2/3)"));
        d.apply(&env(
            Some(0),
            2,
            Event::SweepFinished {
                sweep: 0,
                counters: SweepCounters { total: 4, executed: 4, ..Default::default() },
                duration_ms: 10,
            },
        ));
    }

    /// End-to-end over a real bus: the ANSI frontend consumes a stream
    /// and paints the final frame after the bus hangs up.
    #[test]
    fn ansi_frontend_paints_from_a_live_stream() {
        let bus = EventBus::new();
        let stream = bus.subscribe(64);
        bus.publish(Event::SweepStarted { sweep: 0, total: 2 });
        bus.publish(Event::JobDone {
            sweep: 0,
            idx: 0,
            key: "k".to_string(),
            manifest: "w64".to_string(),
            label: "a".to_string(),
            status: JobStatus::Hit,
            ok: true,
            error: None,
            duration_ms: None,
            worker: None,
        });
        drop(bus);
        let mut out = Vec::new();
        run(stream, &mut out).unwrap();
        let painted = String::from_utf8(out).unwrap();
        assert!(painted.contains("repro — live sweep dashboard"));
        assert!(painted.contains("1 hit"));
    }
}
