//! `repro-events` — the engine's typed telemetry subsystem.
//!
//! Everything the engine used to *print* (drive progress lines, worker
//! restart notices, cache refresh tallies) is modelled here as a typed,
//! versioned [`Event`], published through a bounded, never-blocking
//! [`EventBus`], and serialized as one JSON object per line (JSONL) by
//! the [`Envelope`] codec.  Frontends — the `--progress jsonl` stream,
//! the feature-gated `tui` dashboard, the `serve` RPC's `events` verb
//! — are thin consumers of the same stream; human-readable output is
//! just another subscriber, never a special case inside the engine.
//!
//! # Wire format
//!
//! Each event is one line:
//!
//! ```json
//! {"seq":3,"shard":1,"ts":1700000000000,"type":"job_done","v":1,...}
//! ```
//!
//! * `v` — schema version ([`EVENTS_VERSION`]).  Bumped only for
//!   breaking changes; additions of fields or event types do **not**
//!   bump it.
//! * `seq` — per-bus monotone sequence number (per *source* process; a
//!   driver interleaving child streams re-emits their lines verbatim,
//!   so (shard, seq) is unique, bare seq is not).
//! * `ts` — wall-clock milliseconds since the Unix epoch.
//! * `shard` — present only on events from a sharded source
//!   ([`EventBus::with_source`]).
//! * `type` + flattened per-variant fields — see [`Event`].
//!
//! # Versioning policy (additive-only)
//!
//! The schema evolves by *addition*: new event types and new fields may
//! appear at any version; existing fields are never renamed, retyped,
//! or removed without a `v` bump.  [`Envelope::parse`] therefore
//! ignores unknown fields and maps unknown `type`s to
//! [`Event::Unknown`] instead of erroring — an old reader tails a new
//! stream losslessly for the events it knows.  The golden-file test in
//! `tests/events.rs` pins the serialized form of every variant.
//!
//! # Outcome partition
//!
//! Every job in a sweep produces exactly one terminal [`Event::JobDone`]
//! whose `status` is one of `executed` / `hit` / `dup` / `skip` /
//! `cancelled` ([`JobStatus`]) — failures are `status:"executed"` with
//! `ok:false`, not a sixth status — so for any completed sweep the
//! per-status counts exactly partition [`Event::SweepStarted`]'s
//! `total`, mirroring [`crate::engine::EngineReport`].

mod bus;
#[cfg(feature = "tui")]
pub mod tui;

pub use bus::{EventBus, EventStream, Tick};

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::Json;

/// Schema version stamped into every envelope's `v` field.  Additive
/// changes (new event types, new fields) do not bump it; see the
/// module docs for the evolution contract.
pub const EVENTS_VERSION: u64 = 1;

/// Terminal disposition of one job within a sweep — the `status` field
/// of [`Event::JobDone`].  Exactly one of these is emitted per
/// submitted job, so the counts partition the sweep total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobStatus {
    /// Ran on a worker (successfully or not — see `JobDone::ok`).
    Executed,
    /// Satisfied by the run cache at submit time.
    Hit,
    /// Resolved from an identical job earlier in the same submission.
    Dup,
    /// Declined because its content address belongs to another shard.
    Skip,
    /// Cancelled while still queued; never executed.
    Cancelled,
}

impl JobStatus {
    /// The serialized form (the `status` field value).
    pub fn as_str(self) -> &'static str {
        match self {
            JobStatus::Executed => "executed",
            JobStatus::Hit => "hit",
            JobStatus::Dup => "dup",
            JobStatus::Skip => "skip",
            JobStatus::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Result<JobStatus> {
        Ok(match s {
            "executed" => JobStatus::Executed,
            "hit" => JobStatus::Hit,
            "dup" => JobStatus::Dup,
            "skip" => JobStatus::Skip,
            "cancelled" => JobStatus::Cancelled,
            other => anyhow::bail!("unknown job status {other:?}"),
        })
    }
}

/// Per-sweep outcome counters carried by [`Event::SweepFinished`] —
/// the event-stream mirror of [`crate::engine::EngineReport`]'s
/// counters.  `executed + hits + dups + skips + cancelled == total`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepCounters {
    pub total: usize,
    pub executed: usize,
    pub hits: usize,
    pub dups: usize,
    pub skips: usize,
    pub cancelled: usize,
    /// Executed jobs (or their dups) whose outcome was an error.
    /// Overlaps `executed`/`dups`; not part of the partition.
    pub failed: usize,
}

impl SweepCounters {
    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("cancelled".to_string(), num(self.cancelled));
        m.insert("dups".to_string(), num(self.dups));
        m.insert("executed".to_string(), num(self.executed));
        m.insert("failed".to_string(), num(self.failed));
        m.insert("hits".to_string(), num(self.hits));
        m.insert("skips".to_string(), num(self.skips));
        m.insert("total".to_string(), num(self.total));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<SweepCounters> {
        Ok(SweepCounters {
            total: j.get("total")?.as_usize()?,
            executed: j.get("executed")?.as_usize()?,
            hits: j.get("hits")?.as_usize()?,
            dups: j.get("dups")?.as_usize()?,
            skips: j.get("skips")?.as_usize()?,
            cancelled: j.get("cancelled")?.as_usize()?,
            failed: j.get("failed")?.as_usize()?,
        })
    }
}

/// One telemetry event.  Serialized names are pinned by the golden
/// test in `tests/events.rs`; evolution is additive-only (see the
/// module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A submission entered the engine (`total` jobs).
    SweepStarted { sweep: u64, total: usize },
    /// Every job of the submission has a terminal outcome.
    SweepFinished { sweep: u64, counters: SweepCounters, duration_ms: u64 },
    /// A job was accepted into a sweep (emitted for every job,
    /// including those resolved immediately).
    JobQueued { sweep: u64, idx: usize, key: String, manifest: String, label: String },
    /// A job reached its terminal outcome.  `ok` mirrors the
    /// `Ok`/`Err` of the [`crate::engine::JobOutcome`]; `duration_ms`
    /// and `worker` are present only for `status:"executed"`.
    JobDone {
        sweep: u64,
        idx: usize,
        key: String,
        manifest: String,
        label: String,
        status: JobStatus,
        ok: bool,
        error: Option<String>,
        duration_ms: Option<u64>,
        worker: Option<usize>,
    },
    /// An engine worker thread came up (and built its executor).
    /// `window` is the executor's pipeline depth — how many jobs the
    /// worker keeps in flight at once (1 = classic lockstep).
    WorkerSpawned { worker: usize, window: usize },
    /// An out-of-process worker crashed/disconnected and its slot is
    /// restarting; `stderr` is the teed last-stderr excerpt.
    WorkerRestarted { worker: usize, restarts_left: usize, stderr: String },
    /// A worker slot exhausted its restart budget and is giving up.
    WorkerBudgetExhausted { worker: usize, stderr: String },
    /// An armed `--job-timeout` deadline expired with `pending` jobs
    /// still unacknowledged on the worker's connection.  The stalled
    /// connection is treated exactly like a connection death: it is
    /// torn down and the crash-recovery path (re-dispatch once under
    /// the restart budget) takes over, so a `worker_stalled` is always
    /// followed by a `worker_restarted` or `worker_budget_exhausted`.
    WorkerStalled { worker: usize, timeout_ms: u64, pending: usize },
    /// An incremental cache refresh surfaced sibling-shard records.
    CacheRefresh { new_keys: usize, total_keys: usize },
    /// A background tier-merge folded segments.
    CacheCompaction { inputs: usize, output: String, entries: usize, deduped: usize },
    /// The shard driver launched a shard process (`attempt` starts
    /// at 1; restarts re-announce with higher attempts).
    ShardSpawned { shard: usize, attempt: usize },
    /// A shard process exited (`ok` = zero exit status).
    ShardExit { shard: usize, ok: bool, detail: String },
    /// The driver is relaunching a crashed shard.
    ShardRestarted { shard: usize, attempt: usize, max_attempts: usize },
    /// Periodic progress: merged cache view + throughput + ETA.
    Snapshot {
        done: usize,
        total: Option<usize>,
        cached_keys: usize,
        segments: usize,
        throughput: f64,
        eta_s: Option<f64>,
        pool_hits: usize,
        pool_steals: usize,
        dropped: u64,
    },
    /// A verbatim line forwarded from a child process's own event
    /// stream.  Encodes as the inner line itself (no double wrapping):
    /// the child already stamped its own envelope, including its
    /// `shard` tag.
    ChildLine { line: String },
    /// Parse-side only: an event type this reader does not know.  The
    /// envelope header (`v`/`seq`/`ts`/`shard`) is still available —
    /// additive evolution never breaks a tailing consumer.
    Unknown { kind: String },
}

impl Event {
    /// The serialized `type` field value.
    pub fn kind(&self) -> &str {
        match self {
            Event::SweepStarted { .. } => "sweep_started",
            Event::SweepFinished { .. } => "sweep_finished",
            Event::JobQueued { .. } => "job_queued",
            Event::JobDone { .. } => "job_done",
            Event::WorkerSpawned { .. } => "worker_spawned",
            Event::WorkerRestarted { .. } => "worker_restarted",
            Event::WorkerBudgetExhausted { .. } => "worker_budget_exhausted",
            Event::WorkerStalled { .. } => "worker_stalled",
            Event::CacheRefresh { .. } => "cache_refresh",
            Event::CacheCompaction { .. } => "cache_compaction",
            Event::ShardSpawned { .. } => "shard_spawned",
            Event::ShardExit { .. } => "shard_exit",
            Event::ShardRestarted { .. } => "shard_restarted",
            Event::Snapshot { .. } => "snapshot",
            Event::ChildLine { .. } => "child_line",
            Event::Unknown { kind } => kind,
        }
    }
}

/// A stamped event: what [`EventBus::publish`] produces and what one
/// JSONL line encodes.  The codec is pure — given the same envelope it
/// always produces the same line — so golden tests pin exact strings.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    /// Schema version ([`EVENTS_VERSION`]).
    pub v: u64,
    /// Per-source monotone sequence number.
    pub seq: u64,
    /// Wall-clock milliseconds since the Unix epoch.
    pub ts_ms: u64,
    /// Source shard index, when the publishing bus was tagged.
    pub shard: Option<usize>,
    pub event: Event,
}

fn num(x: usize) -> Json {
    Json::Num(x as f64)
}

fn num64(x: u64) -> Json {
    Json::Num(x as f64)
}

fn st(s: &str) -> Json {
    Json::Str(s.to_string())
}

impl Envelope {
    /// Serialize as one JSONL line (no trailing newline).
    /// [`Event::ChildLine`] is the one pass-through: its inner line —
    /// already a complete envelope stamped by the child — is returned
    /// verbatim.
    pub fn line(&self) -> String {
        if let Event::ChildLine { line } = &self.event {
            return line.clone();
        }
        let mut m = BTreeMap::new();
        m.insert("v".to_string(), num64(self.v));
        m.insert("seq".to_string(), num64(self.seq));
        m.insert("ts".to_string(), num64(self.ts_ms));
        if let Some(s) = self.shard {
            m.insert("shard".to_string(), num(s));
        }
        m.insert("type".to_string(), st(self.event.kind()));
        match &self.event {
            Event::SweepStarted { sweep, total } => {
                m.insert("sweep".to_string(), num64(*sweep));
                m.insert("total".to_string(), num(*total));
            }
            Event::SweepFinished { sweep, counters, duration_ms } => {
                m.insert("sweep".to_string(), num64(*sweep));
                m.insert("counters".to_string(), counters.to_json());
                m.insert("duration_ms".to_string(), num64(*duration_ms));
            }
            Event::JobQueued { sweep, idx, key, manifest, label } => {
                m.insert("sweep".to_string(), num64(*sweep));
                m.insert("idx".to_string(), num(*idx));
                m.insert("key".to_string(), st(key));
                m.insert("manifest".to_string(), st(manifest));
                m.insert("label".to_string(), st(label));
            }
            Event::JobDone {
                sweep,
                idx,
                key,
                manifest,
                label,
                status,
                ok,
                error,
                duration_ms,
                worker,
            } => {
                m.insert("sweep".to_string(), num64(*sweep));
                m.insert("idx".to_string(), num(*idx));
                m.insert("key".to_string(), st(key));
                m.insert("manifest".to_string(), st(manifest));
                m.insert("label".to_string(), st(label));
                m.insert("status".to_string(), st(status.as_str()));
                m.insert("ok".to_string(), Json::Bool(*ok));
                if let Some(e) = error {
                    m.insert("error".to_string(), st(e));
                }
                if let Some(d) = duration_ms {
                    m.insert("duration_ms".to_string(), num64(*d));
                }
                if let Some(w) = worker {
                    m.insert("worker".to_string(), num(*w));
                }
            }
            Event::WorkerSpawned { worker, window } => {
                m.insert("worker".to_string(), num(*worker));
                m.insert("window".to_string(), num(*window));
            }
            Event::WorkerRestarted { worker, restarts_left, stderr } => {
                m.insert("worker".to_string(), num(*worker));
                m.insert("restarts_left".to_string(), num(*restarts_left));
                m.insert("stderr".to_string(), st(stderr));
            }
            Event::WorkerBudgetExhausted { worker, stderr } => {
                m.insert("worker".to_string(), num(*worker));
                m.insert("stderr".to_string(), st(stderr));
            }
            Event::WorkerStalled { worker, timeout_ms, pending } => {
                m.insert("worker".to_string(), num(*worker));
                m.insert("timeout_ms".to_string(), num64(*timeout_ms));
                m.insert("pending".to_string(), num(*pending));
            }
            Event::CacheRefresh { new_keys, total_keys } => {
                m.insert("new_keys".to_string(), num(*new_keys));
                m.insert("total_keys".to_string(), num(*total_keys));
            }
            Event::CacheCompaction { inputs, output, entries, deduped } => {
                m.insert("inputs".to_string(), num(*inputs));
                m.insert("output".to_string(), st(output));
                m.insert("entries".to_string(), num(*entries));
                m.insert("deduped".to_string(), num(*deduped));
            }
            Event::ShardSpawned { shard, attempt } => {
                m.insert("shard".to_string(), num(*shard));
                m.insert("attempt".to_string(), num(*attempt));
            }
            Event::ShardExit { shard, ok, detail } => {
                m.insert("shard".to_string(), num(*shard));
                m.insert("ok".to_string(), Json::Bool(*ok));
                m.insert("detail".to_string(), st(detail));
            }
            Event::ShardRestarted { shard, attempt, max_attempts } => {
                m.insert("shard".to_string(), num(*shard));
                m.insert("attempt".to_string(), num(*attempt));
                m.insert("max_attempts".to_string(), num(*max_attempts));
            }
            Event::Snapshot {
                done,
                total,
                cached_keys,
                segments,
                throughput,
                eta_s,
                pool_hits,
                pool_steals,
                dropped,
            } => {
                m.insert("done".to_string(), num(*done));
                if let Some(t) = total {
                    m.insert("total".to_string(), num(*t));
                }
                m.insert("cached_keys".to_string(), num(*cached_keys));
                m.insert("segments".to_string(), num(*segments));
                m.insert("throughput".to_string(), Json::Num(*throughput));
                if let Some(e) = eta_s {
                    m.insert("eta_s".to_string(), Json::Num(*e));
                }
                m.insert("pool_hits".to_string(), num(*pool_hits));
                m.insert("pool_steals".to_string(), num(*pool_steals));
                m.insert("dropped".to_string(), num64(*dropped));
            }
            Event::ChildLine { .. } => unreachable!("pass-through handled above"),
            Event::Unknown { .. } => {}
        }
        Json::Obj(m).dump()
    }

    /// Parse one JSONL line back into an envelope.  Unknown fields are
    /// ignored and unknown `type`s become [`Event::Unknown`] — the
    /// additive-evolution contract.  Fails only on malformed JSON or a
    /// known type missing one of its pinned fields.
    pub fn parse(line: &str) -> Result<Envelope> {
        let j = Json::parse(line).context("event line is not valid JSON")?;
        let v = j.get("v")?.as_f64()? as u64;
        let seq = j.get("seq")?.as_f64()? as u64;
        let ts_ms = j.get("ts")?.as_f64()? as u64;
        let shard = j.get("shard").ok().and_then(|x| x.as_usize().ok());
        let kind = j.get("type")?.as_str()?.to_string();
        let event = match kind.as_str() {
            "sweep_started" => Event::SweepStarted {
                sweep: j.get("sweep")?.as_f64()? as u64,
                total: j.get("total")?.as_usize()?,
            },
            "sweep_finished" => Event::SweepFinished {
                sweep: j.get("sweep")?.as_f64()? as u64,
                counters: SweepCounters::from_json(j.get("counters")?)?,
                duration_ms: j.get("duration_ms")?.as_f64()? as u64,
            },
            "job_queued" => Event::JobQueued {
                sweep: j.get("sweep")?.as_f64()? as u64,
                idx: j.get("idx")?.as_usize()?,
                key: j.get("key")?.as_str()?.to_string(),
                manifest: j.get("manifest")?.as_str()?.to_string(),
                label: j.get("label")?.as_str()?.to_string(),
            },
            "job_done" => Event::JobDone {
                sweep: j.get("sweep")?.as_f64()? as u64,
                idx: j.get("idx")?.as_usize()?,
                key: j.get("key")?.as_str()?.to_string(),
                manifest: j.get("manifest")?.as_str()?.to_string(),
                label: j.get("label")?.as_str()?.to_string(),
                status: JobStatus::parse(j.get("status")?.as_str()?)?,
                ok: j.get("ok")?.as_bool()?,
                error: j.get("error").ok().and_then(|x| x.as_str().ok()).map(String::from),
                duration_ms: j
                    .get("duration_ms")
                    .ok()
                    .and_then(|x| x.as_f64().ok())
                    .map(|d| d as u64),
                worker: j.get("worker").ok().and_then(|x| x.as_usize().ok()),
            },
            "worker_spawned" => Event::WorkerSpawned {
                worker: j.get("worker")?.as_usize()?,
                // additive evolution: streams written before pipelining
                // landed carry no window field; they were lockstep
                window: j.get("window").ok().and_then(|x| x.as_usize().ok()).unwrap_or(1),
            },
            "worker_restarted" => Event::WorkerRestarted {
                worker: j.get("worker")?.as_usize()?,
                restarts_left: j.get("restarts_left")?.as_usize()?,
                stderr: j.get("stderr")?.as_str()?.to_string(),
            },
            "worker_budget_exhausted" => Event::WorkerBudgetExhausted {
                worker: j.get("worker")?.as_usize()?,
                stderr: j.get("stderr")?.as_str()?.to_string(),
            },
            "worker_stalled" => Event::WorkerStalled {
                worker: j.get("worker")?.as_usize()?,
                timeout_ms: j.get("timeout_ms")?.as_f64()? as u64,
                pending: j.get("pending")?.as_usize()?,
            },
            "cache_refresh" => Event::CacheRefresh {
                new_keys: j.get("new_keys")?.as_usize()?,
                total_keys: j.get("total_keys")?.as_usize()?,
            },
            "cache_compaction" => Event::CacheCompaction {
                inputs: j.get("inputs")?.as_usize()?,
                output: j.get("output")?.as_str()?.to_string(),
                entries: j.get("entries")?.as_usize()?,
                deduped: j.get("deduped")?.as_usize()?,
            },
            "shard_spawned" => Event::ShardSpawned {
                shard: j.get("shard")?.as_usize()?,
                attempt: j.get("attempt")?.as_usize()?,
            },
            "shard_exit" => Event::ShardExit {
                shard: j.get("shard")?.as_usize()?,
                ok: j.get("ok")?.as_bool()?,
                detail: j.get("detail")?.as_str()?.to_string(),
            },
            "shard_restarted" => Event::ShardRestarted {
                shard: j.get("shard")?.as_usize()?,
                attempt: j.get("attempt")?.as_usize()?,
                max_attempts: j.get("max_attempts")?.as_usize()?,
            },
            "snapshot" => Event::Snapshot {
                done: j.get("done")?.as_usize()?,
                total: j.get("total").ok().and_then(|x| x.as_usize().ok()),
                cached_keys: j.get("cached_keys")?.as_usize()?,
                segments: j.get("segments")?.as_usize()?,
                throughput: j.get("throughput")?.as_f64()?,
                eta_s: j.get("eta_s").ok().and_then(|x| x.as_f64().ok()),
                pool_hits: j.get("pool_hits")?.as_usize()?,
                pool_steals: j.get("pool_steals")?.as_usize()?,
                dropped: j.get("dropped")?.as_f64()? as u64,
            },
            _ => Event::Unknown { kind },
        };
        Ok(Envelope { v, seq, ts_ms, shard, event })
    }
}
