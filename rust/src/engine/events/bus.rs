//! The bounded, never-blocking event bus.
//!
//! Publishers (engine, scheduler, driver, backends) hold cheap
//! [`EventBus`] clones; consumers call [`EventBus::subscribe`] for a
//! bounded [`EventStream`].  The contract that matters sits on the
//! publish side:
//!
//! * **Zero-cost when nobody listens.**  A bus that has never been
//!   subscribed to returns from [`EventBus::publish`] after one relaxed
//!   atomic load — the engine hot path pays nothing for telemetry it
//!   is not emitting.
//! * **Never blocks.**  With subscribers attached, publish takes a
//!   `try_read` on the subscriber list (a writer mid-`subscribe`
//!   counts the event as dropped rather than waiting) and a `try_send`
//!   per stream; a full stream drops the event into the
//!   [`EventBus::dropped`] counter instead of stalling a worker.  Slow
//!   consumers lose events, loudly and countably — they never slow the
//!   sweep down.
//! * **Monotone per-source sequencing.**  Every published envelope is
//!   stamped with an increasing `seq` (and wall-clock `ts`), so a
//!   consumer can detect gaps from drops.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use super::{Envelope, Event, EVENTS_VERSION};

struct Sub {
    tx: SyncSender<Arc<Envelope>>,
    /// Flipped (under the read lock — it's atomic) when a send reports
    /// the receiver gone; pruned on the next `subscribe`.
    dead: AtomicBool,
}

struct BusInner {
    subs: RwLock<Vec<Sub>>,
    seq: AtomicU64,
    dropped: AtomicU64,
    /// False until the first `subscribe`: the publish fast path.  Never
    /// reset — after every stream disconnects, publish still stamps a
    /// sequence number and skips the dead subscribers, which is cheap
    /// and keeps `seq` gap-free for any future subscriber.
    active: AtomicBool,
}

/// Handle for publishing [`Event`]s; clone freely (all clones share one
/// bus).  See the module docs for the non-blocking contract.
#[derive(Clone)]
pub struct EventBus {
    inner: Arc<BusInner>,
    /// Stamped into every envelope's `shard` field (sharded sources).
    source: Option<usize>,
}

impl Default for EventBus {
    fn default() -> EventBus {
        EventBus {
            inner: Arc::new(BusInner {
                subs: RwLock::new(Vec::new()),
                seq: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                active: AtomicBool::new(false),
            }),
            source: None,
        }
    }
}

impl std::fmt::Debug for EventBus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventBus")
            .field("seq", &self.inner.seq.load(Ordering::Relaxed))
            .field("dropped", &self.inner.dropped.load(Ordering::Relaxed))
            .field("source", &self.source)
            .finish()
    }
}

fn now_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

impl EventBus {
    pub fn new() -> EventBus {
        EventBus::default()
    }

    /// A clone of this bus whose envelopes carry `"shard": index` — how
    /// a sharded child process tags its stream before the driver
    /// interleaves it with siblings'.
    pub fn with_source(&self, shard: usize) -> EventBus {
        EventBus { inner: Arc::clone(&self.inner), source: Some(shard) }
    }

    /// Stamp and fan out one event.  Never blocks: see the module docs
    /// for what happens to slow or vanished subscribers.
    pub fn publish(&self, event: Event) {
        if !self.inner.active.load(Ordering::Relaxed) {
            return;
        }
        let seq = self.inner.seq.fetch_add(1, Ordering::Relaxed);
        let env = Arc::new(Envelope {
            v: EVENTS_VERSION,
            seq,
            ts_ms: now_ms(),
            shard: self.source,
            event,
        });
        match self.inner.subs.try_read() {
            Ok(subs) => {
                for sub in subs.iter() {
                    if sub.dead.load(Ordering::Relaxed) {
                        continue;
                    }
                    match sub.tx.try_send(Arc::clone(&env)) {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => {
                            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            sub.dead.store(true, Ordering::Relaxed);
                        }
                    }
                }
            }
            // a subscriber is being attached right now; losing this one
            // event (counted) beats making a worker wait on the lock
            Err(_) => {
                self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Attach a bounded subscriber (`capacity` buffered envelopes, min
    /// 1).  Events published while the buffer is full are dropped and
    /// counted, not delivered late — size the capacity for the
    /// consumer's latency, not the sweep's length.
    pub fn subscribe(&self, capacity: usize) -> EventStream {
        let (tx, rx) = mpsc::sync_channel(capacity.max(1));
        let mut subs = self.inner.subs.write().unwrap_or_else(|p| p.into_inner());
        subs.retain(|s| !s.dead.load(Ordering::Relaxed));
        subs.push(Sub { tx, dead: AtomicBool::new(false) });
        self.inner.active.store(true, Ordering::Relaxed);
        EventStream { rx }
    }

    /// Events dropped so far (full or mid-subscribe streams) — the
    /// `events_dropped` metric, also carried by snapshot events.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Envelopes stamped so far (next `seq` to be assigned).
    pub fn published(&self) -> u64 {
        self.inner.seq.load(Ordering::Relaxed)
    }

    /// Has anyone ever subscribed?  Publishers may use this to skip
    /// building expensive event payloads.
    pub fn is_active(&self) -> bool {
        self.inner.active.load(Ordering::Relaxed)
    }
}

/// A subscriber's receiving end: an iterator/receiver of stamped
/// envelopes.  Ends (`None`) when every [`EventBus`] clone has been
/// dropped and the buffer is drained.
pub struct EventStream {
    rx: Receiver<Arc<Envelope>>,
}

impl EventStream {
    /// Next envelope, blocking; `None` once the bus is gone and the
    /// buffer is empty.
    pub fn recv(&self) -> Option<Arc<Envelope>> {
        self.rx.recv().ok()
    }

    /// Non-blocking variant: `None` when nothing is buffered *or* the
    /// stream has ended.
    pub fn try_recv(&self) -> Option<Arc<Envelope>> {
        self.rx.try_recv().ok()
    }

    /// Bounded-wait variant, distinguishing "nothing yet" from "the
    /// bus is gone" — what a polling frontend needs for its tick loop.
    pub fn recv_timeout(&self, timeout: Duration) -> Tick {
        match self.rx.recv_timeout(timeout) {
            Ok(env) => Tick::Event(env),
            Err(RecvTimeoutError::Timeout) => Tick::Timeout,
            Err(RecvTimeoutError::Disconnected) => Tick::Ended,
        }
    }
}

/// Outcome of one bounded wait ([`EventStream::recv_timeout`]).
pub enum Tick {
    /// An envelope arrived.
    Event(Arc<Envelope>),
    /// Nothing arrived within the timeout; the stream is still live.
    Timeout,
    /// Every bus clone is gone and the buffer is drained.
    Ended,
}

impl Iterator for EventStream {
    type Item = Arc<Envelope>;

    fn next(&mut self) -> Option<Arc<Envelope>> {
        self.recv()
    }
}
