//! The network backend: each engine worker slot dials a long-lived
//! worker endpoint (`repro worker --listen`) over TCP or a Unix domain
//! socket, speaking the exact [`super::wire`] protocol the process
//! backend speaks over pipes — the frames are byte-identical, only the
//! transport changes.
//!
//! # Topology
//!
//! A [`NetworkBackend`] holds an ordered endpoint list (`--workers
//! host:port,unix:/path,...`).  Worker slot `k` starts at endpoint
//! `k % n` — with `workers == n` this is a 1:1 slot↔endpoint mapping —
//! and every subsequent connection attempt advances round-robin, so a
//! dead endpoint fails over to the next one instead of pinning its slot
//! to a corpse.
//!
//! # Supervision / reconnect semantics
//!
//! Reconnects mirror [`super::ProcessBackend`]'s child restarts under
//! the same bounded budget ([`NetworkBackend::with_max_restarts`]): the
//! first connection is free, each later one consumes budget, and a
//! transport failure mid-job re-dispatches the in-flight job exactly
//! once on a fresh connection.  Remote workers outlive any one engine,
//! so there is no child to reap — teardown is just dropping the socket.
//!
//! # Pipelined dispatch
//!
//! The network transport is where pipelining pays most: every lockstep
//! job charges a full network round-trip of dead air.  This backend
//! therefore defaults to a window of [`DEFAULT_PIPELINE_DEPTH`] jobs in
//! flight per connection ([`NetworkBackend::with_pipeline_depth`]; `1`
//! restores strict lockstep) — the window is encoded into one reused
//! buffer and shipped as a single write+flush, replies stream back in
//! completion order and are matched to their slot by key, and a
//! connection death with a non-empty window re-dispatches **all
//! unacknowledged jobs exactly once** on the next (budget-gated)
//! endpoint, exactly like the process backend's windowed recovery.  A
//! reply keyed to nothing in the window is a protocol desync: a
//! transport failure, never a mis-filed record.
//!
//! Remote workers have no stderr to tee, so transport-failure outcomes
//! instead carry the *last error text the worker reported on the wire*
//! (including the `"?"`-keyed last-words frame `repro worker --listen`
//! emits when its serve loop dies) — network failures stay as
//! diagnosable as process-backend ones.
//!
//! # Deadlines
//!
//! [`NetworkBackend::with_job_timeout`] (`--job-timeout SECS`) arms
//! per-operation socket deadlines on every connection: a connect,
//! write, or reply read that stalls past the deadline fails with a
//! timeout error, which the engine treats exactly like a connection
//! death — [`Event::WorkerStalled`] fires, the socket is torn down, and
//! the ordinary crash-recovery path (budget-gated reconnect, one
//! re-dispatch of the unacknowledged window) takes over.  The default
//! is unarmed: sockets stay fully blocking and the dispatch path is
//! bit-for-bit identical to a build without deadlines.

use std::fmt;
use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::train::RunRecord;

use super::super::events::{Event, EventBus};
use super::super::job::EngineJob;
use super::super::lock;
use super::wire;
use super::{Backend, Capabilities, Executor};

// ------------------------------------------------------------ endpoint

/// One dialable worker address: `host:port` TCP or `unix:/path`.
#[derive(Clone, Debug)]
pub enum Endpoint {
    /// A TCP address (`127.0.0.1:7070`, `build-box:7070`).
    Tcp(String),
    /// A Unix domain socket path (`unix:/run/umup/worker.sock`).
    #[cfg(unix)]
    Unix(PathBuf),
}

impl Endpoint {
    /// Parse one endpoint: a `unix:` prefix selects a Unix socket path,
    /// anything with a colon is a TCP `host:port`.
    pub fn parse(s: &str) -> Result<Endpoint> {
        let s = s.trim();
        if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                if path.is_empty() {
                    bail!("unix endpoint has an empty path");
                }
                return Ok(Endpoint::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                bail!("unix endpoints are not supported on this platform");
            }
        }
        if !s.contains(':') || s.is_empty() {
            bail!("endpoint {s:?} is neither unix:<path> nor host:port");
        }
        Ok(Endpoint::Tcp(s.to_string()))
    }

    /// Dial the endpoint; returns independent read/write halves.
    /// Sockets are fully blocking — see [`Endpoint::connect_with_deadline`]
    /// for the armed variant.
    pub fn connect(&self) -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>)> {
        self.connect_with_deadline(None)
    }

    /// Dial the endpoint with an optional deadline: when `Some`, the
    /// TCP connect itself and every subsequent read/write on either
    /// half must complete within `timeout` or fail with a timeout
    /// error (the engine treats that exactly like a connection death).
    /// `None` leaves the socket fully blocking, byte-identical to
    /// [`Endpoint::connect`].
    pub fn connect_with_deadline(
        &self,
        timeout: Option<Duration>,
    ) -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>)> {
        match self {
            Endpoint::Tcp(addr) => {
                let stream = match timeout {
                    Some(t) => {
                        let sockaddr = addr
                            .to_socket_addrs()
                            .with_context(|| format!("resolving tcp endpoint {addr}"))?
                            .next()
                            .ok_or_else(|| {
                                anyhow!("tcp endpoint {addr} resolved to no address")
                            })?;
                        TcpStream::connect_timeout(&sockaddr, t)
                            .with_context(|| format!("connecting to tcp endpoint {addr}"))?
                    }
                    None => TcpStream::connect(addr)
                        .with_context(|| format!("connecting to tcp endpoint {addr}"))?,
                };
                // frames are small and latency-bound; don't batch them
                let _ = stream.set_nodelay(true);
                // set before try_clone so both halves share the deadline
                stream.set_read_timeout(timeout).context("setting read timeout")?;
                stream.set_write_timeout(timeout).context("setting write timeout")?;
                let reader = stream.try_clone().context("cloning tcp stream")?;
                Ok((Box::new(reader), Box::new(stream)))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let stream = UnixStream::connect(path)
                    .with_context(|| format!("connecting to unix endpoint {}", path.display()))?;
                stream.set_read_timeout(timeout).context("setting read timeout")?;
                stream.set_write_timeout(timeout).context("setting write timeout")?;
                let reader = stream.try_clone().context("cloning unix stream")?;
                Ok((Box::new(reader), Box::new(stream)))
            }
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "{addr}"),
            #[cfg(unix)]
            Endpoint::Unix(path) => write!(f, "unix:{}", path.display()),
        }
    }
}

// ------------------------------------------------------------ listener

/// The accepting side of an [`Endpoint`]: used by `repro worker
/// --listen` and the `repro serve` control socket.
pub enum Listener {
    /// A bound TCP listener.
    Tcp(TcpListener),
    /// A bound Unix socket listener (the path is unlinked on drop).
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind the endpoint.  TCP port 0 binds an ephemeral port (read the
    /// real one back via [`Listener::local_desc`]); a stale Unix socket
    /// file from a dead process is probed and reclaimed, but a socket
    /// with a live listener behind it is never stolen.
    pub fn bind(ep: &Endpoint) -> Result<Listener> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding tcp listener on {addr}"))?;
                Ok(Listener::Tcp(l))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                // a leftover socket file from a dead process would make
                // bind fail with AddrInUse; reclaim it — but only after
                // probing that nothing is accepting on it, so a live
                // listener is never silently unlinked out from under
                // its process
                if path.exists() {
                    if UnixStream::connect(path).is_ok() {
                        bail!(
                            "unix endpoint {} is already served by a live listener; \
                             refusing to steal its socket",
                            path.display()
                        );
                    }
                    std::fs::remove_file(path).with_context(|| {
                        format!("removing stale unix socket {}", path.display())
                    })?;
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding unix listener on {}", path.display()))?;
                Ok(Listener::Unix(l, path.clone()))
            }
        }
    }

    /// The bound address as a dialable endpoint string (resolves an
    /// ephemeral TCP port to the real one).
    pub fn local_desc(&self) -> String {
        match self {
            Listener::Tcp(l) => match l.local_addr() {
                Ok(a) => a.to_string(),
                Err(_) => "tcp:?".to_string(),
            },
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    /// Block for one connection; returns read/write halves plus a peer
    /// description for log lines.
    pub fn accept(&self) -> Result<(Box<dyn Read + Send>, Box<dyn Write + Send>, String)> {
        match self {
            Listener::Tcp(l) => {
                let (stream, peer) = l.accept().context("accepting tcp connection")?;
                let _ = stream.set_nodelay(true);
                let reader = stream.try_clone().context("cloning accepted tcp stream")?;
                Ok((Box::new(reader), Box::new(stream), peer.to_string()))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                let (stream, _) = l.accept().context("accepting unix connection")?;
                let reader = stream.try_clone().context("cloning accepted unix stream")?;
                Ok((Box::new(reader), Box::new(stream), "unix-peer".to_string()))
            }
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

// ------------------------------------------------------------- backend

/// Default in-flight window per connection: deep enough to hide a
/// LAN round-trip behind execution, comfortably inside the worker's
/// read-ahead queue ([`wire::WORKER_READAHEAD`]).
pub const DEFAULT_PIPELINE_DEPTH: usize = 4;

struct NetInner {
    endpoints: Vec<Endpoint>,
    max_restarts_per_worker: usize,
    pipeline_depth: usize,
    /// Per-operation socket deadline (`--job-timeout`); `None` keeps
    /// every socket fully blocking.
    job_timeout: Option<Duration>,
    /// Shared-secret token presented to auth-advertising listeners.
    token: Option<String>,
    restarts: AtomicUsize,
    /// Telemetry publisher, attached by the engine at construction
    /// ([`Backend::attach_events`]).  Interior-mutable because the
    /// backend is already shared (`Arc<dyn Backend>`) by then.
    events: Mutex<Option<EventBus>>,
}

impl NetInner {
    fn publish(&self, event: Event) {
        if let Some(bus) = lock(&self.events).as_ref() {
            bus.publish(event);
        }
    }
}

/// A [`Backend`] that dials every job out to remote worker endpoints.
pub struct NetworkBackend {
    inner: Arc<NetInner>,
}

impl NetworkBackend {
    /// Parse a comma-separated endpoint list (`host:port,unix:/path`).
    pub fn new(workers: &str) -> Result<NetworkBackend> {
        let endpoints = workers
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(Endpoint::parse)
            .collect::<Result<Vec<_>>>()?;
        if endpoints.is_empty() {
            bail!("network backend needs at least one worker endpoint");
        }
        Ok(NetworkBackend::from_endpoints(endpoints))
    }

    /// Build from already-parsed endpoints.
    pub fn from_endpoints(endpoints: Vec<Endpoint>) -> NetworkBackend {
        NetworkBackend {
            inner: Arc::new(NetInner {
                endpoints,
                max_restarts_per_worker: 2,
                pipeline_depth: DEFAULT_PIPELINE_DEPTH,
                job_timeout: None,
                token: None,
                restarts: AtomicUsize::new(0),
                events: Mutex::new(None),
            }),
        }
    }

    /// Set the per-slot reconnect budget (default 2), mirroring
    /// [`super::ProcessBackend::with_max_restarts`].  Builder-style;
    /// must be called before the backend is handed to an engine.
    pub fn with_max_restarts(mut self, max_restarts_per_worker: usize) -> NetworkBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_max_restarts must be called before the backend is shared")
            .max_restarts_per_worker = max_restarts_per_worker;
        self
    }

    /// Set the in-flight window per connection (default
    /// [`DEFAULT_PIPELINE_DEPTH`]): up to `depth` encoded job frames
    /// outstanding per remote worker, replies matched back by key in
    /// completion order.  `1` restores strict lockstep — required when
    /// a byte-determinism suite pins exact reconnect counts, since a
    /// windowed connection death re-dispatches the whole
    /// unacknowledged window on one reconnect.  Builder-style; must be
    /// called before the backend is handed to an engine.
    pub fn with_pipeline_depth(mut self, depth: usize) -> NetworkBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_pipeline_depth must be called before the backend is shared")
            .pipeline_depth = depth.max(1);
        self
    }

    /// Arm a per-operation job deadline (`--job-timeout SECS`): every
    /// connect, write, and reply read on a worker connection must
    /// complete within `timeout` or the connection is declared stalled
    /// and torn down — [`Event::WorkerStalled`] fires, then the
    /// ordinary crash-recovery path (reconnect under the restart
    /// budget, one re-dispatch of the unacknowledged window) takes
    /// over.  `None` (the default) leaves sockets fully blocking:
    /// bit-for-bit identical to an unarmed build, which the
    /// byte-determinism suites rely on.  Builder-style; must be called
    /// before the backend is handed to an engine.
    pub fn with_job_timeout(mut self, timeout: Option<Duration>) -> NetworkBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_job_timeout must be called before the backend is shared")
            .job_timeout = timeout;
        self
    }

    /// Present a shared-secret token (`--token` / `UMUP_TOKEN`) during
    /// the hello handshake.  Listeners that do not advertise auth
    /// ignore it; auth-advertising listeners reject the handshake
    /// without a matching one.  Builder-style; must be called before
    /// the backend is handed to an engine.
    pub fn with_token(mut self, token: Option<String>) -> NetworkBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_token must be called before the backend is shared")
            .token = token;
        self
    }

    /// Total reconnects across all worker slots so far.
    pub fn restarts(&self) -> usize {
        self.inner.restarts.load(Ordering::SeqCst)
    }

    /// How many endpoints this backend round-robins over.
    pub fn n_endpoints(&self) -> usize {
        self.inner.endpoints.len()
    }
}

impl Backend for NetworkBackend {
    fn name(&self) -> &str {
        "network"
    }

    fn capabilities(&self) -> Capabilities {
        // remote workers keep their own per-manifest session pools, so
        // manifest-affine dispatch still pays; crashes stay remote
        Capabilities { session_affinity: true, out_of_process: true }
    }

    /// Fail fast on a bad fleet: dial *every* endpoint once and demand
    /// a valid worker hello from each — including the auth step, so an
    /// auth-advertising fleet with no local `--token` errors at engine
    /// construction, not mid-sweep.  Likewise a typo'd address or a
    /// serve socket in the worker list.
    fn health(&self) -> Result<()> {
        for ep in &self.inner.endpoints {
            let probe = ep.connect_with_deadline(self.inner.job_timeout).and_then(
                |(reader, mut writer)| {
                    let mut reader = BufReader::new(reader);
                    let line = wire::read_frame(&mut reader)?
                        .ok_or_else(|| anyhow!("endpoint hung up before its hello frame"))?;
                    wire::check_hello(&line)?;
                    authenticate(&line, self.inner.token.as_deref(), &mut *writer)
                },
            );
            probe.with_context(|| format!("worker endpoint {ep} health probe failed"))?;
        }
        Ok(())
    }

    fn attach_events(&self, bus: &EventBus) {
        *lock(&self.inner.events) = Some(bus.clone());
    }

    fn spawn_executor(&self, worker_id: usize) -> Box<dyn Executor> {
        Box::new(NetExecutor {
            inner: Arc::clone(&self.inner),
            worker: worker_id,
            // slot k starts at endpoint k % n: 1:1 when slots == endpoints
            cursor: worker_id,
            conn: None,
            connected_once: false,
            restarts_left: self.inner.max_restarts_per_worker,
            last_remote_error: String::new(),
            frame_buf: String::new(),
            batch_buf: String::new(),
            reply_buf: Vec::new(),
        })
    }
}

/// The dial-side auth step, run right after a validated hello: when
/// the listener's hello advertises auth, send the shared-secret token
/// frame (the listener checks it before serving anything).  An
/// auth-advertising hello with no local token configured is a
/// guaranteed rejection, so that case fails here, with the fix spelled
/// out, instead of as an opaque mid-sweep connection death.
fn authenticate(hello: &str, token: Option<&str>, writer: &mut dyn Write) -> Result<()> {
    if !wire::hello_advertises_auth(hello) {
        return Ok(());
    }
    let token = token.ok_or_else(|| {
        anyhow!(
            "endpoint requires a shared-secret token (its hello advertises auth) — \
             pass --token or set UMUP_TOKEN to match the listener's"
        )
    })?;
    wire::write_frame(writer, &wire::token_frame(token)).context("sending auth token frame")
}

// ------------------------------------------------------------ executor

/// A live connection to one remote worker.
struct NetConn {
    reader: BufReader<Box<dyn Read + Send>>,
    writer: Box<dyn Write + Send>,
    peer: String,
}

struct NetExecutor {
    inner: Arc<NetInner>,
    worker: usize,
    /// Next endpoint index to try (advances on every attempt, so a
    /// reconnect after a failure moves on instead of redialing the
    /// same dead address).
    cursor: usize,
    conn: Option<NetConn>,
    /// The first connection is free; later ones consume budget.
    connected_once: bool,
    restarts_left: usize,
    /// Most recent error text a remote worker sent this slot on the
    /// wire (an error reply frame, including the `"?"`-keyed last-words
    /// frame a dying `--listen` worker emits).  Threaded into
    /// restart/budget events and budget-exhaustion messages — the
    /// network stand-in for the process backend's stderr tail.
    last_remote_error: String,
    /// Reused codec scratch (one encoded job frame / one window of
    /// framed jobs / one reply payload): the steady-state dispatch path
    /// allocates nothing per job.
    frame_buf: String,
    batch_buf: String,
    reply_buf: Vec<u8>,
}

/// How one send/receive exchange with the remote worker ended.
enum Exchange {
    Record(RunRecord),
    JobErr(String),
    Transport(anyhow::Error),
}

impl NetExecutor {
    /// Dial the next endpoint(s) round-robin: up to one full lap over
    /// the list, validating the worker hello on each attempt.
    fn connect_next(&mut self) -> Result<NetConn> {
        let n = self.inner.endpoints.len();
        let mut last_err = None;
        for _ in 0..n {
            let ep = self.inner.endpoints[self.cursor % n].clone();
            self.cursor = self.cursor.wrapping_add(1);
            let attempt = ep.connect_with_deadline(self.inner.job_timeout).and_then(
                |(reader, mut writer)| {
                    let mut reader = BufReader::new(reader);
                    let line = wire::read_frame(&mut reader)?
                        .ok_or_else(|| anyhow!("endpoint hung up before its hello frame"))?;
                    wire::check_hello(&line)?;
                    authenticate(&line, self.inner.token.as_deref(), &mut *writer)?;
                    Ok(NetConn { reader, writer, peer: ep.to_string() })
                },
            );
            match attempt {
                Ok(conn) => return Ok(conn),
                Err(e) => {
                    last_err =
                        Some(e.context(format!("dialing worker endpoint {ep}")));
                }
            }
        }
        Err(last_err.expect("endpoint list is never empty"))
    }

    /// The connection for this slot, dialing (budget-gated) if needed.
    fn ensure_conn(&mut self) -> Result<&mut NetConn> {
        if self.conn.is_none() {
            if self.connected_once {
                if self.restarts_left == 0 {
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        // remote stderr stays remote; the worker's last
                        // on-wire error text stands in for the tail
                        stderr: self.last_remote_error.clone(),
                    });
                    bail!(
                        "worker {}: restart budget exhausted ({} reconnects used){}",
                        self.worker,
                        self.inner.max_restarts_per_worker,
                        self.remote_context()
                    );
                }
                self.restarts_left -= 1;
                self.inner.restarts.fetch_add(1, Ordering::SeqCst);
                eprintln!(
                    "engine: reconnecting worker {} ({} reconnects left)",
                    self.worker, self.restarts_left
                );
                self.inner.publish(Event::WorkerRestarted {
                    worker: self.worker,
                    restarts_left: self.restarts_left,
                    stderr: self.last_remote_error.clone(),
                });
            }
            let conn = self.connect_next()?;
            self.connected_once = true;
            self.conn = Some(conn);
        }
        Ok(self.conn.as_mut().expect("just ensured"))
    }

    /// One full job exchange: send the job frame, read the reply frame.
    /// Codec work goes through the executor's reused scratch buffers
    /// (`_into` variants) — no per-job allocation at steady state.
    fn exchange(&mut self, job: &EngineJob, key: &str) -> Exchange {
        let mut frame = std::mem::take(&mut self.frame_buf);
        let mut scratch = std::mem::take(&mut self.reply_buf);
        frame.clear();
        wire::encode_job_into(key, job, &mut frame);
        let out = (|| {
            let conn = match self.ensure_conn() {
                Ok(c) => c,
                Err(e) => return Exchange::Transport(e),
            };
            if let Err(e) = wire::write_frame(&mut conn.writer, &frame) {
                let peer = conn.peer.clone();
                return Exchange::Transport(e.context(format!("sending job to worker {peer}")));
            }
            let reply = wire::read_frame_into(&mut conn.reader, &mut scratch)
                .and_then(|f| f.ok_or_else(|| anyhow!("worker {} hung up mid-job", conn.peer)));
            let line = match reply {
                Ok(line) => line,
                Err(e) => return Exchange::Transport(e.context("reading worker reply")),
            };
            match wire::decode_reply(line) {
                Ok(wire::WireReply::Record { key: reply_key, record }) => {
                    if reply_key != key {
                        return Exchange::Transport(anyhow!(
                            "worker replied for key {reply_key} while {key} was in flight \
                             (protocol desync)"
                        ));
                    }
                    Exchange::Record(record)
                }
                Ok(wire::WireReply::Error { error, .. }) => Exchange::JobErr(error),
                Err(e) => Exchange::Transport(e),
            }
        })();
        self.frame_buf = frame;
        self.reply_buf = scratch;
        if let Exchange::JobErr(e) = &out {
            self.last_remote_error = e.clone();
        }
        out
    }

    /// When an armed `--job-timeout` turns a stalled connection into a
    /// read/write timeout, publish [`Event::WorkerStalled`] before the
    /// normal connection-death recovery runs.  Detection is by io error
    /// kind anywhere in the chain (`WouldBlock` for unix sockets,
    /// `TimedOut` for TCP); with no deadline armed this is a no-op, so
    /// unarmed runs stay bit-for-bit identical.
    fn note_stall(&self, err: &anyhow::Error, pending: usize) {
        let Some(timeout) = self.inner.job_timeout else { return };
        let stalled = err.chain().any(|c| {
            c.downcast_ref::<std::io::Error>().map_or(false, |io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                )
            })
        });
        if !stalled {
            return;
        }
        eprintln!(
            "engine: worker {} stalled past its {}ms job deadline with {} jobs \
             unacknowledged; treating the connection as dead",
            self.worker,
            timeout.as_millis(),
            pending
        );
        self.inner.publish(Event::WorkerStalled {
            worker: self.worker,
            timeout_ms: timeout.as_millis() as u64,
            pending,
        });
    }

    /// Render the worker's last on-wire error text for a message —
    /// the network analogue of `ProcessExecutor::stderr_context`.
    fn remote_context(&self) -> String {
        if self.last_remote_error.is_empty() {
            String::new()
        } else {
            format!("; last error from the remote worker: {}", self.last_remote_error)
        }
    }

    fn teardown_conn(&mut self) {
        // remote workers outlive the engine; dropping the socket is the
        // whole teardown (the worker's per-connection loop sees EOF)
        self.conn = None;
    }

    /// One windowed dispatch attempt — the network mirror of
    /// `ProcessExecutor::pump_window`: ship every still-pending job as
    /// one frame burst, then consume replies in completion order,
    /// matching each to its window slot by key.  An error reply keyed
    /// to nothing in the window (the dying worker's `"?"` last-words
    /// frame) is captured into `last_remote` and surfaced as the
    /// transport error's text.
    fn pump_window(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        pending: &mut Vec<usize>,
        batch: &str,
        scratch: &mut Vec<u8>,
        last_remote: &mut String,
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) -> Result<()> {
        let conn = self.ensure_conn()?;
        wire::flush_frames(&mut conn.writer, batch)
            .with_context(|| format!("sending job window to worker {}", conn.peer))?;
        while !pending.is_empty() {
            let line = wire::read_frame_into(&mut conn.reader, scratch)
                .context("reading worker reply")?
                .ok_or_else(|| {
                    anyhow!(
                        "worker {} hung up with {} jobs unacknowledged",
                        conn.peer,
                        pending.len()
                    )
                })?;
            let (key, outcome) = match wire::decode_reply(line)? {
                wire::WireReply::Record { key, record } => (key, Ok(record)),
                wire::WireReply::Error { key, error } => {
                    last_remote.clear();
                    last_remote.push_str(&error);
                    (key, Err(anyhow!("{error}")))
                }
            };
            let slot = pending.iter().position(|&i| jobs[i].1 == key);
            match (slot, outcome) {
                (Some(slot), outcome) => {
                    let idx = pending.remove(slot);
                    done(idx, outcome);
                }
                (None, Err(remote)) => {
                    // the worker's serve loop died and named its reason
                    // before dropping the connection
                    bail!(
                        "worker {} reported a stream-level failure with {} jobs \
                         unacknowledged: {remote:#}",
                        conn.peer,
                        pending.len()
                    );
                }
                (None, Ok(_)) => bail!(
                    "worker {} replied for key {key} which is not in the in-flight window \
                     (protocol desync or duplicate reply)",
                    conn.peer
                ),
            }
        }
        Ok(())
    }

    /// The windowed dispatch loop — mirrors
    /// `ProcessExecutor::run_window`: one re-dispatch of all
    /// unacknowledged jobs on a fresh (budget-gated) connection, then
    /// per-job `Err`s.
    fn run_window(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) {
        let mut pending: Vec<usize> = (0..jobs.len()).collect();
        let mut first_err: Option<anyhow::Error> = None;
        loop {
            let mut batch = std::mem::take(&mut self.batch_buf);
            let mut frame = std::mem::take(&mut self.frame_buf);
            let mut scratch = std::mem::take(&mut self.reply_buf);
            let mut last_remote = String::new();
            batch.clear();
            for &i in &pending {
                frame.clear();
                wire::encode_job_into(jobs[i].1, jobs[i].0, &mut frame);
                wire::frame_into(&frame, &mut batch);
            }
            let attempt =
                self.pump_window(jobs, &mut pending, &batch, &mut scratch, &mut last_remote, done);
            self.batch_buf = batch;
            self.frame_buf = frame;
            self.reply_buf = scratch;
            if !last_remote.is_empty() {
                self.last_remote_error = last_remote;
            }
            let err = match attempt {
                Ok(()) => return,
                Err(e) => e,
            };
            self.note_stall(&err, pending.len());
            self.teardown_conn();
            match first_err.take() {
                None if self.connected_once && self.restarts_left == 0 => {
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        stderr: self.last_remote_error.clone(),
                    });
                    for &i in &pending {
                        done(
                            i,
                            Err(anyhow!(
                                "worker {} connection lost mid-window on {} ({err:#}); \
                                 restart budget exhausted ({} reconnects used), not \
                                 re-dispatching{}",
                                self.worker,
                                jobs[i].0.config.label,
                                self.inner.max_restarts_per_worker,
                                self.remote_context()
                            )),
                        );
                    }
                    return;
                }
                None => {
                    eprintln!(
                        "engine: worker {} connection lost with {} jobs unacknowledged \
                         ({err:#}); re-dispatching the window once",
                        self.worker,
                        pending.len()
                    );
                    first_err = Some(err);
                }
                Some(first) => {
                    for &i in &pending {
                        done(
                            i,
                            Err(anyhow!(
                                "worker {} failed twice on job {} (first: {first:#}; after \
                                 re-dispatch: {err:#}){}",
                                self.worker,
                                jobs[i].0.config.label,
                                self.remote_context()
                            )),
                        );
                    }
                    return;
                }
            }
        }
    }
}

impl Executor for NetExecutor {
    fn pipeline_depth(&self) -> usize {
        self.inner.pipeline_depth
    }

    /// Windowed dispatch (see the module docs): ship the whole batch as
    /// one frame burst, stream completions back by key.  A single-job
    /// batch routes through [`Executor::run`] so depth-1 behavior —
    /// including exact reconnect accounting — is untouched.
    fn run_batch(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) {
        match jobs {
            [] => {}
            [(job, key)] => done(0, self.run(job, key)),
            _ => self.run_window(jobs, done),
        }
    }

    fn run(&mut self, job: &EngineJob, key: &str) -> Result<RunRecord> {
        match self.exchange(job, key) {
            Exchange::Record(r) => Ok(r),
            Exchange::JobErr(e) => Err(anyhow!("{e}")),
            Exchange::Transport(first) => {
                // the connection is unusable: drop it, then re-dispatch
                // the in-flight job exactly once on a fresh connection —
                // but only announce a re-dispatch that can actually
                // happen (mirrors ProcessExecutor::run)
                self.note_stall(&first, 1);
                self.teardown_conn();
                if self.connected_once && self.restarts_left == 0 {
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        stderr: self.last_remote_error.clone(),
                    });
                    return Err(anyhow!(
                        "worker {} connection lost mid-job on {} ({first:#}); restart \
                         budget exhausted ({} reconnects used), not re-dispatching{}",
                        self.worker,
                        job.config.label,
                        self.inner.max_restarts_per_worker,
                        self.remote_context()
                    ));
                }
                eprintln!(
                    "engine: worker {} connection lost mid-job ({first:#}); \
                     re-dispatching once",
                    self.worker
                );
                match self.exchange(job, key) {
                    Exchange::Record(r) => Ok(r),
                    Exchange::JobErr(e) => Err(anyhow!("{e}")),
                    Exchange::Transport(second) => {
                        self.note_stall(&second, 1);
                        self.teardown_conn();
                        Err(anyhow!(
                            "worker {} failed twice on job {} (first: {first:#}; after \
                             re-dispatch: {second:#}){}",
                            self.worker,
                            job.config.label,
                            self.remote_context()
                        ))
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_parse_tcp_and_unix_and_reject_garbage() {
        match Endpoint::parse("127.0.0.1:7070").unwrap() {
            Endpoint::Tcp(a) => assert_eq!(a, "127.0.0.1:7070"),
            #[cfg(unix)]
            other => panic!("parsed as {other:?}"),
        }
        #[cfg(unix)]
        match Endpoint::parse("unix:/tmp/w.sock").unwrap() {
            Endpoint::Unix(p) => assert_eq!(p, PathBuf::from("/tmp/w.sock")),
            other => panic!("parsed as {other:?}"),
        }
        assert!(Endpoint::parse("no-port-here").is_err());
        assert!(Endpoint::parse("").is_err());
        #[cfg(unix)]
        assert!(Endpoint::parse("unix:").is_err());
    }

    #[test]
    fn backend_parses_endpoint_lists_and_rejects_empty() {
        let b = NetworkBackend::new("127.0.0.1:1,127.0.0.1:2, 127.0.0.1:3").unwrap();
        assert_eq!(b.n_endpoints(), 3);
        assert_eq!(b.name(), "network");
        assert!(b.capabilities().out_of_process);
        assert!(NetworkBackend::new("").is_err());
        assert!(NetworkBackend::new(" , ,").is_err());
    }

    #[test]
    fn listener_binds_ephemeral_port_and_reports_dialable_addr() {
        let l = Listener::bind(&Endpoint::Tcp("127.0.0.1:0".to_string())).unwrap();
        let desc = l.local_desc();
        assert!(desc.starts_with("127.0.0.1:"), "got {desc}");
        assert_ne!(desc, "127.0.0.1:0", "ephemeral port must resolve");
        // the reported address is dialable
        let ep = Endpoint::parse(&desc).unwrap();
        let dial = std::thread::spawn(move || ep.connect().map(|_| ()));
        let (_r, _w, _peer) = l.accept().unwrap();
        dial.join().unwrap().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn unix_bind_reclaims_dead_sockets_but_never_live_ones() {
        let dir = std::env::temp_dir().join(format!("umup-stale-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.sock");
        let ep = Endpoint::Unix(path.clone());
        // a live listener behind the file: a second bind must refuse
        let live = Listener::bind(&ep).unwrap();
        let err = Listener::bind(&ep).unwrap_err().to_string();
        assert!(err.contains("live listener"), "got: {err}");
        drop(live); // our Drop unlinks the path
        assert!(!path.exists(), "Listener drop must unlink its socket");
        // a stale file from a dead process: raw std listeners never
        // unlink on drop, which is exactly the crash leftover shape
        drop(UnixListener::bind(&path).unwrap());
        assert!(path.exists(), "raw UnixListener drop must leave the file");
        let reclaimed = Listener::bind(&ep).unwrap();
        drop(reclaimed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn health_rejects_unreachable_endpoints() {
        // bind then drop: the port is (almost certainly) dead
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let b = NetworkBackend::new(&dead).unwrap();
        let err = b.health().unwrap_err().to_string();
        assert!(err.contains("health probe failed"), "got: {err}");
    }
}
