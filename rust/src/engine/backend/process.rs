//! The out-of-process backend: each engine worker slot owns a spawned
//! worker child (`repro worker`) speaking the [`super::wire`] protocol
//! over stdin/stdout.
//!
//! # Why
//!
//! One process's XLA sessions bound how far a sweep can fan out; child
//! processes bound memory per worker, isolate native crashes (a
//! segfaulting run kills one child, not the sweep), and are the
//! stepping stone to a network/cluster backend — the engine core never
//! learns the difference.
//!
//! # Supervision / restart semantics
//!
//! Each [`Executor`] owns exactly one child at a time (spawned lazily
//! on first use, after [`Backend::health`] has already validated the
//! worker command once at engine construction).  A *transport* failure
//! — the child died, wrote garbage, or tore a frame — is handled
//! per-worker, mirroring the shard driver's supervision pattern
//! (`engine::driver`):
//!
//! 1. the dead child is torn down (killed if needed, always reaped);
//! 2. if the worker's bounded restart budget
//!    ([`ProcessBackend::with_max_restarts`]) allows, a fresh child is
//!    spawned and the in-flight job is **re-dispatched once**;
//! 3. a second transport failure on the same job — or an exhausted
//!    budget — reports the job as a normal `Err` outcome (the engine's
//!    per-job failure isolation takes it from there; the worker slot
//!    itself keeps serving later jobs while budget remains).
//!
//! A *job* failure (the child replies with an error frame) is not a
//! crash: it costs no restart and the same child keeps serving.
//!
//! # Pipelined dispatch
//!
//! With [`ProcessBackend::with_pipeline_depth`] > 1 the executor keeps
//! a *window* of up to `depth` encoded job frames outstanding on the
//! child's stdin at once: the whole window is encoded into one reused
//! scratch buffer (`wire::encode_job_into` + `wire::frame_into`, zero
//! allocation at steady state) and shipped with a single write+flush,
//! then replies are consumed *in completion order* and matched back to
//! their window slot by key.  A reply keyed to nothing in the window
//! (unknown, or a duplicate of an already-acknowledged job) is a
//! protocol desync — a transport failure, never a mis-filed record.
//! Recovery composes with the restart semantics above: a transport
//! failure with a non-empty window re-dispatches **all unacknowledged
//! jobs exactly once** on the freshly spawned child (acknowledged jobs
//! are done — their results were already streamed out); a second
//! failure reports every still-unacknowledged job as a normal per-job
//! `Err`.  The default depth is **1** (strict lockstep, byte-for-byte
//! the pre-pipelining dispatch), which also keeps restart accounting
//! exactly one-job-deep — required by the byte-determinism suites.
//!
//! Child stderr is never lost: a drain thread tees every line to the
//! parent's stderr with a `[worker k]` prefix and keeps a bounded tail,
//! which is appended to transport-failure outcomes so "the child died"
//! errors carry the child's last words.
//!
//! # Deadlines
//!
//! Pipe reads cannot carry socket-style timeouts, so
//! [`ProcessBackend::with_job_timeout`] (`--job-timeout SECS`) arms a
//! [`Watchdog`] thread around every exchange instead: if the child has
//! not replied by the deadline it is SIGKILLed by pid, the blocked read
//! fails with EOF, [`Event::WorkerStalled`] fires, and the ordinary
//! transport-failure recovery above (restart + one re-dispatch) takes
//! over.  Windowed dispatch re-arms per reply, so a window of `n` jobs
//! legitimately gets `n` single-job deadlines end to end.  The default
//! is unarmed: no watchdog thread exists and the dispatch path is
//! bit-for-bit identical to a build without deadlines.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::train::RunRecord;
use crate::util::signal;

use super::super::events::{Event, EventBus};
use super::super::job::EngineJob;
use super::super::lock;
use super::wire;
use super::{Backend, Capabilities, Executor};

/// Stderr lines retained per worker for failure context.
const STDERR_TAIL_LINES: usize = 12;

/// How long to wait for a child to exit on its own (after stdin EOF)
/// before killing it.
const SHUTDOWN_GRACE: Duration = Duration::from_millis(500);

/// Watchdog poll granularity: how promptly a disarm is noticed and the
/// worst-case overshoot past the deadline.
const WATCHDOG_TICK: Duration = Duration::from_millis(10);

/// A one-shot deadline over one pipe exchange with a hung-but-alive
/// child.  The thread sleeps toward the deadline and, unless
/// [`Watchdog::disarm`]ed first, SIGKILLs the child by pid — the
/// blocked pipe read then fails, and the normal transport-failure
/// recovery (restart + one re-dispatch) takes over.  Kill-by-pid
/// because `Child::kill` needs `&mut Child`, which the blocked reader
/// holds.
struct Watchdog {
    cancel: Arc<AtomicBool>,
    fired: Arc<AtomicBool>,
    thread: JoinHandle<()>,
}

impl Watchdog {
    /// Arm: unless disarmed first, `pid` is SIGKILLed after `timeout`.
    fn arm(pid: u32, timeout: Duration) -> Watchdog {
        let cancel = Arc::new(AtomicBool::new(false));
        let fired = Arc::new(AtomicBool::new(false));
        let (c, f) = (Arc::clone(&cancel), Arc::clone(&fired));
        let thread = std::thread::spawn(move || {
            let deadline = Instant::now() + timeout;
            loop {
                if c.load(Ordering::SeqCst) {
                    return;
                }
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                std::thread::sleep(left.min(WATCHDOG_TICK));
            }
            if !c.load(Ordering::SeqCst) {
                f.store(true, Ordering::SeqCst);
                signal::send(pid, signal::SIGKILL);
            }
        });
        Watchdog { cancel, fired, thread }
    }

    /// Disarm and reap the watchdog thread; true if it already fired
    /// (the child blew the deadline and was killed).
    fn disarm(self) -> bool {
        self.cancel.store(true, Ordering::SeqCst);
        let _ = self.thread.join();
        self.fired.load(Ordering::SeqCst)
    }
}

struct Inner {
    make_cmd: Box<dyn Fn(usize) -> Command + Send + Sync>,
    max_restarts_per_worker: usize,
    pipeline_depth: usize,
    /// Per-exchange deadline (`--job-timeout`); `None` arms nothing.
    job_timeout: Option<Duration>,
    restarts: AtomicUsize,
    /// Telemetry publisher, attached by the engine at construction
    /// ([`Backend::attach_events`]).  Interior-mutable because the
    /// backend is already shared (`Arc<dyn Backend>`) by then.
    events: Mutex<Option<EventBus>>,
}

impl Inner {
    fn publish(&self, event: Event) {
        if let Some(bus) = lock(&self.events).as_ref() {
            bus.publish(event);
        }
    }
}

/// A [`Backend`] that runs every job in a pool of spawned worker
/// processes.  Construct with [`ProcessBackend::new`] (an arbitrary
/// worker command) or [`ProcessBackend::repro_worker`] (this binary's
/// `repro worker` subcommand).
pub struct ProcessBackend {
    inner: Arc<Inner>,
}

impl ProcessBackend {
    /// A backend whose worker `k` is the child process built by
    /// `make_cmd(k)`.  The command must speak the [`wire`] protocol on
    /// stdin/stdout (stdio is overridden to piped on spawn).
    pub fn new<F>(make_cmd: F) -> ProcessBackend
    where
        F: Fn(usize) -> Command + Send + Sync + 'static,
    {
        ProcessBackend {
            inner: Arc::new(Inner {
                make_cmd: Box::new(make_cmd),
                max_restarts_per_worker: 2,
                pipeline_depth: 1,
                job_timeout: None,
                restarts: AtomicUsize::new(0),
                events: Mutex::new(None),
            }),
        }
    }

    /// A backend that spawns this very binary's `repro worker`
    /// subcommand — the standard production shape.  `mock` selects the
    /// deterministic mock executor (no XLA, no artifacts needed).
    /// `sessions` is forwarded as the child's `--sessions` cap and must
    /// match the engine's `max_sessions_per_worker`, so the scheduler's
    /// warm-manifest mirror models the pool the child actually keeps.
    pub fn repro_worker(artifacts: &str, mock: bool, sessions: usize) -> Result<ProcessBackend> {
        let exe = std::env::current_exe().context("resolving the repro binary path")?;
        let artifacts = artifacts.to_string();
        Ok(ProcessBackend::new(move |_worker| {
            let mut cmd = Command::new(&exe);
            cmd.arg("worker")
                .arg("--artifacts")
                .arg(&artifacts)
                .arg("--sessions")
                .arg(sessions.to_string());
            if mock {
                cmd.arg("--mock");
            }
            cmd
        }))
    }

    /// Set the per-worker restart budget (default 2): how many times
    /// one worker slot may respawn its child after a transport failure
    /// before jobs on that slot report errors instead.  Builder-style;
    /// must be called before the backend is handed to an engine.
    pub fn with_max_restarts(mut self, max_restarts_per_worker: usize) -> ProcessBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_max_restarts must be called before the backend is shared")
            .max_restarts_per_worker = max_restarts_per_worker;
        self
    }

    /// Set the in-flight window per child (default 1 = strict
    /// lockstep): up to `depth` encoded job frames outstanding on one
    /// child's stdin, replies matched back by key in completion order.
    /// Values above 1 trade the per-job round-trip stall for window
    /// throughput; keep 1 when byte-determinism suites pin exact
    /// restart counts (a windowed crash re-dispatches the *whole*
    /// unacknowledged window on one restart).  Builder-style; must be
    /// called before the backend is handed to an engine.
    pub fn with_pipeline_depth(mut self, depth: usize) -> ProcessBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_pipeline_depth must be called before the backend is shared")
            .pipeline_depth = depth.max(1);
        self
    }

    /// Arm a per-exchange job deadline (`--job-timeout SECS`): a child
    /// that has not replied within `timeout` is declared stalled and
    /// SIGKILLed by a [`Watchdog`] thread — [`Event::WorkerStalled`]
    /// fires, then the ordinary crash recovery (respawn under the
    /// restart budget, one re-dispatch of the unacknowledged window)
    /// takes over.  `None` (the default) arms nothing: bit-for-bit
    /// identical to an unarmed build, which the byte-determinism
    /// suites rely on.  Builder-style; must be called before the
    /// backend is handed to an engine.
    pub fn with_job_timeout(mut self, timeout: Option<Duration>) -> ProcessBackend {
        Arc::get_mut(&mut self.inner)
            .expect("with_job_timeout must be called before the backend is shared")
            .job_timeout = timeout;
        self
    }

    /// Total child restarts across all worker slots so far.
    pub fn restarts(&self) -> usize {
        self.inner.restarts.load(Ordering::SeqCst)
    }
}

impl Backend for ProcessBackend {
    fn name(&self) -> &str {
        "process"
    }

    fn capabilities(&self) -> Capabilities {
        // children keep their own per-manifest session pools, so
        // manifest-affine dispatch still pays; crashes stay isolated
        Capabilities { session_affinity: true, out_of_process: true }
    }

    fn attach_events(&self, bus: &EventBus) {
        *lock(&self.inner.events) = Some(bus.clone());
    }

    /// Fail fast on a broken worker command: spawn one probe child,
    /// demand a valid hello frame, and reap it.  Runs once, at engine
    /// construction, so a missing binary or wrong `--artifacts` path
    /// errors there instead of on every job.
    fn health(&self) -> Result<()> {
        let mut cmd = (self.inner.make_cmd)(0);
        cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = cmd.spawn().context("spawning worker health probe")?;
        // close stdin immediately: a well-behaved worker writes its
        // hello then exits on EOF, so the probe never hangs on a child
        // that is merely waiting for jobs
        drop(child.stdin.take());
        // drain stderr *concurrently* with the hello wait: a chatty
        // child (verbose native init, debug logging) that writes more
        // than the pipe buffer before its hello would otherwise block
        // on a full pipe while we block on its stdout — deadlock.  Keep
        // a bounded tail so a failed probe still names the real cause
        // (e.g. a bad --artifacts path failing the registry open).
        let stderr = child.stderr.take().expect("probe stderr is piped");
        let drain = std::thread::spawn(move || {
            let mut tail: VecDeque<String> = VecDeque::new();
            for line in BufReader::new(stderr).lines() {
                let Ok(line) = line else { break };
                if tail.len() >= STDERR_TAIL_LINES {
                    tail.pop_front();
                }
                tail.push_back(line);
            }
            tail
        });
        let stdout = child.stdout.take().expect("probe stdout is piped");
        let mut reader = BufReader::new(stdout);
        let hello = wire::read_frame(&mut reader)
            .and_then(|f| f.ok_or_else(|| anyhow!("worker exited before its hello frame")))
            .and_then(|line| wire::check_hello(&line));
        if hello.is_err() {
            let _ = child.kill();
        }
        let _ = child.wait();
        // the child is dead, so the drain hits EOF and the join is
        // prompt; its tail feeds the error message
        let tail = drain.join().unwrap_or_default();
        hello
            .map_err(|e| {
                let tail: Vec<&str> =
                    tail.iter().map(|l| l.trim()).filter(|l| !l.is_empty()).collect();
                if tail.is_empty() {
                    e
                } else {
                    e.context(format!("probe child stderr (tail):\n{}", tail.join("\n")))
                }
            })
            .context("worker health probe failed (wrong binary or broken worker command?)")
    }

    fn spawn_executor(&self, worker_id: usize) -> Box<dyn Executor> {
        Box::new(ProcessExecutor {
            inner: Arc::clone(&self.inner),
            worker: worker_id,
            conn: None,
            spawned_once: false,
            restarts_left: self.inner.max_restarts_per_worker,
            stderr_tail: Arc::new(Mutex::new(VecDeque::new())),
            frame_buf: String::new(),
            batch_buf: String::new(),
            reply_buf: Vec::new(),
        })
    }
}

// ------------------------------------------------------------ executor

/// A live child: the pipes plus the stderr drain thread.
struct ChildConn {
    child: Child,
    stdin: Option<ChildStdin>,
    stdout: BufReader<ChildStdout>,
    stderr_thread: Option<JoinHandle<()>>,
}

struct ProcessExecutor {
    inner: Arc<Inner>,
    worker: usize,
    conn: Option<ChildConn>,
    /// The first spawn is free; every later one consumes restart budget.
    spawned_once: bool,
    restarts_left: usize,
    /// Last [`STDERR_TAIL_LINES`] stderr lines across this slot's
    /// children (appended to transport-failure outcomes).
    stderr_tail: Arc<Mutex<VecDeque<String>>>,
    /// Reused codec scratch (one encoded job frame / one window of
    /// framed jobs / one reply payload): the steady-state dispatch path
    /// allocates nothing per job.
    frame_buf: String,
    batch_buf: String,
    reply_buf: Vec<u8>,
}

/// How one send/receive exchange with the child ended.
enum Exchange {
    /// A completed record.
    Record(RunRecord),
    /// The child reported the job failed (child itself is healthy).
    JobErr(String),
    /// The child (or its stream) is gone; restart territory.
    Transport(anyhow::Error),
}

impl ProcessExecutor {
    fn spawn_child(&mut self) -> Result<ChildConn> {
        let mut cmd = (self.inner.make_cmd)(self.worker);
        cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawning worker {} child process", self.worker))?;
        let stdin = child.stdin.take().expect("worker stdin is piped");
        let stdout = child.stdout.take().expect("worker stdout is piped");
        let stderr = child.stderr.take().expect("worker stderr is piped");
        let worker = self.worker;
        let tail = Arc::clone(&self.stderr_tail);
        // tee the child's stderr: every line to the parent's stderr
        // with a worker prefix, and a bounded tail for error outcomes
        let stderr_thread = std::thread::spawn(move || {
            for line in BufReader::new(stderr).lines() {
                let Ok(line) = line else { break };
                eprintln!("[worker {worker}] {line}");
                let mut tail = lock(&tail);
                if tail.len() >= STDERR_TAIL_LINES {
                    tail.pop_front();
                }
                tail.push_back(line);
            }
        });
        let mut conn = ChildConn {
            child,
            stdin: Some(stdin),
            stdout: BufReader::new(stdout),
            stderr_thread: Some(stderr_thread),
        };
        let hello = wire::read_frame(&mut conn.stdout)
            .and_then(|f| f.ok_or_else(|| anyhow!("worker exited before its hello frame")))
            .and_then(|line| wire::check_hello(&line));
        match hello {
            Ok(()) => Ok(conn),
            Err(e) => {
                teardown(&mut conn);
                Err(e.context(format!("worker {} child failed its handshake", self.worker)))
            }
        }
    }

    /// The child for this slot, spawning (budget-gated) if necessary.
    fn ensure_conn(&mut self) -> Result<&mut ChildConn> {
        if self.conn.is_none() {
            if self.spawned_once {
                if self.restarts_left == 0 {
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        stderr: self.stderr_excerpt(),
                    });
                    bail!(
                        "worker {}: restart budget exhausted ({} restarts used){}",
                        self.worker,
                        self.inner.max_restarts_per_worker,
                        self.stderr_context()
                    );
                }
                self.restarts_left -= 1;
                self.inner.restarts.fetch_add(1, Ordering::SeqCst);
                eprintln!(
                    "engine: restarting worker {} child ({} restarts left)",
                    self.worker, self.restarts_left
                );
                self.inner.publish(Event::WorkerRestarted {
                    worker: self.worker,
                    restarts_left: self.restarts_left,
                    stderr: self.stderr_excerpt(),
                });
            }
            let conn = self.spawn_child()?;
            self.spawned_once = true;
            self.conn = Some(conn);
        }
        Ok(self.conn.as_mut().expect("just ensured"))
    }

    /// One full job exchange: send the job frame, read the reply frame.
    /// Codec work goes through the executor's reused scratch buffers
    /// (`_into` variants) — no per-job allocation at steady state.
    fn exchange(&mut self, job: &EngineJob, key: &str) -> Exchange {
        let mut frame = std::mem::take(&mut self.frame_buf);
        let mut scratch = std::mem::take(&mut self.reply_buf);
        frame.clear();
        wire::encode_job_into(key, job, &mut frame);
        let timeout = self.inner.job_timeout;
        let mut stalled = false;
        let out = (|| {
            let conn = match self.ensure_conn() {
                Ok(c) => c,
                Err(e) => return Exchange::Transport(e),
            };
            // one armed deadline covers the whole write+read round trip
            let dog = timeout.map(|t| Watchdog::arm(conn.child.id(), t));
            let send = conn
                .stdin
                .as_mut()
                .ok_or_else(|| anyhow!("worker stdin already closed"))
                .and_then(|stdin| wire::write_frame(stdin, &frame));
            if let Err(e) = send {
                stalled = dog.map_or(false, Watchdog::disarm);
                return Exchange::Transport(e.context("sending job to worker child"));
            }
            let reply = wire::read_frame_into(&mut conn.stdout, &mut scratch)
                .and_then(|f| f.ok_or_else(|| anyhow!("worker child hung up mid-job")));
            stalled = dog.map_or(false, Watchdog::disarm);
            let line = match reply {
                Ok(line) => line,
                Err(e) => return Exchange::Transport(e.context("reading worker reply")),
            };
            match wire::decode_reply(line) {
                Ok(wire::WireReply::Record { key: reply_key, record }) => {
                    if reply_key != key {
                        return Exchange::Transport(anyhow!(
                            "worker replied for key {reply_key} while {key} was in flight \
                             (protocol desync)"
                        ));
                    }
                    Exchange::Record(record)
                }
                Ok(wire::WireReply::Error { error, .. }) => Exchange::JobErr(error),
                Err(e) => Exchange::Transport(e),
            }
        })();
        self.frame_buf = frame;
        self.reply_buf = scratch;
        if stalled {
            self.note_stall(1);
        }
        out
    }

    /// Publish [`Event::WorkerStalled`] after a watchdog kill, so
    /// telemetry records a deadline stall rather than an anonymous
    /// child crash.  The stall is always followed by the recovery
    /// path's `worker_restarted` or `worker_budget_exhausted`.
    fn note_stall(&self, pending: usize) {
        let timeout_ms = self.inner.job_timeout.map_or(0, |t| t.as_millis() as u64);
        eprintln!(
            "engine: worker {} child stalled past its {}ms job deadline with {} jobs \
             unacknowledged; killed",
            self.worker, timeout_ms, pending
        );
        self.inner.publish(Event::WorkerStalled {
            worker: self.worker,
            timeout_ms,
            pending,
        });
    }

    /// The raw retained stderr tail (for event payloads).
    fn stderr_excerpt(&self) -> String {
        lock(&self.stderr_tail).iter().cloned().collect::<Vec<_>>().join("\n")
    }

    /// Render the retained stderr tail for an error message.
    fn stderr_context(&self) -> String {
        let tail = lock(&self.stderr_tail);
        if tail.is_empty() {
            return String::new();
        }
        let mut out = String::from("; recent child stderr:");
        for line in tail.iter() {
            out.push_str("\n  | ");
            out.push_str(line);
        }
        out
    }

    fn teardown_conn(&mut self) {
        if let Some(mut conn) = self.conn.take() {
            teardown(&mut conn);
        }
    }

    /// One windowed dispatch attempt: ship every still-pending job as a
    /// single frame batch, then read replies (completion order),
    /// matching each back to its window slot by key.  Acknowledged jobs
    /// are reported through `done` and removed from `pending` as their
    /// replies land, so on a transport `Err` the caller re-dispatches
    /// exactly the unacknowledged remainder.  `batch` must hold the
    /// frames of `pending` (in order) — encoded by the caller so the
    /// scratch buffers don't fight the `self` borrow.  `stalled` is set
    /// when an armed job deadline killed the child mid-window.
    fn pump_window(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        pending: &mut Vec<usize>,
        batch: &str,
        scratch: &mut Vec<u8>,
        stalled: &mut bool,
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) -> Result<()> {
        let timeout = self.inner.job_timeout;
        let conn = self.ensure_conn()?;
        let pid = conn.child.id();
        // a wedged child can also stall the flush by never draining its
        // stdin pipe, so the write leg gets a deadline of its own
        let dog = timeout.map(|t| Watchdog::arm(pid, t));
        let sent = conn
            .stdin
            .as_mut()
            .ok_or_else(|| anyhow!("worker stdin already closed"))
            .and_then(|stdin| wire::flush_frames(stdin, batch));
        *stalled |= dog.map_or(false, Watchdog::disarm);
        sent.context("sending job window to worker child")?;
        while !pending.is_empty() {
            // each reply re-arms: a window of n jobs legitimately takes
            // n single-job times end to end
            let dog = timeout.map(|t| Watchdog::arm(pid, t));
            let read = wire::read_frame_into(&mut conn.stdout, scratch);
            *stalled |= dog.map_or(false, Watchdog::disarm);
            let line = read.context("reading worker reply")?.ok_or_else(|| {
                anyhow!("worker child hung up with {} jobs unacknowledged", pending.len())
            })?;
            let (key, outcome) = match wire::decode_reply(line)? {
                wire::WireReply::Record { key, record } => (key, Ok(record)),
                wire::WireReply::Error { key, error } => (key, Err(anyhow!("{error}"))),
            };
            let slot = pending.iter().position(|&i| jobs[i].1 == key).ok_or_else(|| {
                anyhow!(
                    "worker replied for key {key} which is not in the in-flight window \
                     (protocol desync or duplicate reply)"
                )
            })?;
            let idx = pending.remove(slot);
            done(idx, outcome);
        }
        Ok(())
    }
}

impl Executor for ProcessExecutor {
    fn pipeline_depth(&self) -> usize {
        self.inner.pipeline_depth
    }

    /// Windowed dispatch (see the module docs): ship the whole batch as
    /// one frame burst, stream completions back by key.  A single-job
    /// batch routes through [`Executor::run`] so depth-1 behavior —
    /// including the exact restart accounting the byte-determinism
    /// suites pin — is untouched.
    fn run_batch(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) {
        match jobs {
            [] => {}
            [(job, key)] => done(0, self.run(job, key)),
            _ => self.run_window(jobs, done),
        }
    }

    fn run(&mut self, job: &EngineJob, key: &str) -> Result<RunRecord> {
        match self.exchange(job, key) {
            Exchange::Record(r) => Ok(r),
            Exchange::JobErr(e) => Err(anyhow!("{e}")),
            Exchange::Transport(first) => {
                // the child is unusable: tear it down, then re-dispatch
                // the in-flight job exactly once on a fresh child —
                // but only announce a re-dispatch that can actually
                // happen: with the restart budget exhausted there is no
                // fresh child to spawn, so report the *first* failure's
                // context (plus the budget note) instead of logging a
                // phantom retry and burning a spawn attempt.
                self.teardown_conn();
                if self.spawned_once && self.restarts_left == 0 {
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        stderr: self.stderr_excerpt(),
                    });
                    return Err(anyhow!(
                        "worker {} child lost mid-job on {} ({first:#}); restart budget \
                         exhausted ({} restarts used), not re-dispatching{}",
                        self.worker,
                        job.config.label,
                        self.inner.max_restarts_per_worker,
                        self.stderr_context()
                    ));
                }
                eprintln!(
                    "engine: worker {} child lost mid-job ({first:#}); re-dispatching once",
                    self.worker
                );
                match self.exchange(job, key) {
                    Exchange::Record(r) => Ok(r),
                    Exchange::JobErr(e) => Err(anyhow!("{e}")),
                    Exchange::Transport(second) => {
                        self.teardown_conn();
                        Err(anyhow!(
                            "worker {} child failed twice on job {} (first: {first:#}; \
                             after re-dispatch: {second:#}){}",
                            self.worker,
                            job.config.label,
                            self.stderr_context()
                        ))
                    }
                }
            }
        }
    }
}

impl ProcessExecutor {
    /// The windowed dispatch loop shared conceptually with the network
    /// executor: attempt the window, and on a transport failure tear
    /// the child down and re-dispatch **all unacknowledged jobs exactly
    /// once** on a fresh (budget-gated) child; a second transport
    /// failure — or an already-exhausted budget — reports every
    /// still-unacknowledged job as a per-job `Err`.
    fn run_window(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) {
        let mut pending: Vec<usize> = (0..jobs.len()).collect();
        let mut first_err: Option<anyhow::Error> = None;
        loop {
            // encode the pending window before touching the connection
            // (the scratch buffers can't be borrowed across ensure_conn)
            let mut batch = std::mem::take(&mut self.batch_buf);
            let mut frame = std::mem::take(&mut self.frame_buf);
            let mut scratch = std::mem::take(&mut self.reply_buf);
            batch.clear();
            for &i in &pending {
                frame.clear();
                wire::encode_job_into(jobs[i].1, jobs[i].0, &mut frame);
                wire::frame_into(&frame, &mut batch);
            }
            let mut stalled = false;
            let attempt =
                self.pump_window(jobs, &mut pending, &batch, &mut scratch, &mut stalled, done);
            self.batch_buf = batch;
            self.frame_buf = frame;
            self.reply_buf = scratch;
            if stalled {
                self.note_stall(pending.len());
            }
            let err = match attempt {
                Ok(()) => return,
                Err(e) => e,
            };
            self.teardown_conn();
            match first_err.take() {
                None if self.spawned_once && self.restarts_left == 0 => {
                    // no fresh child to re-dispatch on: report the first
                    // failure's context plus the budget note, like the
                    // lockstep path
                    self.inner.publish(Event::WorkerBudgetExhausted {
                        worker: self.worker,
                        stderr: self.stderr_excerpt(),
                    });
                    for &i in &pending {
                        done(
                            i,
                            Err(anyhow!(
                                "worker {} child lost mid-window on {} ({err:#}); restart \
                                 budget exhausted ({} restarts used), not re-dispatching{}",
                                self.worker,
                                jobs[i].0.config.label,
                                self.inner.max_restarts_per_worker,
                                self.stderr_context()
                            )),
                        );
                    }
                    return;
                }
                None => {
                    eprintln!(
                        "engine: worker {} child lost with {} jobs unacknowledged ({err:#}); \
                         re-dispatching the window once",
                        self.worker,
                        pending.len()
                    );
                    first_err = Some(err);
                }
                Some(first) => {
                    for &i in &pending {
                        done(
                            i,
                            Err(anyhow!(
                                "worker {} child failed twice on job {} (first: {first:#}; \
                                 after re-dispatch: {err:#}){}",
                                self.worker,
                                jobs[i].0.config.label,
                                self.stderr_context()
                            )),
                        );
                    }
                    return;
                }
            }
        }
    }
}

impl Drop for ProcessExecutor {
    fn drop(&mut self) {
        self.teardown_conn();
    }
}

/// Stop a child: close stdin (a well-behaved worker exits on EOF), give
/// it a grace period, kill it otherwise, and always reap — a torn-down
/// drain never leaves zombies.
fn teardown(conn: &mut ChildConn) {
    drop(conn.stdin.take());
    let deadline = Instant::now() + SHUTDOWN_GRACE;
    loop {
        match conn.child.try_wait() {
            Ok(Some(_)) => break,
            Ok(None) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(10));
            }
            _ => {
                let _ = conn.child.kill();
                let _ = conn.child.wait();
                break;
            }
        }
    }
    if let Some(t) = conn.stderr_thread.take() {
        // the child is dead, so its stderr is at (or about to hit) EOF
        let _ = t.join();
    }
}
