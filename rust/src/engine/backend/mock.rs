//! The mock backend: closure-driven executors for tests and benches.
//!
//! This is the no-XLA execution path — the engine's queueing, caching,
//! sharding, scheduling and failure machinery is exercised against
//! plain closures, on any machine.  [`det_record`] is the *canonical*
//! deterministic mock result: the integration harnesses
//! (`tests/common`), `repro worker --mock`, and the backend benches all
//! derive their records from it, which is what makes "process-backend
//! drain == in-process drain, byte-for-byte in the cache" a testable
//! contract.

use std::collections::BTreeMap;

use crate::train::{RunConfig, RunRecord};

use super::super::job::EngineJob;
use super::super::pool::JobExec;
use super::{Backend, Capabilities, Executor, FnExecutor};

/// The canonical deterministic mock record: a pure function of the run
/// config (loss = 2 + η over an 8-step curve).  Every mock peer that
/// must agree byte-for-byte with another derives its records here.
pub fn det_record(cfg: &RunConfig) -> RunRecord {
    RunRecord {
        label: cfg.label.clone(),
        train_curve: vec![(1, 3.0 + cfg.hp.eta), (8, 2.0 + cfg.hp.eta)],
        valid_curve: vec![(8, 2.0 + cfg.hp.eta)],
        final_valid_loss: 2.0 + cfg.hp.eta,
        rms_curves: BTreeMap::new(),
        final_rms: vec![("w.head".to_string(), 1.0)],
        diverged: false,
        wall_seconds: 0.01,
    }
}

/// A backend whose executors are built by a per-worker closure factory
/// — the engine's test seam (and the implementation behind the
/// deprecated `Engine::with_factory` shim).
pub struct MockBackend {
    factory: Box<dyn Fn(usize) -> JobExec + Send + Sync>,
    affinity: bool,
}

impl MockBackend {
    /// A backend that builds each worker's executor with `factory`
    /// (called on the worker's own thread, so the executor may own
    /// mutable per-worker state).
    pub fn new<F>(factory: F) -> MockBackend
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        MockBackend { factory: Box::new(factory), affinity: true }
    }

    /// The canonical deterministic mock: every job resolves instantly
    /// to [`det_record`].
    pub fn deterministic() -> MockBackend {
        Self::new(|_worker| Box::new(|job: &EngineJob| Ok(det_record(&job.config))))
    }

    /// Advertise no per-manifest warm state
    /// ([`Capabilities::session_affinity`] = false): the scheduler
    /// dispatches plain priority+FIFO and keeps no warm mirror.
    pub fn without_affinity(mut self) -> MockBackend {
        self.affinity = false;
        self
    }
}

impl Backend for MockBackend {
    fn name(&self) -> &str {
        "mock"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { session_affinity: self.affinity, ..Capabilities::default() }
    }

    fn spawn_executor(&self, worker_id: usize) -> Box<dyn Executor> {
        Box::new(FnExecutor((self.factory)(worker_id)))
    }
}
