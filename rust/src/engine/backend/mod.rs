//! Pluggable execution backends: *where* the engine's jobs run.
//!
//! The engine's scheduling/caching/handle machinery is execution-
//! agnostic; everything that actually trains lives behind the
//! [`Backend`] trait.  A backend is shared by every worker thread
//! (`Send + Sync`, held as an `Arc<dyn Backend>`) and hands each worker
//! its own [`Executor`] via [`Backend::spawn_executor`] — the executor
//! is created *inside* the worker thread, so it may own `!Send` state
//! (XLA sessions, child-process pipes) for that worker's lifetime.
//!
//! # Trait contract
//!
//! * [`Backend::spawn_executor`] is called once per worker, on the
//!   worker's own thread, and must not block on other workers.
//! * [`Executor::run`] executes one job to completion and returns its
//!   [`RunRecord`] or an error.  Errors (and panics, which the worker
//!   loop catches) are per-job: they are reported as that job's
//!   outcome and the worker keeps pulling.  An executor that loses its
//!   underlying resource (e.g. a crashed child process) is expected to
//!   recover *internally* if it can — see the restart semantics on
//!   [`ProcessBackend`] — and to return an `Err` only when the job is
//!   genuinely lost.
//! * The engine persists a successful record to the run cache *before*
//!   the outcome is reported (see [`crate::engine`] docs); executors
//!   never touch the cache themselves.
//! * [`Backend::health`] runs once, at engine construction, before any
//!   worker starts: fail fast here (missing worker binary, bad
//!   protocol) instead of erroring every job.  [`Backend::shutdown`]
//!   runs once after every worker (and its executor) has been torn
//!   down — a place for fleet-level cleanup; per-worker resources
//!   belong to the executor's `Drop`.
//! * [`Backend::capabilities`] is queried once at construction; the
//!   scheduler reads [`Capabilities::session_affinity`] to decide
//!   whether manifest-affine dispatch is worth tracking (see
//!   [`crate::engine`] module docs).
//!
//! # Implementations
//!
//! * `XlaBackend` (behind the `xla` feature) — the in-process path:
//!   each worker owns an [`LruPool`](crate::engine::LruPool) of
//!   compiled XLA sessions.
//! * [`MockBackend`] — the test/bench path: executors are plain
//!   closures ([`JobExec`]); [`MockBackend::deterministic`] is the
//!   canonical mock used by the integration harnesses and
//!   `repro worker --mock`.
//! * [`ProcessBackend`] — the out-of-process path: each worker slot
//!   owns a spawned `repro worker` child speaking the [`wire`]
//!   protocol over stdin/stdout, with bounded restart-on-crash.
//! * [`NetworkBackend`] — the cluster path: each worker slot dials a
//!   long-lived `repro worker --listen` endpoint (TCP or Unix socket)
//!   from a round-robin list, speaking the same [`wire`] frames with
//!   bounded reconnect-on-failure.  [`Endpoint`] / [`Listener`] are the
//!   shared dial/accept halves, reused by the `repro serve` control
//!   plane ([`crate::engine::serve`]).
//!
//! The engine core never learns which of these it is running on.
//! The [`chaos`] module is the adversarial mirror of the network path:
//! a deterministic fault-injecting proxy ([`FaultPlan`], `repro chaos`)
//! that the chaos suite wedges between an engine and its workers to
//! prove every recovery path yields byte-identical results.

pub mod chaos;
pub mod wire;

mod mock;
mod net;
mod process;
#[cfg(feature = "xla")]
mod xla;

pub use chaos::FaultPlan;
pub use mock::{det_record, MockBackend};
pub use net::{Endpoint, Listener, NetworkBackend};
pub use process::ProcessBackend;
#[cfg(feature = "xla")]
pub use xla::XlaBackend;

use anyhow::Result;

use crate::train::RunRecord;

use super::job::EngineJob;
use super::pool::JobExec;

/// What a backend can (or cannot) do, queried once by
/// [`crate::engine::Engine::with_backend`] at construction.
#[derive(Debug, Clone, Copy)]
pub struct Capabilities {
    /// Executors keep per-manifest warm state (compiled sessions) worth
    /// scheduling around: the scheduler mirrors each worker's session
    /// pool and prefers warm-manifest dispatch.  Backends without
    /// per-manifest state disable this to get plain priority+FIFO
    /// dispatch (and no hit/steal accounting).
    pub session_affinity: bool,
    /// Jobs execute outside this process: an executor crash cannot take
    /// the engine down, and host memory is bounded per child.
    pub out_of_process: bool,
}

impl Default for Capabilities {
    fn default() -> Self {
        Capabilities { session_affinity: true, out_of_process: false }
    }
}

/// A source of per-worker [`Executor`]s — the engine's execution seam.
/// See the module docs for the full contract.
pub trait Backend: Send + Sync {
    /// Short human name for logs and error contexts (`"in-process"`,
    /// `"process"`, `"mock"`).
    fn name(&self) -> &str;

    /// Capability flags; queried once at engine construction.
    fn capabilities(&self) -> Capabilities {
        Capabilities::default()
    }

    /// Fail-fast probe run once before any worker starts (default: ok).
    fn health(&self) -> Result<()> {
        Ok(())
    }

    /// Build worker `worker_id`'s executor.  Called on the worker's own
    /// thread, so the returned executor may own `!Send` state.
    fn spawn_executor(&self, worker_id: usize) -> Box<dyn Executor>;

    /// Receive the engine's telemetry publisher, once, at engine
    /// construction (before any worker starts).  Backends supervising
    /// out-of-process resources publish their lifecycle onto it
    /// (`worker_restarted` / `worker_budget_exhausted` with teed stderr
    /// excerpts); the default keeps in-process backends event-free.
    /// Publishing must follow the bus contract: never block.
    fn attach_events(&self, _bus: &crate::engine::events::EventBus) {}

    /// Fleet-level teardown hook, run once after all workers have
    /// exited and dropped their executors (default: no-op).
    fn shutdown(&self) {}
}

/// One worker's job runner.  Owned by a single worker thread; never
/// crosses threads.
pub trait Executor {
    /// Execute `job` (whose content address is `key`) to completion.
    fn run(&mut self, job: &EngineJob, key: &str) -> Result<RunRecord>;

    /// How many jobs this executor wants in flight at once — the
    /// worker loop pulls batches of up to this size from the scheduler
    /// and hands them to [`Executor::run_batch`].  `1` (the default)
    /// is strict lockstep: pull one, run one, report one.  Pipelining
    /// executors (see [`ProcessBackend::with_pipeline_depth`] /
    /// [`NetworkBackend::with_pipeline_depth`]) raise it to overlap
    /// frame encoding, the wire, and the peer's execution.
    fn pipeline_depth(&self) -> usize {
        1
    }

    /// Execute a batch of jobs, reporting each completion through
    /// `done(index_into_jobs, result)` — **exactly once per job, in any
    /// order**.  The engine persists and publishes each outcome from
    /// inside the callback, so results stream as the executor produces
    /// them rather than when the whole batch lands.  The default runs
    /// the batch sequentially through [`Executor::run`], which is the
    /// depth-1 semantics; only executors with a real in-flight window
    /// override this.
    fn run_batch(
        &mut self,
        jobs: &[(&EngineJob, &str)],
        done: &mut dyn FnMut(usize, Result<RunRecord>),
    ) {
        for (i, (job, key)) in jobs.iter().enumerate() {
            done(i, self.run(job, key));
        }
    }
}

/// [`Executor`] over a plain closure — the adapter behind
/// [`MockBackend`] and the deprecated `Engine::with_factory` shim.
pub(crate) struct FnExecutor(pub(crate) JobExec);

impl Executor for FnExecutor {
    fn run(&mut self, job: &EngineJob, _key: &str) -> Result<RunRecord> {
        (self.0)(job)
    }
}
