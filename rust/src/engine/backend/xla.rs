//! The in-process XLA backend: each worker owns an LRU pool of
//! compiled PJRT sessions.
//!
//! This is the default production path (`Engine::new`).  Sessions are
//! `!Send`, so they live inside the executor — created on the worker's
//! thread, compiled on first use per (worker, manifest), LRU-evicted
//! past the configured cap, and amortized across every submission the
//! engine ever sees.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::Session;
use crate::train::{RunRecord, Runner};

use super::super::job::EngineJob;
use super::super::lru::LruPool;
use super::{Backend, Capabilities, Executor};

/// The in-process execution backend: jobs run on this process's XLA
/// sessions, pooled per worker.
pub struct XlaBackend {
    max_sessions_per_worker: usize,
}

impl XlaBackend {
    /// A backend whose workers each hold up to `max_sessions_per_worker`
    /// compiled sessions (LRU-evicted beyond that).
    pub fn new(max_sessions_per_worker: usize) -> XlaBackend {
        XlaBackend { max_sessions_per_worker: max_sessions_per_worker.max(1) }
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        "in-process"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { session_affinity: true, out_of_process: false }
    }

    fn spawn_executor(&self, _worker_id: usize) -> Box<dyn Executor> {
        Box::new(XlaExecutor { sessions: LruPool::new(self.max_sessions_per_worker) })
    }
}

struct XlaExecutor {
    sessions: LruPool<Runner>,
}

impl Executor for XlaExecutor {
    fn run(&mut self, job: &EngineJob, _key: &str) -> Result<RunRecord> {
        let runner = self.sessions.get_or_create(&job.manifest.name, || {
            let session = Session::open(Arc::clone(&job.manifest))
                .with_context(|| format!("opening worker session for {}", job.manifest.name))?;
            Ok(Runner::new(Arc::new(session)))
        })?;
        runner.run(&job.config, &job.corpus)
    }
}
