//! The worker wire protocol: length-prefixed JSON frames over
//! stdin/stdout.
//!
//! A frame is the payload's byte length in ASCII decimal, a newline,
//! the payload (UTF-8 JSON, one line), and a trailing newline:
//!
//! ```text
//! 33\n{"hello":"umup-worker","proto":1}\n
//! ```
//!
//! The conversation is strictly half-duplex, parent-driven:
//!
//! 1. child → parent: one **hello** frame on startup
//!    (`{"hello":"umup-worker","proto":1}`) — the parent's handshake
//!    and health probe;
//! 2. parent → child: one **job** frame per run — the manifest *name*,
//!    the corpus generator config, and the
//!    [`RunConfig::canonical_json`] body plus the presentation label
//!    (which the canonical form deliberately excludes), keyed by the
//!    job's content address;
//! 3. child → parent: one **reply** frame per job — on success the
//!    exact run-cache line codec from [`crate::engine::cache`]
//!    (`{"key":…,"manifest":…,"record":…,"ts":…}`), so the wire format
//!    *is* the cache format and no separate serialization layer
//!    exists; on a job-level failure `{"error":…,"key":…}`.
//!
//! Anything else on the stream — garbage bytes, a torn frame, EOF
//! mid-payload — is a *transport* error: the parent treats the child
//! as dead (see [`super::ProcessBackend`]'s restart semantics), and a
//! child that cannot parse a frame exits nonzero rather than guess.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{BufRead, Read, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::CorpusConfig;
use crate::engine::cache::{
    corpus_json, corpus_json_into, entry_line, entry_line_into, now_ts, parse_full_entry,
};
use crate::engine::job::EngineJob;
use crate::train::{RunConfig, RunRecord};
use crate::util::{write_json_str, Json};

/// Protocol revision; bumped on any frame-shape change.  The hello
/// frame carries it so a parent never feeds jobs to a worker from a
/// different build of the wire format.
pub const PROTO_VERSION: u64 = 1;

/// Upper bound on one frame's payload (a run record with full RMS
/// telemetry is ~100 KiB; anything near this cap is corruption).
const MAX_FRAME_BYTES: usize = 64 << 20;

/// Write one frame: `<len>\n<payload>\n`, flushed (the peer blocks on
/// it).
pub fn write_frame(w: &mut impl Write, payload: &str) -> Result<()> {
    write!(w, "{}\n{payload}\n", payload.len()).context("writing wire frame")?;
    w.flush().context("flushing wire frame")
}

/// Append one framed payload (`<len>\n<payload>\n`) to a caller-owned
/// buffer *without* touching the transport — the batching half of the
/// pipelined hot path: encode a whole window into one scratch `String`,
/// then ship it with a single `write_all` + `flush`
/// ([`flush_frames`]) instead of one syscall pair per frame.
pub fn frame_into(payload: &str, out: &mut String) {
    let _ = write!(out, "{}\n{payload}\n", payload.len());
}

/// Ship a batch of frames accumulated by [`frame_into`]: one
/// `write_all`, one `flush`.  The buffer is left intact (callers
/// `clear()` it for reuse).
pub fn flush_frames(w: &mut impl Write, batch: &str) -> Result<()> {
    w.write_all(batch.as_bytes()).context("writing wire frame batch")?;
    w.flush().context("flushing wire frame batch")
}

/// [`read_frame`] into a caller-owned scratch buffer: on the steady
/// state (frames no larger than any previously seen) the hot loop
/// performs **zero** heap allocation.  Returns the payload as a
/// borrowed `&str` view of `scratch`; `Ok(None)` on clean EOF at a
/// frame boundary.  Error semantics are identical to [`read_frame`]
/// (pinned by the adversarial suite in `tests/net.rs`).
pub fn read_frame_into<'a>(
    r: &mut impl BufRead,
    scratch: &'a mut Vec<u8>,
) -> Result<Option<&'a str>> {
    scratch.clear();
    // bound the prefix read exactly like `read_frame`: a valid length
    // line is ≤ 22 bytes, and newline-free garbage must fail here
    let n = r
        .by_ref()
        .take(64)
        .read_until(b'\n', scratch)
        .context("reading frame length prefix")?;
    if n == 0 {
        return Ok(None);
    }
    let trimmed = std::str::from_utf8(scratch)
        .map(str::trim)
        .map_err(|_| anyhow!("bad frame length prefix (non-UTF-8 garbage on the stream?)"))?;
    let len: usize = trimmed
        .parse()
        .with_context(|| format!("bad frame length prefix {trimmed:?} (garbage on the stream?)"))?;
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    // payload + its trailing newline; resize reuses capacity
    scratch.clear();
    scratch.resize(len + 1, 0);
    r.read_exact(scratch)
        .with_context(|| format!("reading {len}-byte frame payload (torn frame?)"))?;
    if scratch.pop() != Some(b'\n') {
        bail!("frame payload is not newline-terminated (framing lost)");
    }
    let payload = std::str::from_utf8(scratch).context("frame payload is not UTF-8")?;
    Ok(Some(payload))
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.  Any
/// malformed prefix, short payload, or missing terminator is an error —
/// the caller treats the stream as dead.
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut prefix = String::new();
    // bound the prefix read: a valid length line is ≤ 22 bytes, and a
    // peer streaming newline-free garbage must fail here, not buffer
    // the whole stream into memory first
    let n = r
        .by_ref()
        .take(64)
        .read_line(&mut prefix)
        .context("reading frame length prefix")?;
    if n == 0 {
        return Ok(None);
    }
    let trimmed = prefix.trim();
    let len: usize = trimmed
        .parse()
        .with_context(|| format!("bad frame length prefix {trimmed:?} (garbage on the stream?)"))?;
    if len > MAX_FRAME_BYTES {
        bail!("frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    // payload + its trailing newline
    let mut buf = vec![0u8; len + 1];
    r.read_exact(&mut buf)
        .with_context(|| format!("reading {len}-byte frame payload (torn frame?)"))?;
    if buf.pop() != Some(b'\n') {
        bail!("frame payload is not newline-terminated (framing lost)");
    }
    let payload = String::from_utf8(buf).context("frame payload is not UTF-8")?;
    Ok(Some(payload))
}

// -------------------------------------------------------------- hello

fn peer_hello_line(who: &str) -> String {
    peer_hello_line_auth(who, false)
}

/// Hello with an optional shared-secret advertisement: a listener
/// started with `--token`/`UMUP_TOKEN` adds `"auth":true` (additive —
/// token-less peers still parse the hello, then fail with a pointed
/// hint instead of a codec error), telling the dialer to send one
/// [`token_frame`] before any other traffic.
fn peer_hello_line_auth(who: &str, auth: bool) -> String {
    let mut m = BTreeMap::new();
    if auth {
        m.insert("auth".to_string(), Json::Bool(true));
    }
    m.insert("hello".to_string(), Json::Str(who.to_string()));
    m.insert("proto".to_string(), Json::Num(PROTO_VERSION as f64));
    Json::Obj(m).dump()
}

fn check_peer_hello(line: &str, expect: &str) -> Result<()> {
    let j = Json::parse(line).context("parsing peer hello frame")?;
    let who = j.get("hello")?.as_str()?;
    if who != expect {
        // the two sockets a fleet exposes are easy to cross-wire; name
        // the fix instead of just the mismatch
        if who == "umup-serve" && expect == "umup-worker" {
            bail!(
                "peer is a `repro serve` control socket, not a worker — point \
                 worker endpoints at `repro worker --listen` and `repro ctl` at \
                 the serve socket"
            );
        }
        bail!("peer identifies as {who:?}, not {expect:?}");
    }
    let proto = j.get("proto")?.as_f64()? as u64;
    if proto != PROTO_VERSION {
        bail!("peer speaks wire protocol {proto}, this build speaks {PROTO_VERSION}");
    }
    Ok(())
}

/// The worker child's startup frame.
pub fn hello_line() -> String {
    peer_hello_line("umup-worker")
}

/// Validate a worker hello frame (wrong binary / wrong protocol fail
/// fast).
pub fn check_hello(line: &str) -> Result<()> {
    check_peer_hello(line, "umup-worker")
}

/// The `repro serve` daemon's startup frame — deliberately distinct
/// from the worker hello, so an engine mistakenly pointed at a control
/// socket fails its handshake instead of feeding jobs to the
/// coordinator (and vice versa).
pub fn serve_hello_line() -> String {
    peer_hello_line("umup-serve")
}

/// Validate a serve hello frame.
pub fn check_serve_hello(line: &str) -> Result<()> {
    check_peer_hello(line, "umup-serve")
}

/// The worker child's startup frame, advertising shared-secret auth
/// when the listener was started with a token.
pub fn hello_line_auth(auth: bool) -> String {
    peer_hello_line_auth("umup-worker", auth)
}

/// The `repro serve` daemon's startup frame, with the auth
/// advertisement.
pub fn serve_hello_line_auth(auth: bool) -> String {
    peer_hello_line_auth("umup-serve", auth)
}

/// Does this (already [`check_hello`]-validated) hello demand a token
/// frame before any other traffic?
pub fn hello_advertises_auth(line: &str) -> bool {
    Json::parse(line)
        .ok()
        .and_then(|j| j.get("auth").ok().and_then(|a| a.as_bool().ok()))
        .unwrap_or(false)
}

/// The dialer's answer to an auth-advertising hello: one
/// `{"token":…}` frame, sent before any job or RPC frame.
pub fn token_frame(token: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("token".to_string(), Json::Str(token.to_string()));
    Json::Obj(m).dump()
}

/// Listener-side validation of the dialer's token frame.  The error
/// text never echoes either secret; it names the fix instead.
pub fn check_token_frame(line: &str, expect: &str) -> Result<()> {
    let j = Json::parse(line).context("parsing auth token frame")?;
    let got = j.get("token").and_then(|t| t.as_str()).map_err(|_| {
        anyhow!(
            "peer sent no token frame after the auth-advertising hello — \
             pass the listener's shared secret via --token or UMUP_TOKEN"
        )
    })?;
    if got != expect {
        bail!(
            "shared-secret mismatch: the dialer's --token/UMUP_TOKEN does not \
             match this listener's"
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- jobs

/// One decoded job frame — everything a worker process needs to
/// reconstruct the run: the manifest by *name* (resolved against the
/// worker's own artifact registry), the corpus by generator config
/// (corpora are deterministic functions of it), and the full
/// [`RunConfig`].
pub struct WireJob {
    /// The run's content address; replies must echo it.
    pub key: String,
    pub manifest: String,
    pub corpus: CorpusConfig,
    pub config: RunConfig,
}

/// Encode a job frame payload for `job` (content address `key`).
pub fn encode_job(key: &str, job: &EngineJob) -> String {
    let mut line = String::new();
    encode_job_into(key, job, &mut line);
    line
}

/// [`encode_job`] into a caller-owned buffer (appended, not cleared):
/// the zero-realloc dispatch path.  Hand-writes the sorted-key object
/// byte-for-byte (`config`, `corpus`, `key`, `label`, `manifest`); the
/// canonical config was already serialized once for this job's run key,
/// so those bytes are spliced verbatim instead of rebuilding the tree.
/// Byte-equality with the tree writer is pinned by a unit test below.
pub fn encode_job_into(key: &str, job: &EngineJob, out: &mut String) {
    out.push_str("{\"config\":");
    out.push_str(job.canonical_config_json());
    out.push_str(",\"corpus\":");
    corpus_json_into(&job.corpus.config, out);
    out.push_str(",\"key\":");
    write_json_str(key, out);
    out.push_str(",\"label\":");
    write_json_str(&job.config.label, out);
    out.push_str(",\"manifest\":");
    write_json_str(&job.manifest.name, out);
    out.push('}');
}

/// Decode a job frame payload.
pub fn decode_job(line: &str) -> Result<WireJob> {
    let j = Json::parse(line).context("parsing wire job frame")?;
    let key = j.get("key")?.as_str()?.to_string();
    let manifest = j.get("manifest")?.as_str()?.to_string();
    let label = j.get("label")?.as_str()?;
    let c = j.get("corpus")?;
    let corpus = CorpusConfig {
        vocab: c.get("vocab")?.as_usize()?,
        n_tokens: c.get("n_tokens")?.as_usize()?,
        seed: c.get("seed")?.as_f64()? as u64,
        zipf_s: c.get("zipf_s")?.as_f64()?,
        k_succ: c.get("k_succ")?.as_usize()?,
        smoothing: c.get("smoothing")?.as_f64()?,
        valid_frac: c.get("valid_frac")?.as_f64()?,
    };
    let config = RunConfig::from_canonical_json(j.get("config")?, label)?;
    Ok(WireJob { key, manifest, corpus, config })
}

// -------------------------------------------------------------- replies

/// One decoded reply frame.
pub enum WireReply {
    /// The job completed; `record` is what the parent persists.
    Record { key: String, record: RunRecord },
    /// The job failed *in the child* (the child itself is healthy).
    Error { key: String, error: String },
}

/// Encode a success reply — byte-identical to the run-cache line codec.
pub fn ok_reply_line(key: &str, manifest: &str, record: &RunRecord) -> String {
    entry_line(key, manifest, now_ts(), record)
}

/// [`ok_reply_line`] into a caller-owned buffer (appended): the
/// worker's zero-realloc reply path.
pub fn ok_reply_line_into(key: &str, manifest: &str, record: &RunRecord, out: &mut String) {
    entry_line_into(key, manifest, now_ts(), record, out);
}

/// Encode a job-failure reply.
pub fn err_reply_line(key: &str, error: &str) -> String {
    let mut line = String::new();
    err_reply_line_into(key, error, &mut line);
    line
}

/// [`err_reply_line`] into a caller-owned buffer (appended).  Same
/// sorted-key shape (`error`, `key`) as the tree writer it replaced.
pub fn err_reply_line_into(key: &str, error: &str, out: &mut String) {
    out.push_str("{\"error\":");
    write_json_str(error, out);
    out.push_str(",\"key\":");
    write_json_str(key, out);
    out.push('}');
}

/// Decode a reply frame payload.
pub fn decode_reply(line: &str) -> Result<WireReply> {
    let j = Json::parse(line).context("parsing worker reply frame")?;
    if let Ok(e) = j.get("error") {
        let key = match j.get("key") {
            Ok(k) => k.as_str().unwrap_or("?").to_string(),
            Err(_) => "?".to_string(),
        };
        return Ok(WireReply::Error { key, error: e.as_str()?.to_string() });
    }
    let entry = parse_full_entry(line).context("parsing worker reply as a cache line")?;
    Ok(WireReply::Record { key: entry.key, record: entry.record })
}

// ----------------------------------------------------------------- rpc
//
// Control-plane frames for the `repro serve` daemon: the same
// `<len>\n<payload>\n` framing as the worker protocol, carrying
// id-tagged request/reply envelopes instead of job/record lines.  A
// client connects, reads the daemon's [`serve_hello_line`], then sends
// any number of requests on one connection; every reply echoes the id
// of the request it answers, so a client may pipeline.

/// One decoded control-plane request.
pub struct RpcRequest {
    /// Client-chosen tag; the reply echoes it.
    pub id: u64,
    /// What to do: `submit`, `status`, `cancel`, `cache-stats`,
    /// `shutdown` (the serve loop rejects anything else with an error
    /// reply, never a dropped connection).
    pub verb: String,
    /// Verb-specific arguments (`Json::Null` when absent).
    pub params: Json,
}

/// Encode a request frame payload.
pub fn rpc_request_line(id: u64, verb: &str, params: &Json) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("params".to_string(), params.clone());
    m.insert("verb".to_string(), Json::Str(verb.to_string()));
    Json::Obj(m).dump()
}

/// Decode a request frame payload.
pub fn decode_rpc_request(line: &str) -> Result<RpcRequest> {
    let j = Json::parse(line).context("parsing rpc request frame")?;
    let id = j.get("id")?.as_f64()? as u64;
    let verb = j.get("verb")?.as_str()?.to_string();
    let params = match j.get("params") {
        Ok(p) => p.clone(),
        Err(_) => Json::Null,
    };
    Ok(RpcRequest { id, verb, params })
}

/// One decoded control-plane reply.
pub enum RpcReply {
    /// The request succeeded; `result` is verb-specific.
    Ok { id: u64, result: Json },
    /// The request failed (the connection itself stays usable).
    Err { id: u64, error: String },
}

/// Encode a success reply frame payload.
pub fn rpc_ok_line(id: u64, result: &Json) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("result".to_string(), result.clone());
    Json::Obj(m).dump()
}

/// Encode a failure reply frame payload.
pub fn rpc_err_line(id: u64, error: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(error.to_string()));
    m.insert("id".to_string(), Json::Num(id as f64));
    Json::Obj(m).dump()
}

/// Decode a reply frame payload.
pub fn decode_rpc_reply(line: &str) -> Result<RpcReply> {
    let j = Json::parse(line).context("parsing rpc reply frame")?;
    let id = j.get("id")?.as_f64()? as u64;
    if let Ok(e) = j.get("error") {
        return Ok(RpcReply::Err { id, error: e.as_str()?.to_string() });
    }
    Ok(RpcReply::Ok { id, result: j.get("result")?.clone() })
}

// --------------------------------------------------------------- serve

/// How many decoded job frames a worker holds ahead of execution: deep
/// enough to hide the parent's encode+send latency behind the current
/// job's run, small enough that a dying worker strands at most a
/// window's worth of re-dispatchable work (see the pipelined executors
/// in `process.rs`/`net.rs`, which bound their in-flight window
/// independently).
pub const WORKER_READAHEAD: usize = 8;

/// A worker process's main loop: write the hello frame, then answer job
/// frames with reply frames until the parent hangs up (EOF).  `exec`
/// failures become error replies (the loop continues); protocol
/// failures — unparseable frames — return `Err`, and the process
/// should exit nonzero so the parent's supervisor restarts it.
///
/// **Pipelining:** a scoped reader thread decodes incoming frames ahead
/// of execution into a bounded queue ([`WORKER_READAHEAD`]), so frame
/// parsing overlaps the current job's run and a pipelining parent can
/// keep several frames in flight without the worker's socket buffer
/// filling.  Replies are written in execution (= arrival) order; a
/// windowed parent matches them by key, so completion-order streaming
/// is safe end to end.  The reply loop reuses one scratch buffer
/// through the `_into` codec — zero allocation per frame at steady
/// state.
///
/// The XLA `repro worker` serves through this function.  The `--mock`
/// worker hand-rolls the same frame sequence in `main.rs` instead
/// (its env-armed failure injection needs raw access to the output
/// stream between decode and reply) — any change to the frame shapes
/// here must be mirrored there, and the byte-identity suite in
/// `tests/backend.rs` will catch a divergence.
pub fn serve<R, W, F>(input: R, output: W, exec: F) -> Result<()>
where
    R: BufRead + Send,
    W: Write,
    F: FnMut(&WireJob) -> Result<RunRecord>,
{
    serve_authed(input, output, None, exec)
}

/// [`serve`] plus the listener-side half of the shared-secret
/// handshake: the hello advertises auth when `token` is set, and the
/// dialer's [`token_frame`] is read and validated before any job frame
/// is accepted.  A peer that hangs up instead of sending a token (a
/// port probe, a drain self-dial) ends the loop quietly; a missing or
/// mismatched token fails it, which `--listen` workers report back on
/// the wire before closing the connection.
pub fn serve_authed<R, W, F>(
    mut input: R,
    mut output: W,
    token: Option<&str>,
    mut exec: F,
) -> Result<()>
where
    R: BufRead + Send,
    W: Write,
    F: FnMut(&WireJob) -> Result<RunRecord>,
{
    write_frame(&mut output, &hello_line_auth(token.is_some()))?;
    if let Some(expect) = token {
        match read_frame(&mut input)? {
            Some(line) => check_token_frame(&line, expect)?,
            None => return Ok(()),
        }
    }
    let (tx, rx) = std::sync::mpsc::sync_channel::<Result<WireJob>>(WORKER_READAHEAD);
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut input = input;
            let mut scratch = Vec::new();
            loop {
                let job = match read_frame_into(&mut input, &mut scratch) {
                    Ok(Some(line)) => decode_job(line),
                    Ok(None) => break,
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                };
                let stop = job.is_err();
                if tx.send(job).is_err() || stop {
                    break;
                }
            }
        });
        // `rx` must die with this closure: an early error return drops
        // it here, unblocking a reader parked on a full queue before
        // the scope joins the thread
        let rx = rx;
        let mut reply = String::new();
        for job in rx.iter() {
            let job = job?;
            reply.clear();
            match exec(&job) {
                Ok(record) => ok_reply_line_into(&job.key, &job.manifest, &record, &mut reply),
                Err(e) => err_reply_line_into(&job.key, &format!("{e:#}"), &mut reply),
            }
            write_frame(&mut output, &reply)?;
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use std::io::Cursor;
    use std::sync::Arc;

    use super::*;
    use crate::data::Corpus;
    use crate::parametrization::{HpSet, Parametrization, Scheme};
    use crate::runtime::{Manifest, Spec};

    fn frame_roundtrip(payload: &str) -> String {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        let mut r = Cursor::new(buf);
        read_frame(&mut r).unwrap().expect("one frame in, one frame out")
    }

    #[test]
    fn frames_round_trip_including_embedded_newlines_length() {
        for payload in ["", "x", "{\"a\":1}", "päylöad"] {
            assert_eq!(frame_roundtrip(payload), payload);
        }
        // two frames back to back, then clean EOF
        let mut buf = Vec::new();
        write_frame(&mut buf, "one").unwrap();
        write_frame(&mut buf, "two").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("one"));
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("two"));
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn read_frame_into_matches_read_frame_and_reuses_scratch() {
        // round-trip, multiple frames through ONE scratch buffer
        let mut buf = Vec::new();
        write_frame(&mut buf, "a longer first frame payload").unwrap();
        write_frame(&mut buf, "two").unwrap();
        write_frame(&mut buf, "").unwrap();
        let mut r = Cursor::new(buf);
        let mut scratch = Vec::new();
        assert_eq!(
            read_frame_into(&mut r, &mut scratch).unwrap(),
            Some("a longer first frame payload")
        );
        let cap = scratch.capacity();
        assert_eq!(read_frame_into(&mut r, &mut scratch).unwrap(), Some("two"));
        assert_eq!(read_frame_into(&mut r, &mut scratch).unwrap(), Some(""));
        assert!(read_frame_into(&mut r, &mut scratch).unwrap().is_none());
        // steady state: smaller frames never grew the scratch buffer
        assert_eq!(scratch.capacity(), cap, "scratch reallocated on a smaller frame");
        // identical adversarial semantics to `read_frame`
        for bad in [
            b"this is not a frame\n".to_vec(),
            b"100\n{\"half\":".to_vec(),
            b"2\nabX".to_vec(),
            format!("{}\n", usize::MAX).into_bytes(),
        ] {
            let mut r = Cursor::new(bad);
            assert!(read_frame_into(&mut r, &mut scratch).is_err());
        }
    }

    #[test]
    fn frame_into_batches_read_back_frame_by_frame() {
        let mut batch = String::new();
        frame_into("one", &mut batch);
        frame_into("{\"a\":1}", &mut batch);
        frame_into("päylöad", &mut batch);
        let mut out = Vec::new();
        flush_frames(&mut out, &batch).unwrap();
        // byte-identical to three write_frame calls
        let mut seq = Vec::new();
        for p in ["one", "{\"a\":1}", "päylöad"] {
            write_frame(&mut seq, p).unwrap();
        }
        assert_eq!(out, seq);
        let mut r = Cursor::new(out);
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("one"));
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("{\"a\":1}"));
        assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some("päylöad"));
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn garbage_and_torn_frames_are_errors_not_hangs() {
        // garbage prefix
        let mut r = Cursor::new(b"this is not a frame\n".to_vec());
        assert!(read_frame(&mut r).is_err());
        // truncated payload (prefix promises more bytes than exist)
        let mut r = Cursor::new(b"100\n{\"half\":".to_vec());
        assert!(read_frame(&mut r).is_err());
        // missing terminator (payload followed by the wrong byte)
        let mut r = Cursor::new(b"2\nabX".to_vec());
        assert!(read_frame(&mut r).is_err());
        // absurd length
        let mut r = Cursor::new(format!("{}\n", usize::MAX).into_bytes());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn hello_line_validates_and_rejects_imposters() {
        check_hello(&hello_line()).unwrap();
        assert!(check_hello("{\"hello\":\"someone-else\",\"proto\":1}").is_err());
        assert!(check_hello("{\"hello\":\"umup-worker\",\"proto\":999}").is_err());
        assert!(check_hello("usage: repro <command>").is_err());
    }

    #[test]
    fn serve_hello_is_distinct_and_cross_wiring_names_the_fix() {
        check_serve_hello(&serve_hello_line()).unwrap();
        // engine dialed the control socket: error explains the fix
        let err = check_hello(&serve_hello_line()).unwrap_err().to_string();
        assert!(err.contains("control socket"), "unhelpful error: {err}");
        // ctl dialed a worker socket: plain identity mismatch
        assert!(check_serve_hello(&hello_line()).is_err());
        assert!(check_serve_hello("{\"hello\":\"umup-serve\",\"proto\":999}").is_err());
    }

    #[test]
    fn auth_advertisement_is_additive_and_token_frames_validate() {
        // an auth-advertising hello still passes the identity check —
        // the `auth` key is an additive field, not a new protocol
        check_hello(&hello_line_auth(true)).unwrap();
        check_serve_hello(&serve_hello_line_auth(true)).unwrap();
        // advertisement round trip, and its absence on the open hellos
        assert!(hello_advertises_auth(&hello_line_auth(true)));
        assert!(hello_advertises_auth(&serve_hello_line_auth(true)));
        assert!(!hello_advertises_auth(&hello_line()));
        assert!(!hello_advertises_auth(&serve_hello_line()));
        assert!(!hello_advertises_auth(&hello_line_auth(false)));
        // token validation: match passes, mismatch and non-token frames
        // fail with hints that never echo a secret
        check_token_frame(&token_frame("s3cret"), "s3cret").unwrap();
        let err = check_token_frame(&token_frame("wrong"), "s3cret").unwrap_err().to_string();
        assert!(err.contains("mismatch"), "unhelpful error: {err}");
        assert!(!err.contains("s3cret") && !err.contains("wrong"), "error echoes a secret: {err}");
        let err = check_token_frame(&hello_line(), "s3cret").unwrap_err().to_string();
        assert!(err.contains("UMUP_TOKEN"), "unhelpful error: {err}");
    }

    #[test]
    fn serve_authed_gates_jobs_behind_the_token_frame() {
        let job = test_job();
        // right token: the job gets its reply
        let mut input = Vec::new();
        write_frame(&mut input, &token_frame("s3cret")).unwrap();
        write_frame(&mut input, &encode_job("authedkey", &job)).unwrap();
        let mut output = Vec::new();
        serve_authed(Cursor::new(input), &mut output, Some("s3cret"), |j| {
            Ok(det_record_for(&j.key))
        })
        .unwrap();
        let mut r = Cursor::new(output);
        let hello = read_frame(&mut r).unwrap().unwrap();
        check_hello(&hello).unwrap();
        assert!(hello_advertises_auth(&hello));
        match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
            WireReply::Record { key, .. } => assert_eq!(key, "authedkey"),
            WireReply::Error { error, .. } => panic!("authed job failed: {error}"),
        }
        // wrong token: the loop fails before any job executes
        let mut input = Vec::new();
        write_frame(&mut input, &token_frame("wrong")).unwrap();
        write_frame(&mut input, &encode_job("unreached", &job)).unwrap();
        let mut output = Vec::new();
        let err = serve_authed(Cursor::new(input), &mut output, Some("s3cret"), |_| {
            panic!("job executed despite a bad token")
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"), "got: {err:#}");
        // EOF in place of the token frame (a probe) is a quiet exit
        let mut output = Vec::new();
        serve_authed(Cursor::new(Vec::new()), &mut output, Some("s3cret"), |_| {
            panic!("job executed on a probe connection")
        })
        .unwrap();
    }

    #[test]
    fn rpc_frames_round_trip_and_tag_ids() {
        // request with params
        let mut params = std::collections::BTreeMap::new();
        params.insert("sweep".to_string(), Json::Num(3.0));
        let params = Json::Obj(params);
        let req = decode_rpc_request(&rpc_request_line(42, "status", &params)).unwrap();
        assert_eq!(req.id, 42);
        assert_eq!(req.verb, "status");
        assert_eq!(req.params.get("sweep").unwrap().as_usize().unwrap(), 3);
        // request without params decodes to Null, not an error
        let req = decode_rpc_request("{\"id\":7,\"verb\":\"cache-stats\"}").unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.params, Json::Null);
        // ok reply
        match decode_rpc_reply(&rpc_ok_line(42, &Json::Num(24.0))).unwrap() {
            RpcReply::Ok { id, result } => {
                assert_eq!(id, 42);
                assert_eq!(result.as_usize().unwrap(), 24);
            }
            RpcReply::Err { .. } => panic!("ok reply decoded as error"),
        }
        // error reply (connection-level: stays decodable, id preserved)
        match decode_rpc_reply(&rpc_err_line(42, "no such sweep")).unwrap() {
            RpcReply::Err { id, error } => {
                assert_eq!(id, 42);
                assert!(error.contains("no such sweep"));
            }
            RpcReply::Ok { .. } => panic!("error reply decoded as ok"),
        }
        // garbage is an error, not a panic
        assert!(decode_rpc_request("not json").is_err());
        assert!(decode_rpc_reply("{\"id\":1}").is_err());
    }

    fn test_job() -> EngineJob {
        let man = Arc::new(Manifest {
            name: "w32_test".to_string(),
            dir: std::path::PathBuf::from("."),
            spec: Spec {
                width: 32,
                depth: 2,
                batch: 4,
                seq: 16,
                vocab: 64,
                head_dim: 16,
                trainable_norms: false,
            },
            tensors: vec![],
            n_params: 0,
            state_ext_len: 1,
            loss_offset: 0,
            rms_offset: 1,
            scale_sites: std::collections::BTreeMap::new(),
            n_scale_sites: 0,
            quant_sites: std::collections::BTreeMap::new(),
            n_quant_sites: 0,
            rms_sites: vec![],
        });
        let corpus = Arc::new(Corpus {
            config: CorpusConfig { vocab: 64, n_tokens: 12345, seed: 9, ..Default::default() },
            tokens: vec![],
            n_train: 0,
        });
        let mut config = RunConfig::quick(
            "wire-label",
            Parametrization::new(Scheme::Umup),
            HpSet::with_eta(0.375),
            16,
        );
        config.seed = 42;
        config.lr_tweaks = vec![("emb".to_string(), 4.0)];
        EngineJob::new(man, corpus, config, vec![])
    }

    #[test]
    fn job_frames_round_trip_config_corpus_and_label() {
        let job = test_job();
        let line = encode_job("00aabbccddeeff11", &job);
        let back = decode_job(&line).unwrap();
        assert_eq!(back.key, "00aabbccddeeff11");
        assert_eq!(back.manifest, "w32_test");
        assert_eq!(back.corpus.n_tokens, 12345);
        assert_eq!(back.corpus.seed, 9);
        assert_eq!(back.config.label, "wire-label");
        // the decoded config is content-identical: same canonical form
        assert_eq!(back.config.canonical_json().dump(), job.config.canonical_json().dump());
    }

    /// The hand-rolled encoders must stay byte-identical to the tree
    /// writers they replaced — the cache byte-determinism contract and
    /// the run-key stability both ride on the frame bytes.
    #[test]
    fn into_encoders_match_tree_writers_byte_for_byte() {
        let job = test_job();
        let mut tree = BTreeMap::new();
        tree.insert(
            "config".to_string(),
            Json::parse(job.canonical_config_json()).unwrap(),
        );
        tree.insert("corpus".to_string(), corpus_json(&job.corpus.config));
        tree.insert("key".to_string(), Json::Str("00aabbccddeeff11".to_string()));
        tree.insert("label".to_string(), Json::Str(job.config.label.clone()));
        tree.insert("manifest".to_string(), Json::Str(job.manifest.name.clone()));
        let mut hand = String::from("prefix-preserved:");
        encode_job_into("00aabbccddeeff11", &job, &mut hand);
        assert_eq!(hand, format!("prefix-preserved:{}", Json::Obj(tree).dump()));

        let mut tree = BTreeMap::new();
        tree.insert("error".to_string(), Json::Str("bo\"om\n \u{1}".to_string()));
        tree.insert("key".to_string(), Json::Str("deadbeef".to_string()));
        let mut hand = String::from("prefix-preserved:");
        err_reply_line_into("deadbeef", "bo\"om\n \u{1}", &mut hand);
        assert_eq!(hand, format!("prefix-preserved:{}", Json::Obj(tree).dump()));
    }

    fn det_record_for(key: &str) -> RunRecord {
        RunRecord {
            label: key.to_string(),
            train_curve: vec![(1, 3.5)],
            valid_curve: vec![(1, 3.25)],
            final_valid_loss: 3.25,
            rms_curves: std::collections::BTreeMap::new(),
            final_rms: vec![],
            diverged: false,
            wall_seconds: 0.0,
        }
    }

    /// The read-ahead serve loop answers every queued frame in arrival
    /// order and terminates cleanly on EOF — pipelined parents rely on
    /// one reply per job frame, no drops, no hangs.
    #[test]
    fn serve_reads_ahead_and_replies_to_every_frame_in_order() {
        let job = test_job();
        let mut input = Vec::new();
        // queue more frames than WORKER_READAHEAD to exercise the
        // bounded-channel backpressure path
        let n = WORKER_READAHEAD + 3;
        for i in 0..n {
            write_frame(&mut input, &encode_job(&format!("key{i:02}"), &job)).unwrap();
        }
        let mut output = Vec::new();
        let mut served = Vec::new();
        serve(Cursor::new(input), &mut output, |j| {
            served.push(j.key.clone());
            if j.key == "key01" {
                anyhow::bail!("injected job failure");
            }
            Ok(det_record_for(&j.key))
        })
        .unwrap();
        assert_eq!(served, (0..n).map(|i| format!("key{i:02}")).collect::<Vec<_>>());
        let mut r = Cursor::new(output);
        check_hello(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        for i in 0..n {
            let key = format!("key{i:02}");
            match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
                WireReply::Record { key: k, record } => {
                    assert_eq!(k, key);
                    assert_eq!(record, det_record_for(&key));
                }
                WireReply::Error { key: k, error } => {
                    assert_eq!(k, "key01", "unexpected error for {k}: {error}");
                    assert!(error.contains("injected job failure"));
                }
            }
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "extra frame after the replies");
    }

    /// A protocol failure mid-stream fails `serve` (the worker exits
    /// nonzero) but every frame decoded before the corruption still got
    /// its reply — a pipelined parent loses only the unacked window.
    #[test]
    fn serve_replies_to_good_frames_then_errors_on_garbage() {
        let job = test_job();
        let mut input = Vec::new();
        write_frame(&mut input, &encode_job("goodkey", &job)).unwrap();
        input.extend_from_slice(b"this is not a frame\n");
        let mut output = Vec::new();
        let err = serve(Cursor::new(input), &mut output, |j| Ok(det_record_for(&j.key)))
            .expect_err("garbage on the stream must fail the serve loop");
        assert!(format!("{err:#}").contains("bad frame length prefix"), "wrong error: {err:#}");
        let mut r = Cursor::new(output);
        check_hello(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        match decode_reply(&read_frame(&mut r).unwrap().unwrap()).unwrap() {
            WireReply::Record { key, .. } => assert_eq!(key, "goodkey"),
            WireReply::Error { error, .. } => panic!("good frame got an error reply: {error}"),
        }
    }

    #[test]
    fn replies_round_trip_through_the_cache_codec() {
        let record = RunRecord {
            label: "r".to_string(),
            train_curve: vec![(1, 3.5), (8, 2.5)],
            valid_curve: vec![(8, 2.5)],
            final_valid_loss: 2.5,
            rms_curves: std::collections::BTreeMap::new(),
            final_rms: vec![("w.head".to_string(), 1.0)],
            diverged: false,
            wall_seconds: 0.01,
        };
        let line = ok_reply_line("deadbeefdeadbeef", "w32", &record);
        match decode_reply(&line).unwrap() {
            WireReply::Record { key, record: back } => {
                assert_eq!(key, "deadbeefdeadbeef");
                assert_eq!(back, record);
            }
            WireReply::Error { .. } => panic!("ok reply decoded as error"),
        }
        match decode_reply(&err_reply_line("deadbeefdeadbeef", "boom")).unwrap() {
            WireReply::Error { key, error } => {
                assert_eq!(key, "deadbeefdeadbeef");
                assert_eq!(error, "boom");
            }
            WireReply::Record { .. } => panic!("error reply decoded as record"),
        }
        assert!(decode_reply("not json at all").is_err());
    }
}
