//! Deterministic fault injection for the network stack: a [`FaultPlan`]
//! spec plus an in-tree chaos proxy (`repro chaos --listen A --upstream
//! B --faults SPEC`) that sits between an engine and a real `repro
//! worker --listen`, forwarding the wire protocol verbatim except for
//! the exact faults the plan names.
//!
//! # Determinism
//!
//! Every fault is pinned to a *reply ordinal*: the proxy counts worker
//! reply frames globally (across all proxied connections, 1-based) and
//! each destructive fault fires **exactly once**, at exactly the
//! ordinal its plan names — `drop-conn:5` kills the connection in place
//! of the fifth reply, on every run.  Per-connection counters would
//! re-fire the same fault after every engine reconnect and chew through
//! the restart budget; a global one-shot counter makes each plan a
//! single, recoverable wound.  The first upstream frame of each
//! connection is the worker hello and is forwarded uncounted, so the
//! handshake itself is never a fault target.
//!
//! `delay-ms` is the exception: it is not one-shot but a uniform added
//! latency on every counted reply, for shaking out ordering assumptions
//! without ever corrupting anything.
//!
//! The chaos suite (`tests/chaos.rs`) drives a real sweep through the
//! proxy under every plan and asserts the drained cache is
//! byte-identical to a clean in-process run — the whole point: no fault
//! the plan can express may corrupt results, only delay them.

use std::io::{BufReader, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::net::{Endpoint, Listener};
use super::wire;

/// A parsed `--faults` / `UMUP_FAULTS` spec: which reply ordinal each
/// fault fires at (see the module docs for the counting rules).  All
/// fields `None` is a pure passthrough proxy.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// After forwarding reply `n`, hold the connection open but forward
    /// nothing more — the hung-but-alive shape only `--job-timeout`
    /// can recover from.
    pub stall_after: Option<u64>,
    /// Sleep this many milliseconds before forwarding *every* counted
    /// reply (not one-shot).
    pub delay_ms: Option<u64>,
    /// In place of reply `n`, send its length prefix plus half its
    /// payload, then close — a torn frame mid-payload.
    pub tear_frame: Option<u64>,
    /// Close the connection in place of reply `n` (the reply is lost).
    pub drop_conn: Option<u64>,
    /// In place of reply `n`, send a line that is not a frame at all,
    /// then close — garbage on the stream.
    pub garbage_reply: Option<u64>,
}

impl FaultPlan {
    /// Parse a comma-separated `key:value` spec, e.g.
    /// `stall-after:3,delay-ms:50`.  Unknown keys error naming the
    /// known set; an empty spec is a passthrough plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("fault {part:?} is not key:value"))?;
            let value: u64 = value
                .trim()
                .parse()
                .with_context(|| format!("fault {key:?} value {value:?} is not a number"))?;
            match key.trim() {
                "stall-after" => plan.stall_after = Some(value),
                "delay-ms" => plan.delay_ms = Some(value),
                "tear-frame" => plan.tear_frame = Some(value),
                "drop-conn" => plan.drop_conn = Some(value),
                "garbage-reply" => plan.garbage_reply = Some(value),
                other => bail!(
                    "unknown fault {other:?} (known: stall-after, delay-ms, tear-frame, \
                     drop-conn, garbage-reply)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when the plan injects nothing at all.
    pub fn is_passthrough(&self) -> bool {
        *self == FaultPlan::default()
    }
}

/// Accept proxied connections forever, spawning one thread per client.
/// Each connection dials `upstream` fresh; faults fire against the
/// process-global reply counter, so a plan's one-shot faults stay
/// one-shot across reconnects.  Returns only on an accept error.
pub fn run_proxy(listener: Listener, upstream: Endpoint, plan: FaultPlan) -> Result<()> {
    let counter = Arc::new(AtomicU64::new(0));
    loop {
        let (client_r, client_w, peer) = listener.accept()?;
        let upstream = upstream.clone();
        let plan = plan.clone();
        let counter = Arc::clone(&counter);
        thread::spawn(move || {
            if let Err(e) = proxy_conn(client_r, client_w, &upstream, &plan, &counter) {
                eprintln!("chaos: connection from {peer} ended: {e:#}");
            }
        });
    }
}

/// Serve one proxied connection: a raw byte pump for the client→worker
/// direction (job frames are never faulted — only replies are, so a
/// faulted run can still be byte-compared against a clean one), and a
/// frame-by-frame fault loop for worker→client replies.
fn proxy_conn(
    client_r: Box<dyn Read + Send>,
    mut client_w: Box<dyn Write + Send>,
    upstream: &Endpoint,
    plan: &FaultPlan,
    counter: &AtomicU64,
) -> Result<()> {
    let (up_r, mut up_w) = upstream
        .connect()
        .with_context(|| format!("chaos proxy dialing upstream {upstream}"))?;
    thread::spawn(move || {
        let mut client_r = client_r;
        let _ = std::io::copy(&mut client_r, &mut up_w);
    });
    let mut up_r = BufReader::new(up_r);
    // the first upstream frame is the worker hello: forwarded uncounted
    let hello = wire::read_frame(&mut up_r)
        .context("chaos proxy reading upstream hello")?
        .ok_or_else(|| anyhow!("upstream hung up before its hello frame"))?;
    wire::write_frame(&mut client_w, &hello).context("chaos proxy forwarding hello")?;
    let mut scratch = Vec::new();
    loop {
        let payload = match wire::read_frame_into(&mut up_r, &mut scratch)
            .context("chaos proxy reading upstream reply")?
        {
            Some(p) => p,
            None => return Ok(()),
        };
        // 1-based global reply ordinal — the fault trigger
        let n = counter.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(ms) = plan.delay_ms {
            thread::sleep(Duration::from_millis(ms));
        }
        if plan.garbage_reply == Some(n) {
            eprintln!("chaos: sending garbage in place of reply {n}");
            client_w.write_all(b"** chaos garbage **\n").context("writing garbage")?;
            client_w.flush().context("flushing garbage")?;
            return Ok(());
        }
        if plan.tear_frame == Some(n) {
            eprintln!("chaos: tearing the frame of reply {n}");
            let torn = payload.len() / 2;
            writeln!(client_w, "{}", payload.len()).context("writing torn prefix")?;
            client_w
                .write_all(&payload.as_bytes()[..torn])
                .context("writing torn payload")?;
            client_w.flush().context("flushing torn frame")?;
            return Ok(());
        }
        if plan.drop_conn == Some(n) {
            eprintln!("chaos: dropping the connection in place of reply {n}");
            return Ok(());
        }
        wire::write_frame(&mut client_w, payload)
            .with_context(|| format!("chaos proxy forwarding reply {n}"))?;
        if plan.stall_after == Some(n) {
            eprintln!("chaos: stalling the connection after reply {n}");
            loop {
                thread::sleep(Duration::from_secs(3600));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plans_parse_and_reject_unknown_keys() {
        let plan = FaultPlan::parse("stall-after:3, delay-ms:50,tear-frame:2").unwrap();
        assert_eq!(plan.stall_after, Some(3));
        assert_eq!(plan.delay_ms, Some(50));
        assert_eq!(plan.tear_frame, Some(2));
        assert_eq!(plan.drop_conn, None);
        assert!(!plan.is_passthrough());
        assert!(FaultPlan::parse("").unwrap().is_passthrough());
        assert!(FaultPlan::parse(" , ").unwrap().is_passthrough());
        let err = FaultPlan::parse("explode:1").unwrap_err().to_string();
        assert!(err.contains("unknown fault") && err.contains("drop-conn"), "got: {err}");
        assert!(FaultPlan::parse("delay-ms").is_err());
        assert!(FaultPlan::parse("delay-ms:soon").is_err());
    }

    #[test]
    fn passthrough_proxy_forwards_hello_and_replies_verbatim() {
        let up_listener = Listener::bind(&Endpoint::Tcp("127.0.0.1:0".to_string())).unwrap();
        let up_addr = up_listener.local_desc();
        let upstream = thread::spawn(move || {
            let (_r, mut w, _peer) = up_listener.accept().unwrap();
            wire::write_frame(&mut w, &wire::hello_line()).unwrap();
            wire::write_frame(&mut w, "reply-one").unwrap();
            wire::write_frame(&mut w, "reply-two").unwrap();
        });
        let proxy_listener = Listener::bind(&Endpoint::Tcp("127.0.0.1:0".to_string())).unwrap();
        let proxy_addr = proxy_listener.local_desc();
        let up_ep = Endpoint::parse(&up_addr).unwrap();
        thread::spawn(move || {
            let _ = run_proxy(proxy_listener, up_ep, FaultPlan::default());
        });
        let (r, _w) = Endpoint::parse(&proxy_addr).unwrap().connect().unwrap();
        let mut r = BufReader::new(r);
        assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), wire::hello_line());
        assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), "reply-one");
        assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), "reply-two");
        upstream.join().unwrap();
    }

    #[test]
    fn garbage_fault_fires_at_exactly_its_ordinal() {
        let up_listener = Listener::bind(&Endpoint::Tcp("127.0.0.1:0".to_string())).unwrap();
        let up_addr = up_listener.local_desc();
        let upstream = thread::spawn(move || {
            let (_r, mut w, _peer) = up_listener.accept().unwrap();
            wire::write_frame(&mut w, &wire::hello_line()).unwrap();
            wire::write_frame(&mut w, "reply-one").unwrap();
            wire::write_frame(&mut w, "reply-two").unwrap();
        });
        let proxy_listener = Listener::bind(&Endpoint::Tcp("127.0.0.1:0".to_string())).unwrap();
        let proxy_addr = proxy_listener.local_desc();
        let up_ep = Endpoint::parse(&up_addr).unwrap();
        thread::spawn(move || {
            let plan = FaultPlan::parse("garbage-reply:2").unwrap();
            let _ = run_proxy(proxy_listener, up_ep, plan);
        });
        let (r, _w) = Endpoint::parse(&proxy_addr).unwrap().connect().unwrap();
        let mut r = BufReader::new(r);
        assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), wire::hello_line());
        // reply 1 passes untouched; reply 2 is garbage, which the frame
        // reader rejects exactly like any other stream corruption
        assert_eq!(wire::read_frame(&mut r).unwrap().unwrap(), "reply-one");
        let err = wire::read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("length prefix"), "got: {err}");
        upstream.join().unwrap();
    }
}
