//! Least-recently-used pooling for per-worker compiled sessions.
//!
//! The engine's workers each keep a `manifest name -> Session` pool so
//! XLA compiles (seconds per module) amortize across jobs.  The pool
//! used to be cleared *wholesale* when it hit its cap, which threw away
//! every warm session the moment a multi-shape sweep touched one shape
//! too many.  [`LruPool`] replaces that with per-entry LRU eviction:
//! only the coldest session is dropped, so manifest-affine job streams
//! (the common case — sweeps batch by shape) keep their hit rate.
//!
//! The pool is deliberately generic over the payload: the engine
//! instantiates it with real `Runner`s, while the tests (which must run
//! without XLA artifacts) instantiate it with mock values through the
//! same code path.

use anyhow::Result;

/// A capacity-bounded `name -> V` pool with least-recently-used
/// eviction and hit/miss/eviction counters.
///
/// Backed by a `Vec` ordered cold-to-warm: caps are single digits (a
/// worker holds a handful of compiled sessions), so linear scans beat
/// any pointer-chasing structure.
pub struct LruPool<V> {
    cap: usize,
    /// Cold (front) to warm (back); the back entry is the most recent.
    entries: Vec<(String, V)>,
    hits: usize,
    misses: usize,
    evictions: usize,
}

impl<V> LruPool<V> {
    pub fn new(cap: usize) -> LruPool<V> {
        LruPool { cap: cap.max(1), entries: Vec::new(), hits: 0, misses: 0, evictions: 0 }
    }

    /// Fetch `name`, building it with `make` on a miss; evicts the
    /// least-recently-used entry first when the pool is full.  Either
    /// way the entry becomes the most-recently-used.  A failing `make`
    /// leaves the pool unchanged (the slot is not reserved).
    pub fn get_or_create<F>(&mut self, name: &str, make: F) -> Result<&mut V>
    where
        F: FnOnce() -> Result<V>,
    {
        if let Some(pos) = self.entries.iter().position(|(n, _)| n == name) {
            self.hits += 1;
            let entry = self.entries.remove(pos);
            self.entries.push(entry);
        } else {
            let v = make()?;
            self.misses += 1;
            if self.entries.len() >= self.cap {
                self.entries.remove(0);
                self.evictions += 1;
            }
            self.entries.push((name.to_string(), v));
        }
        Ok(&mut self.entries.last_mut().expect("just pushed or promoted").1)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|(n, _)| n == name)
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Resident names, most-recently-used first (test observability).
    pub fn names_mru(&self) -> Vec<&str> {
        self.entries.iter().rev().map(|(n, _)| n.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::bail;

    /// Mock session factory: counts how many times each "compile" runs.
    fn counting_make(log: &mut Vec<String>, name: &str) -> Result<String> {
        log.push(name.to_string());
        Ok(format!("session:{name}"))
    }

    #[test]
    fn capacity_one_thrashes_and_capacity_three_holds() {
        for cap in 1..=3usize {
            let mut pool: LruPool<String> = LruPool::new(cap);
            let mut compiles = Vec::new();
            // touch three distinct manifests twice, round-robin
            for _ in 0..2 {
                for name in ["w32", "w64", "w128"] {
                    let v = pool.get_or_create(name, || counting_make(&mut compiles, name))
                        .unwrap();
                    assert_eq!(v, &format!("session:{name}"));
                }
            }
            assert!(pool.len() <= cap, "cap {cap} violated: {}", pool.len());
            match cap {
                // round-robin over 3 names with 1 or 2 slots always
                // misses (the classic LRU-thrash pattern)
                1 | 2 => assert_eq!(compiles.len(), 6, "cap {cap}"),
                // 3 slots hold the whole working set: 3 compiles total
                _ => assert_eq!(compiles.len(), 3, "cap {cap}"),
            }
            assert_eq!(pool.misses(), compiles.len());
            assert_eq!(pool.hits() + pool.misses(), 6);
        }
    }

    #[test]
    fn reuse_order_evicts_the_coldest_not_the_oldest_inserted() {
        let mut pool: LruPool<String> = LruPool::new(2);
        let mut compiles = Vec::new();
        pool.get_or_create("a", || counting_make(&mut compiles, "a")).unwrap();
        pool.get_or_create("b", || counting_make(&mut compiles, "b")).unwrap();
        // touch "a" again: "b" becomes the LRU victim despite being newer
        pool.get_or_create("a", || counting_make(&mut compiles, "a")).unwrap();
        assert_eq!(pool.names_mru(), vec!["a", "b"]);
        pool.get_or_create("c", || counting_make(&mut compiles, "c")).unwrap();
        assert!(pool.contains("a"), "recently-used entry must survive");
        assert!(!pool.contains("b"), "coldest entry must be evicted");
        assert_eq!(pool.names_mru(), vec!["c", "a"]);
        assert_eq!(pool.evictions(), 1);
        assert_eq!(compiles, vec!["a", "b", "c"]);
    }

    #[test]
    fn manifest_affine_stream_hits_after_warmup() {
        // a sweep batched by shape: long runs of one manifest with an
        // occasional baseline shape interleaved — the engine's common
        // access pattern, which wholesale clearing used to destroy
        let mut pool: LruPool<String> = LruPool::new(2);
        let mut compiles = Vec::new();
        let stream: Vec<&str> =
            (0..50).map(|i| if i % 10 < 9 { "w256" } else { "w64" }).collect();
        for name in &stream {
            pool.get_or_create(name, || counting_make(&mut compiles, name)).unwrap();
        }
        // both shapes fit: exactly one compile each, everything else hits
        assert_eq!(compiles.len(), 2);
        assert_eq!(pool.hits(), 48);
        assert_eq!(pool.evictions(), 0);
        let hit_rate = pool.hits() as f64 / (pool.hits() + pool.misses()) as f64;
        assert!(hit_rate > 0.9, "affine stream should be >90% hits, got {hit_rate}");
    }

    #[test]
    fn failed_make_leaves_pool_unchanged_and_is_retryable() {
        let mut pool: LruPool<String> = LruPool::new(2);
        let err = pool
            .get_or_create("boom", || -> Result<String> { bail!("compile failed") })
            .unwrap_err();
        assert!(err.to_string().contains("compile failed"));
        assert!(pool.is_empty());
        assert_eq!(pool.misses(), 0, "failed make is not a miss");
        // the same name can be retried successfully afterwards
        let mut compiles = Vec::new();
        pool.get_or_create("boom", || counting_make(&mut compiles, "boom")).unwrap();
        assert!(pool.contains("boom"));
    }
}
