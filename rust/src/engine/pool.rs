//! Persistent worker threads for the engine.
//!
//! Each of the N long-lived workers asks the engine's [`Backend`] for
//! its own [`Executor`] (created *inside* the worker thread, so it may
//! own `!Send` state — XLA PJRT handles are `Rc`-based, and a child
//! process's pipes are single-owner) and pulls work from the shared
//! [`Scheduler`] — which hands each worker manifest-affine job streams
//! when the backend advertises per-manifest warm state (see the
//! scheduler docs), so cross-shape sweeps stop thrashing the per-worker
//! session pools.  Because the workers outlive individual submissions,
//! executor state (compiled sessions, worker children) is amortized
//! across experiments, not just within one sweep.
//!
//! Results are persisted to the shared run cache *by the worker*, before
//! the outcome is reported to the submitting handle: a caller that drops
//! its [`crate::engine::SweepHandle`] mid-stream abandons only the
//! notifications, never the completed work.
//!
//! Error handling: a failing job is reported back per task (stringified)
//! and the worker keeps pulling — the pre-engine scheduler's
//! `break`-on-error bug (which silently abandoned a worker's remaining
//! share of the queue) is structurally impossible here.  Executor
//! *panics* are caught the same way (per job, message preserved), so a
//! single poisoned run cannot kill a worker and strand the rest of a
//! long sweep.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::train::RunRecord;

use super::backend::{Backend, Executor as _};
use super::events::{Event, JobStatus};
use super::job::EngineJob;
use super::sched::{Reply, Scheduler};
use super::{lock, Shared};

/// A per-worker job executor closure — the payload of
/// [`crate::engine::MockBackend`] and the deprecated
/// `Engine::with_factory` shim.  It is created *inside* the worker
/// thread, so it may own `!Send` state.
pub type JobExec = Box<dyn FnMut(&EngineJob) -> Result<RunRecord>>;

pub(crate) struct WorkerPool {
    sched: Arc<Scheduler>,
    backend: Arc<dyn Backend>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(
        workers: usize,
        backend: Arc<dyn Backend>,
        sched: Arc<Scheduler>,
        shared: Arc<Shared>,
    ) -> WorkerPool {
        let handles = (0..workers.max(1))
            .map(|w| {
                let sched = Arc::clone(&sched);
                let shared = Arc::clone(&shared);
                let backend = Arc::clone(&backend);
                std::thread::spawn(move || worker_loop(w, &sched, &shared, &*backend))
            })
            .collect();
        WorkerPool { sched, backend, handles }
    }
}

fn worker_loop(w: usize, sched: &Scheduler, shared: &Shared, backend: &dyn Backend) {
    let mut exec = backend.spawn_executor(w);
    // how many jobs this worker keeps in flight: 1 = classic lockstep
    // (pull one, run one, report one); pipelining executors raise it
    // and the scheduler feeds whole warm-affine batches
    let depth = exec.pipeline_depth().max(1);
    shared.events.publish(Event::WorkerSpawned { worker: w, window: depth });
    loop {
        let tasks = sched.next_batch_for(w, depth);
        if tasks.is_empty() {
            return; // drained shutdown
        }
        let t0 = std::time::Instant::now();
        let jobs: Vec<(&EngineJob, &str)> =
            tasks.iter().map(|t| (&t.job, t.key.as_str())).collect();
        // RefCell: both the report callback and the panic-recovery
        // sweep below need the completion flags
        let completed = std::cell::RefCell::new(vec![false; tasks.len()]);
        // each completion is persisted/published/replied from inside
        // the callback, as the executor produces it — results stream
        // out of a pipelined window in completion order, they don't
        // wait for the whole batch to land
        let mut report = |i: usize, result: Result<RunRecord>| {
            let task = &tasks[i];
            if std::mem::replace(&mut completed.borrow_mut()[i], true) {
                // the executor contract says exactly-once; don't let a
                // buggy backend double-report a job
                eprintln!(
                    "engine: worker {w} executor reported {} twice (dropping the second)",
                    task.job.config.label
                );
                return;
            }
            let result = match result {
                Ok(record) => {
                    // persist before reporting, so a consumer that sees
                    // the outcome may rely on the cache already holding
                    // it
                    if let Err(e) =
                        lock(&shared.cache).put(&task.key, &task.job.manifest.name, &record)
                    {
                        eprintln!(
                            "run-cache: failed to persist {}: {e:#}",
                            task.job.config.label
                        );
                    }
                    Ok(record)
                }
                Err(e) => Err(format!("{e:#}")),
            };
            {
                let mut stats = lock(&shared.stats);
                stats.executed += 1;
                if result.is_err() {
                    stats.failed += 1;
                }
            }
            // publish before replying: a consumer woken by the outcome
            // may rely on the event already being on the bus
            if shared.events.is_active() {
                shared.events.publish(Event::JobDone {
                    sweep: task.sweep,
                    idx: task.idx,
                    key: task.key.clone(),
                    manifest: task.job.manifest.name.clone(),
                    label: task.job.config.label.clone(),
                    status: JobStatus::Executed,
                    ok: result.is_ok(),
                    error: result.as_ref().err().cloned(),
                    duration_ms: Some(t0.elapsed().as_millis() as u64),
                    worker: Some(w),
                });
            }
            let _ = task.reply.send(Reply::Done { idx: task.idx, result });
        };
        // AssertUnwindSafe: worst case a panic leaves the executor's
        // session pool with a half-inserted entry, which is rebuilt on
        // the next miss — strictly better than losing the worker.
        let ran = catch_unwind(AssertUnwindSafe(|| exec.run_batch(&jobs, &mut report)));
        if let Err(payload) = ran {
            // a panic mid-batch already reported some completions
            // through the callback; every job still outstanding gets
            // the panic as its per-job outcome
            let msg = format!("job panicked: {}", panic_msg(payload.as_ref()));
            for i in 0..tasks.len() {
                let already = completed.borrow()[i];
                if !already {
                    report(i, Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // hang up: workers drain the remaining queue, then exit (each
        // dropping its executor), then the backend's fleet-level
        // teardown hook runs
        self.sched.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.backend.shutdown();
    }
}
