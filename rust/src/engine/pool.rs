//! Persistent worker threads for the engine.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (`!Send`), so compiled
//! sessions can never migrate between threads.  The pool therefore keeps
//! N long-lived workers, each of which builds its *own* executor state
//! (in production: a `manifest name -> Session` map, see
//! `Engine::new`) via the factory closure and pulls work from the
//! shared [`Scheduler`] — which hands each worker manifest-affine job
//! streams (see the scheduler docs), so cross-shape sweeps stop
//! thrashing the per-worker session pools.  Because the workers outlive
//! individual submissions, XLA compiles are amortized across
//! experiments, not just within one sweep.
//!
//! Results are persisted to the shared run cache *by the worker*, before
//! the outcome is reported to the submitting handle: a caller that drops
//! its [`crate::engine::SweepHandle`] mid-stream abandons only the
//! notifications, never the completed work.
//!
//! Error handling: a failing job is reported back per task (stringified)
//! and the worker keeps pulling — the pre-engine scheduler's
//! `break`-on-error bug (which silently abandoned a worker's remaining
//! share of the queue) is structurally impossible here.  Executor
//! *panics* are caught the same way (per job, message preserved), so a
//! single poisoned run cannot kill a worker and strand the rest of a
//! long sweep.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::train::RunRecord;

use super::job::EngineJob;
use super::sched::{Reply, Scheduler};
use super::{lock, Shared};

/// A per-worker job executor.  It is created *inside* the worker thread,
/// so it may own `!Send` state (XLA sessions).
pub type JobExec = Box<dyn FnMut(&EngineJob) -> Result<RunRecord>>;

pub(crate) struct WorkerPool {
    sched: Arc<Scheduler>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new<F>(
        workers: usize,
        factory: F,
        sched: Arc<Scheduler>,
        shared: Arc<Shared>,
    ) -> WorkerPool
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let handles = (0..workers.max(1))
            .map(|w| {
                let sched = Arc::clone(&sched);
                let shared = Arc::clone(&shared);
                let factory = Arc::clone(&factory);
                std::thread::spawn(move || worker_loop(w, &sched, &shared, &*factory))
            })
            .collect();
        WorkerPool { sched, handles }
    }
}

fn worker_loop<F>(w: usize, sched: &Scheduler, shared: &Shared, factory: &F)
where
    F: Fn(usize) -> JobExec,
{
    let mut exec = factory(w);
    while let Some(task) = sched.next_for(w) {
        // AssertUnwindSafe: worst case a panic leaves the executor's
        // session pool with a half-inserted entry, which is rebuilt on
        // the next miss — strictly better than losing the worker.
        let result = match catch_unwind(AssertUnwindSafe(|| exec(&task.job))) {
            Ok(Ok(record)) => {
                // persist before reporting, so a consumer that sees the
                // outcome may rely on the cache already holding it
                if let Err(e) =
                    lock(&shared.cache).put(&task.key, &task.job.manifest.name, &record)
                {
                    eprintln!(
                        "run-cache: failed to persist {}: {e:#}",
                        task.job.config.label
                    );
                }
                Ok(record)
            }
            Ok(Err(e)) => Err(format!("{e:#}")),
            Err(payload) => Err(format!("job panicked: {}", panic_msg(payload.as_ref()))),
        };
        {
            let mut stats = lock(&shared.stats);
            stats.executed += 1;
            if result.is_err() {
                stats.failed += 1;
            }
        }
        let _ = task.reply.send(Reply::Done { idx: task.idx, result });
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // hang up: workers drain the remaining queue, then exit
        self.sched.shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
