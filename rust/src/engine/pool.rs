//! Persistent worker threads for the engine.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (`!Send`), so compiled
//! sessions can never migrate between threads.  The pool therefore keeps
//! N long-lived workers, each of which builds its *own* executor state
//! (in production: a `manifest name -> Session` map, see
//! `Engine::new`) via the factory closure and drains a shared task
//! queue.  Because the workers outlive individual `Engine::run` calls,
//! XLA compiles are amortized across experiments, not just within one
//! sweep.
//!
//! Error handling: a failing job is reported back per task (stringified)
//! and the worker keeps draining the queue — the pre-engine scheduler's
//! `break`-on-error bug (which silently abandoned a worker's remaining
//! share of the queue) is structurally impossible here.  Executor
//! *panics* are caught the same way (per job, message preserved), so a
//! single poisoned run cannot kill a worker and strand the rest of a
//! long sweep.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::train::RunRecord;

use super::job::EngineJob;

/// A per-worker job executor.  It is created *inside* the worker thread,
/// so it may own `!Send` state (XLA sessions).
pub type JobExec = Box<dyn FnMut(&EngineJob) -> Result<RunRecord>>;

/// One dispatched job plus its reply channel.
pub(crate) struct Task {
    pub idx: usize,
    pub job: EngineJob,
    pub reply: Sender<(usize, Result<RunRecord, String>)>,
}

pub(crate) struct WorkerPool {
    tx: Option<Sender<Task>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new<F>(workers: usize, factory: F) -> WorkerPool
    where
        F: Fn(usize) -> JobExec + Send + Sync + 'static,
    {
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let handles = (0..workers.max(1))
            .map(|w| {
                let rx = Arc::clone(&rx);
                let factory = Arc::clone(&factory);
                std::thread::spawn(move || worker_loop(w, &rx, &*factory))
            })
            .collect();
        WorkerPool { tx: Some(tx), handles }
    }

    /// Queue a task; returns false if every worker is gone.
    pub fn submit(&self, task: Task) -> bool {
        match &self.tx {
            Some(tx) => tx.send(task).is_ok(),
            None => false,
        }
    }
}

fn worker_loop<F>(w: usize, rx: &Mutex<Receiver<Task>>, factory: &F)
where
    F: Fn(usize) -> JobExec,
{
    let mut exec = factory(w);
    loop {
        // The lock is held only around `recv` (tasks are handed out one
        // at a time); execution happens with the queue unlocked.
        let task = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // a sibling panicked holding the lock
        };
        let Ok(task) = task else {
            return; // channel closed: pool is shutting down
        };
        // AssertUnwindSafe: worst case a panic leaves the executor's
        // session pool with a half-inserted entry, which is rebuilt on
        // the next miss — strictly better than losing the worker.
        let out = match catch_unwind(AssertUnwindSafe(|| exec(&task.job))) {
            Ok(res) => res.map_err(|e| format!("{e:#}")),
            Err(payload) => Err(format!("job panicked: {}", panic_msg(payload.as_ref()))),
        };
        let _ = task.reply.send((task.idx, out));
    }
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // hang up: workers drain the queue and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
