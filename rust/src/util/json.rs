//! Minimal recursive-descent JSON parser + writer.
//!
//! Parses the artifact manifests written by `python/compile/aot.py` and
//! serializes experiment results. Supports the full JSON grammar except
//! `\u` surrogate pairs outside the BMP (not produced by our tooling).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
    /// Pre-serialized JSON, spliced verbatim into `dump` output.  Never
    /// produced by [`Json::parse`]; exists so hot paths can reuse a
    /// canonical serialization they already computed (e.g. the engine's
    /// per-job canonical config, hashed for the run key and embedded in
    /// the worker wire frame) instead of rebuilding the value tree.
    /// The caller owns validity — the writer does not re-check it.
    Raw(String),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Serialize (stable key order; floats in shortest round-trip form).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// [`Json::dump`] into a caller-owned buffer (appended, not
    /// cleared) — the zero-realloc path for hot loops that serialize
    /// into one reused scratch `String`.  Byte-identical to `dump`.
    pub fn dump_into(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            // JSON has no inf/nan literal; readers map null back to
            // +inf (only divergence sentinels are non-finite)
            Json::Num(n) => write_json_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Raw(s) => out.push_str(s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` as a JSON string literal (quotes + escapes), exactly as
/// [`Json::dump`] would.  Public so hand-rolled writers (the wire
/// codec's `_into` hot path) can stay byte-identical to the tree
/// writer without building a [`Json::Str`].
pub fn write_json_str(s: &str, out: &mut String) {
    write_escaped(s, out);
}

/// Append `n` with [`Json::dump`]'s number formatting (non-finite →
/// `null`, integral magnitudes below 1e15 as integers, shortest
/// round-trip floats otherwise).  The numeric half of the
/// byte-identical hand-rolled-writer contract.
pub fn write_json_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-sync to char boundary for multibyte utf-8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let chunk = std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\" ü"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn non_finite_dumps_as_null() {
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(2.5).dump(), "2.5");
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse("[[[[[[1]]]]]]").unwrap();
        assert!(matches!(v, Json::Arr(_)));
    }

    #[test]
    fn raw_splices_verbatim() {
        let mut m = BTreeMap::new();
        m.insert("pre".to_string(), Json::Raw("{\"a\":[1,2.5]}".to_string()));
        m.insert("s".to_string(), Json::Str("x".to_string()));
        let dumped = Json::Obj(m).dump();
        assert_eq!(dumped, "{\"pre\":{\"a\":[1,2.5]},\"s\":\"x\"}");
        // the splice round-trips through the parser as real structure
        let back = Json::parse(&dumped).unwrap();
        assert_eq!(back.get("pre").unwrap().get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
