//! ASCII line plots + CSV writers for experiment output.
//!
//! Every experiment renders both a CSV (for external plotting) and a
//! terminal plot so the figure *shape* (who wins, where the optimum falls)
//! is visible directly in logs and EXPERIMENTS.md.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::Result;

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Self {
        Series { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series to a `width x height` ASCII grid. `log_x` plots x on a
/// log2 axis (LR sweeps are log-spaced throughout the paper).
pub fn ascii_plot(series: &[Series], width: usize, height: usize, log_x: bool) -> String {
    let tx = |x: f64| if log_x { x.log2() } else { x };
    let pts: Vec<(f64, f64, usize)> = series
        .iter()
        .enumerate()
        .flat_map(|(si, s)| {
            s.points.iter().filter(|p| p.1.is_finite()).map(move |&(x, y)| (tx(x), y, si))
        })
        .collect();
    if pts.is_empty() {
        return "(no finite data)\n".into();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y, _) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 == x0 {
        x1 = x0 + 1.0;
    }
    if y1 == y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for &(x, y, si) in &pts {
        let c = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
        let r = ((y1 - y) / (y1 - y0) * (height - 1) as f64).round() as usize;
        grid[r.min(height - 1)][c.min(width - 1)] = MARKS[si % MARKS.len()];
    }
    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let yv = y1 - (y1 - y0) * r as f64 / (height - 1) as f64;
        let _ = writeln!(out, "{yv:>9.3} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(
        out,
        "{:>9} +{}",
        "",
        "-".repeat(width)
    );
    let xl = if log_x { format!("log2x: [{x0:.2}, {x1:.2}]") } else { format!("x: [{x0:.3}, {x1:.3}]") };
    let _ = writeln!(out, "{:>11}{xl}", "");
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "{:>11}{} = {}", "", MARKS[si % MARKS.len()], s.label);
    }
    out
}

/// Write series as a long-format CSV: label,x,y
pub fn write_csv(path: &Path, series: &[Series]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut s = String::from("series,x,y\n");
    for sr in series {
        for &(x, y) in &sr.points {
            let _ = writeln!(s, "{},{x},{y}", sr.label);
        }
    }
    fs::write(path, s)?;
    Ok(())
}

/// Write an arbitrary table as CSV.
pub fn write_table(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut s = header.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_renders() {
        let mut a = Series::new("a");
        let mut b = Series::new("b");
        for i in 0..20 {
            let x = 2f64.powi(i - 10);
            a.push(x, (i as f64 - 10.0).powi(2));
            b.push(x, (i as f64 - 6.0).powi(2) + 5.0);
        }
        let p = ascii_plot(&[a, b], 60, 12, true);
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("a") && p.contains("log2x"));
    }

    #[test]
    fn csv_writes() {
        let dir = std::env::temp_dir().join("umup_plot_test");
        let mut s = Series::new("s");
        s.push(1.0, 2.0);
        write_csv(&dir.join("t.csv"), &[s]).unwrap();
        let txt = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(txt.contains("s,1,2"));
    }
}
