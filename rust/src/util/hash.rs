//! Stable, dependency-free hashing.
//!
//! `std`'s default hasher is randomly keyed per process, so anything
//! that must agree across runs (RNG stream forking, the engine's
//! content-addressed run cache) goes through FNV-1a instead.

/// 64-bit FNV-1a over a byte string.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_deterministic() {
        // the published FNV-1a 64 offset basis: hash of the empty input
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"umup"), fnv1a64(b"umup"));
        assert_ne!(fnv1a64(b"umup"), fnv1a64(b"umup "));
    }
}
