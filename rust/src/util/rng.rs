//! Deterministic PRNG (xoshiro256**) — seeds every stochastic choice in
//! the coordinator (corpus generation, sweep sampling) so experiments are
//! exactly reproducible from the config seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut z = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            z = z.wrapping_add(0x9e3779b97f4a7c15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
            x ^ (x >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (stable hashing of a label).
    pub fn fork(&self, label: &str) -> Rng {
        Rng::new(self.s[0] ^ crate::util::hash::fnv1a64(label.as_bytes()))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.f64() * n as f64) as usize % n.max(1)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Shuffle a slice (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices out of n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_independent() {
        let r = Rng::new(7);
        let mut a = r.fork("corpus");
        let mut b = r.fork("sweep");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
    }
}
