//! Tiny criterion-style bench harness (offline substitute for criterion).
//!
//! Benches are `harness = false` binaries; each calls [`Bencher::run`]
//! which warms up, samples wall-clock iterations until a time budget, and
//! prints mean / p50 / p95 plus throughput, machine-readable as CSV on
//! request (used to fill EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

use super::stats;

pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_samples: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
    /// Optional work units per iteration (elements, FLOPs, ...).
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / (self.mean_ns * 1e-9))
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} M/s", t / 1e6),
            Some(t) => format!("  {t:8.0} /s"),
            None => String::new(),
        };
        format!(
            "{:44} mean {:>12} p50 {:>12} p95 {:>12} ({} samples){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.samples,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bencher {
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.run_with_work(name, None, &mut f)
    }

    pub fn run_with_work<F: FnMut()>(
        &self,
        name: &str,
        work_per_iter: Option<f64>,
        f: &mut F,
    ) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < self.min_samples {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
            samples: samples.len(),
            work_per_iter,
        };
        println!("{}", r.report());
        r
    }
}

/// Prevent the optimizer from eliding a computation.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_samples: 5,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn formats_ns() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1.5e6), "1.50 ms");
    }
}
