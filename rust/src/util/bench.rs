//! Tiny criterion-style bench harness (offline substitute for criterion).
//!
//! Benches are `harness = false` binaries; each calls [`Bencher::run`]
//! which warms up, samples wall-clock iterations until a time budget, and
//! prints mean / p50 / p95 plus throughput, machine-readable as CSV on
//! request (used to fill EXPERIMENTS.md §Perf).
//!
//! Beyond one-shot timing there is a *recorded trajectory*: a bench can
//! distill its runs into named [`Metric`]s and [`record_run`] them into a
//! committed JSON file (one appended entry per recording, so the file is
//! the performance history of the repo, one point per PR).  The same
//! metrics can be gated in CI with [`check_regression`], which compares
//! the gated subset against the file's most recent entry and fails on a
//! direction-aware drop beyond a tolerance — without recording anything.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json::Json;
use super::stats;

pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_samples: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
    /// Optional work units per iteration (elements, FLOPs, ...).
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / (self.mean_ns * 1e-9))
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} M/s", t / 1e6),
            Some(t) => format!("  {t:8.0} /s"),
            None => String::new(),
        };
        format!(
            "{:44} mean {:>12} p50 {:>12} p95 {:>12} ({} samples){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.samples,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bencher {
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.run_with_work(name, None, &mut f)
    }

    pub fn run_with_work<F: FnMut()>(
        &self,
        name: &str,
        work_per_iter: Option<f64>,
        f: &mut F,
    ) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < self.min_samples {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
            samples: samples.len(),
            work_per_iter,
        };
        println!("{}", r.report());
        r
    }
}

/// Prevent the optimizer from eliding a computation.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One distilled bench number for the recorded trajectory.
///
/// `gated` metrics participate in [`check_regression`]; ungated ones are
/// recorded for the history but never fail CI (absolute wall-clock
/// numbers vary too much across runner hardware to gate on — gate
/// *ratios* computed within a single run instead).
#[derive(Debug, Clone)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: String,
    pub higher_is_better: bool,
    pub gated: bool,
}

impl Metric {
    /// A metric where larger is better (throughput, speedup ratios).
    pub fn higher(name: &str, value: f64, unit: &str) -> Metric {
        Metric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
            higher_is_better: true,
            gated: false,
        }
    }

    /// A metric where smaller is better (latency, bytes, memory).
    pub fn lower(name: &str, value: f64, unit: &str) -> Metric {
        Metric { higher_is_better: false, ..Metric::higher(name, value, unit) }
    }

    /// Mark this metric as CI-gated (checked by [`check_regression`]).
    pub fn gated(mut self) -> Metric {
        self.gated = true;
        self
    }
}

fn metric_json(m: &Metric) -> Json {
    let mut o = BTreeMap::new();
    o.insert("value".to_string(), Json::Num(m.value));
    o.insert("unit".to_string(), Json::Str(m.unit.clone()));
    o.insert("higher_is_better".to_string(), Json::Bool(m.higher_is_better));
    o.insert("gated".to_string(), Json::Bool(m.gated));
    Json::Obj(o)
}

/// `entries[i].metrics[name].value`, if present and well-formed.
fn metric_value(entry: &Json, name: &str) -> Option<f64> {
    entry.get("metrics").ok()?.get(name).ok()?.get("value").ok()?.as_f64().ok()
}

/// Load `path`'s entry list, verifying the file records `bench_name`.
/// A missing file is an empty history, not an error.
fn load_entries(path: &Path, bench_name: &str) -> Result<Vec<Json>> {
    let s = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => {
            return Err(e)
                .with_context(|| format!("reading bench trajectory {}", path.display()))
        }
    };
    let doc = Json::parse(&s)
        .with_context(|| format!("parsing bench trajectory {}", path.display()))?;
    let recorded = doc.get("bench")?.as_str()?.to_string();
    if recorded != bench_name {
        bail!("{} records bench {recorded:?}, not {bench_name:?}", path.display());
    }
    Ok(doc.get("entries")?.as_arr()?.to_vec())
}

/// Append one entry (label + unix timestamp + all `metrics`) to the
/// trajectory file at `path`, creating it if absent, and print each
/// metric's delta against the previous entry.  The file is rewritten
/// whole — entries are small (a handful of numbers per PR), so the
/// history stays trivially diffable in review.
pub fn record_run(path: &Path, bench_name: &str, label: &str, metrics: &[Metric]) -> Result<()> {
    let mut entries = load_entries(path, bench_name)?;
    let prev = entries.last().cloned();
    for m in metrics {
        match prev.as_ref().and_then(|p| metric_value(p, &m.name)) {
            Some(old) if old != 0.0 => {
                let pct = (m.value - old) / old * 100.0;
                println!(
                    "record {:36} {:>14.3} {:8} ({pct:+.1}% vs previous entry)",
                    m.name, m.value, m.unit
                );
            }
            _ => println!(
                "record {:36} {:>14.3} {:8} (no previous value)",
                m.name, m.value, m.unit
            ),
        }
    }
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut ms = BTreeMap::new();
    for m in metrics {
        ms.insert(m.name.clone(), metric_json(m));
    }
    let mut entry = BTreeMap::new();
    entry.insert("label".to_string(), Json::Str(label.to_string()));
    entry.insert("ts".to_string(), Json::Num(ts as f64));
    entry.insert("metrics".to_string(), Json::Obj(ms));
    entries.push(Json::Obj(entry));

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str(bench_name.to_string()));
    doc.insert("entries".to_string(), Json::Arr(entries));
    let body = Json::Obj(doc).dump();
    std::fs::write(path, body + "\n")
        .with_context(|| format!("writing bench trajectory {}", path.display()))?;
    println!("recorded {} metrics as {label:?} in {}", metrics.len(), path.display());
    Ok(())
}

/// Compare the *gated* subset of `metrics` against the most recent entry
/// in the trajectory file; fail on a direction-aware regression beyond
/// `tolerance` (0.30 = 30%).  A missing file, an empty history, or a
/// gated metric the baseline has never recorded are notes, not failures
/// — a fresh repo must be able to pass CI before its first recording.
/// Records nothing.
pub fn check_regression(
    path: &Path,
    bench_name: &str,
    metrics: &[Metric],
    tolerance: f64,
) -> Result<()> {
    let entries = load_entries(path, bench_name)?;
    let Some(base) = entries.last() else {
        println!(
            "check: no baseline entries in {} — nothing to gate against",
            path.display()
        );
        return Ok(());
    };
    let mut failures = Vec::new();
    for m in metrics.iter().filter(|m| m.gated) {
        let Some(old) = metric_value(base, &m.name) else {
            println!("check  {:36} (no baseline value for this metric — skipped)", m.name);
            continue;
        };
        let regressed = if m.higher_is_better {
            m.value < old * (1.0 - tolerance)
        } else {
            m.value > old * (1.0 + tolerance)
        };
        let pct = if old != 0.0 { (m.value - old) / old * 100.0 } else { 0.0 };
        if regressed {
            println!(
                "check  {:36} {:>14.3} {:8} REGRESSED vs baseline {:.3} ({pct:+.1}%)",
                m.name, m.value, m.unit, old
            );
            failures.push(format!(
                "{}: {:.3} vs baseline {:.3} {} ({pct:+.1}%, tolerance {:.0}%)",
                m.name,
                m.value,
                old,
                m.unit,
                tolerance * 100.0
            ));
        } else {
            println!(
                "check  {:36} {:>14.3} {:8} ok vs baseline {:.3} ({pct:+.1}%)",
                m.name, m.value, m.unit, old
            );
        }
    }
    if !failures.is_empty() {
        bail!("bench regression vs {}: {}", path.display(), failures.join("; "));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_samples: 5,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.samples >= 5);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn formats_ns() {
        assert_eq!(fmt_ns(12.0), "12 ns");
        assert_eq!(fmt_ns(1.5e6), "1.50 ms");
    }

    #[test]
    fn trajectory_records_appends_and_gates_direction_aware() {
        let dir = std::env::temp_dir()
            .join(format!("umup-bench-traj-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let _ = std::fs::remove_file(&path);

        // missing file: checking is a no-op, recording creates it
        check_regression(&path, "t", &[Metric::higher("speedup", 2.0, "x").gated()], 0.3)
            .unwrap();
        record_run(
            &path,
            "t",
            "first",
            &[
                Metric::higher("speedup", 2.0, "x").gated(),
                Metric::lower("open_ns", 1000.0, "ns"),
            ],
        )
        .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "t");
        assert_eq!(doc.get("entries").unwrap().as_arr().unwrap().len(), 1);

        // within tolerance passes; beyond tolerance fails in the right
        // direction only (lower speedup = regression, higher = fine)
        check_regression(&path, "t", &[Metric::higher("speedup", 1.5, "x").gated()], 0.3)
            .unwrap();
        check_regression(&path, "t", &[Metric::higher("speedup", 9.0, "x").gated()], 0.3)
            .unwrap();
        assert!(check_regression(
            &path,
            "t",
            &[Metric::higher("speedup", 1.0, "x").gated()],
            0.3
        )
        .is_err());
        // ungated metrics never fail, whatever they do
        check_regression(&path, "t", &[Metric::lower("open_ns", 1e9, "ns")], 0.3).unwrap();
        // a gated metric absent from the baseline is skipped, not failed
        check_regression(&path, "t", &[Metric::higher("new_one", 1.0, "x").gated()], 0.3)
            .unwrap();

        // appending keeps history and the bench-name guard holds
        record_run(&path, "t", "second", &[Metric::higher("speedup", 2.2, "x").gated()])
            .unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("entries").unwrap().as_arr().unwrap().len(), 2);
        assert!(load_entries(&path, "other-bench").is_err());

        let _ = std::fs::remove_dir_all(&dir);
    }
}
